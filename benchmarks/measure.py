"""Measured stage costs — calibrates the CloudManager's StageCostModel.

Times REAL operations on this host: in-memory / device-resident /
filesystem checkpoint+restore of an actual train-state pytree, and the
restart (AOT re-compile) of the train step.  The mode/end-to-end benchmarks
feed these into the fleet simulation, so Figures 5-8 rest on measured
numbers, not assumptions.
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path
from typing import Dict

import jax

from repro.configs import ARCHS, SHAPES
from repro.core.checkpointing import make_store
from repro.core.cloud import StageCostModel
from repro.models import model_zoo as zoo


def measure_store_bandwidths(state_mb: float = 32.0) -> Dict[str, float]:
    """bytes/s for each store kind on a real pytree."""
    import jax.numpy as jnp
    n = int(state_mb * 2**20 / 4)
    state = {"w": jnp.arange(n, dtype=jnp.float32),
             "m": jnp.zeros((n,), jnp.float32)}
    state = jax.block_until_ready(state)
    nbytes = 2 * n * 4
    out = {}
    with tempfile.TemporaryDirectory() as td:
        for kind in ("memory", "device", "filesystem"):
            store = make_store(kind, root=Path(td))
            t_save = store.save("b", state)
            t0 = time.perf_counter()
            _ = store.restore("b")
            t_rest = time.perf_counter() - t0
            out[f"{kind}_save_Bps"] = nbytes / max(t_save, 1e-9)
            out[f"{kind}_restore_Bps"] = nbytes / max(t_rest, 1e-9)
    return out


def measure_restart_seconds() -> float:
    """AOT compile time of the reduced train step == 'restart' stage."""
    cfg = ARCHS["granite-8b"].reduced()
    shape = SHAPES["train_4k"].reduced()
    fn = zoo.make_train_step(cfg)
    t0 = time.perf_counter()
    jax.jit(fn).lower(zoo.abstract_state(cfg),
                      zoo.batch_spec(cfg, shape)).compile()
    return time.perf_counter() - t0


def calibrated_cost_model(state_bytes: float,
                          accelerator: bool = False) -> StageCostModel:
    bw = measure_store_bandwidths()
    restart = measure_restart_seconds()
    # the local disk measured here is NOT a shared EFS: cap the filesystem
    # bandwidth at the EFS-elastic rating the paper's Mode A runs against
    efs_rating = 0.35e9
    return StageCostModel(
        state_bytes=state_bytes,
        host_bw=min(bw["memory_save_Bps"], bw["memory_restore_Bps"]),
        device_bw=min(bw["device_save_Bps"], bw["device_restore_Bps"]),
        fs_bw=min(bw["filesystem_save_Bps"], bw["filesystem_restore_Bps"],
                  efs_rating),
        restart_base=restart,
        accelerator=accelerator,
    )


if __name__ == "__main__":
    print(measure_store_bandwidths())
    print("restart", measure_restart_seconds())
