"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (one per measured point).
Sections (run all, or pick with positional names / ``--scenario``):
  fig2_overdecomp     weak-scaling analogue: time/iter vs ODF (+latency)
  fig3_loadbalance    heterogeneous fleet: no-LB vs GreedyRefine (rate-aware)
  fig5_interrupt_cpu  rescale stage breakdown, host-memory store
  fig6_interrupt_dev  rescale stage breakdown, device-resident store
  fig7_modes          interruption-handling overhead, modes A/B/C
  fig8_endtoend       total runtime vs #simultaneous interruptions
  kernels             per-kernel throughput (ref path) + allclose check
  roofline            summary over artifacts/dryrun (§Roofline)
  cluster_hetero      serving cluster: rate-aware vs round-robin routing on
                      a 2-fast/2-slow fleet + a drained spot interruption
  cluster_slo         SLO layer A/B: priority admission + deadline routing +
                      mid-stream migration vs FIFO rate-aware, Poisson
                      interactive/batch mix + a drained spot interruption
  cluster_preempt     SLO-aware preemption A/B: pause batch slots for an
                      interactive surge vs buying replicas (attainment at
                      equal-or-lower fleet dollar cost, identical tokens)
  cluster_chaos       chaos-soup A/B: hard kill + slowdown + contention +
                      endpoint failure survived via checkpoints, heartbeat
                      failure detection and straggler quarantine vs the
                      same soup with recovery off (demonstrably lost work)
  cluster_matrix      million-request scenario matrix: behaviour shapes
                      (pulse_spikes/sawtooth/staircase/epochs/
                      staged_plateau) x router x preemption x fleet on
                      the SimEngine + a 10^6-request diurnal mega-cell;
                      consolidated BENCH_matrix.json with per-cell
                      attainment/p99/tok_per_s/dollar and a global
                      sim_events_per_sec
  engine_throughput   ServingEngine A/B: chunked bulk prefill + sync-free
                      batched decode vs the streamed per-token baseline
  engine_churn        paged-cache A/B: continuous batching on a block pool
                      vs dense slots at equal kv memory, Poisson churn

``--json`` additionally persists each requested section's rows to
``BENCH_<section>.json`` at the repo root (the perf trajectory).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

# `python benchmarks/run.py` puts benchmarks/ itself on sys.path; the
# repo root must be there too for `from benchmarks.measure import ...`
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO_ROOT)

_ROWS: list = []        # rows of the section currently running (--json)


def row(name: str, us_per_call: float, derived: str = ""):
    _ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                  "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


# ------------------------------------------------------------------ fig 2
def fig2_overdecomp():
    from repro.apps.jacobi2d import run_jacobi
    for latency_us, tag in ((0, "fast-net"), (500, "cloud-tcp")):
        base = None
        for odf in (1, 2, 4, 8):
            out = run_jacobi(grid_size=512, n_pes=4, odf=odf, iters=14,
                             comm_latency_s=latency_us * 1e-6)
            us = out.time_per_iter * 1e6
            base = base or us
            row(f"fig2_overdecomp_{tag}_odf{odf}", us,
                f"speedup_vs_odf1={base/us:.2f}")


# ------------------------------------------------------------------ fig 3
def fig3_loadbalance():
    rates = {"cpu_fleet": [1.0, 0.85, 0.6, 1.0],
             "gpu_fleet": [1.0, 1.0, 0.55, 0.55]}
    from repro.apps.jacobi2d import run_jacobi
    for fleet, mult in rates.items():
        res = {}
        for strat, aware, tag in ((None, False, "nolb"),
                                  ("greedy_refine", False, "refine_blind"),
                                  ("greedy_refine", True, "refine_rate")):
            out = run_jacobi(grid_size=768, n_pes=4, odf=4, iters=20,
                             kernel="lulesh", pe_rate_multipliers=mult,
                             lb_strategy=strat, lb_every=6, rate_aware=aware)
            tail = out.per_iter[-6:]
            us = float(np.mean([m["time_per_iter"] for m in tail])) * 1e6
            res[tag] = us
            imp = (1 - us / res["nolb"]) * 100 if "nolb" in res else 0.0
            row(f"fig3_lb_{fleet}_{tag}", us, f"improvement={imp:.1f}%")


# ------------------------------------------------------------- fig 5 / 6
def _interrupt_breakdown(store_kind: str, tag: str):
    from repro.configs import ARCHS, SHAPES
    from repro.launch.train import ElasticTrainer
    cfg = ARCHS["granite-8b"].reduced()
    shape = SHAPES["train_4k"].reduced()
    tr = ElasticTrainer(cfg, shape, n_devices=1, store_kind=store_kind)
    tr.train(2, log_every=0)
    ev_shrink = tr.runtime.rescale_to(1)   # simulated interruption rescale
    tr.train(1, log_every=0)
    ev_expand = tr.runtime.rescale_to(1)
    for ev, kind in ((ev_shrink, "shrink"), (ev_expand, "expand")):
        for stage, sec in ev.stages.items():
            row(f"{tag}_{kind}_{stage}", sec * 1e6,
                f"total={ev.total:.3f}s")


def fig5_interrupt_cpu():
    _interrupt_breakdown("memory", "fig5_cpu")


def fig6_interrupt_dev():
    _interrupt_breakdown("device", "fig6_dev")


# ------------------------------------------------------------------ fig 7
def fig7_modes():
    from benchmarks.measure import calibrated_cost_model
    from repro.core.cloud import CloudManager, Mode
    cost = calibrated_cost_model(state_bytes=16 * 64e6)
    for accel, hw in ((False, "cpu"), (True, "gpu")):
        cost_hw = cost.__class__(**{**cost.__dict__, "accelerator": accel})
        for mode in Mode:
            cm = CloudManager(n_instances=16, mode=mode, cost=cost_hw,
                              total_iters=5000, iter_seconds=0.2)
            cm.inject_interruption(t=100.0, count=1)
            rep = cm.run()
            total_overhead = rep.total_time - rep.ideal_time
            row(f"fig7_modes_{hw}_mode{mode.value}",
                total_overhead * 1e6,
                f"overhead_s={total_overhead:.1f};"
                f"rescales={len(rep.rescales)}")


# ------------------------------------------------------------------ fig 8
def fig8_endtoend():
    from benchmarks.measure import calibrated_cost_model
    from repro.core.cloud import CloudManager, Mode
    cost = calibrated_cost_model(state_bytes=16 * 64e6)
    for accel, hw, iters in ((False, "cpu", 5000), (True, "gpu", 30000)):
        cost_hw = cost.__class__(**{**cost.__dict__, "accelerator": accel})
        for n_int in (0, 1, 2, 4, 8):
            for mode in (Mode.B_REACTIVE, Mode.C_PROACTIVE):
                cm = CloudManager(n_instances=16, mode=mode, cost=cost_hw,
                                  total_iters=iters, iter_seconds=0.2)
                if n_int:
                    cm.inject_interruption(t=100.0, count=n_int)
                rep = cm.run()
                row(f"fig8_endtoend_{hw}_mode{mode.value}_int{n_int}",
                    rep.total_time * 1e6,
                    f"overhead={100*rep.overhead_frac:.2f}%")


# ------------------------------------------------------------------ kernels
def kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels.jacobi.ref import jacobi_step_ref
    from repro.models.layers import blockwise_attention
    from repro.models.mamba2 import ssd_intra_chunk_ref

    g = jax.random.normal(jax.random.PRNGKey(0), (1024, 1024))
    f = jax.jit(jacobi_step_ref)
    f(g).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        out = f(g)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / 20 * 1e6
    row("kernel_jacobi_ref_1024", us,
        f"GBps={1024*1024*4*5/(us/1e6)/1e9:.1f}")

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 1024, 8, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 1024, 2, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 1024, 2, 64), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: blockwise_attention(
        q, k, v, causal=True, block_q=256, block_kv=256))
    f(q, k, v).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        out = f(q, k, v)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    flops = 2 * 2 * 1024 * 1024 * 8 * 64 / 2  # causal half
    row("kernel_flash_ref_1k", us, f"GFLOPs={flops/(us/1e6)/1e9:.1f}")

    b, nc, l, h, p, n = 1, 8, 128, 8, 64, 64
    xs = jax.random.split(jax.random.PRNGKey(1), 5)
    xr = jax.random.normal(xs[0], (b, nc, l, h, p))
    dtr = jax.nn.softplus(jax.random.normal(xs[1], (b, nc, l, h)))
    dacs = jnp.cumsum(-jnp.abs(jax.random.normal(xs[2], (b, nc, l, h))) * .1,
                      axis=2)
    Br = jax.random.normal(xs[3], (b, nc, l, n))
    Cr = jax.random.normal(xs[4], (b, nc, l, n))
    f = jax.jit(ssd_intra_chunk_ref)
    jax.block_until_ready(f(xr, dtr, dacs, Br, Cr))
    t0 = time.perf_counter()
    for _ in range(5):
        out = f(xr, dtr, dacs, Br, Cr)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / 5 * 1e6
    row("kernel_ssd_ref_1k", us, f"chunk={l}")


# ------------------------------------------------------------------ cluster
def cluster_hetero(arrival: str = "batch", quick: bool = False):
    """Serving-cluster A/B (paper §III/§IV on the serving workload).

    A 2-fast/2-slow replica fleet serves the same request stream under
    round-robin and rate-aware routing; one fast replica receives a spot
    interruption mid-run and is drained (slots checkpointed + migrated).
    ``arrival`` selects the offered-load model: ``batch`` (closed-loop,
    everything at t=0), ``poisson:<rate>`` or ``trace:<file>``
    (open-loop, scheduled one arrival event at a time).  Rate-aware
    routing must win on p99 latency AND aggregate tokens/sec, and the
    drain must drop zero requests.
    """
    import jax
    from repro.cluster import (InstanceType, ROUTERS, ServingCluster)
    from repro.configs import get_config
    from repro.models import model_zoo as zoo
    from repro.serving.workload import make_arrivals, synthetic_requests

    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    fleet = [InstanceType("fast.2x", 2.0), InstanceType("fast.2x", 2.0),
             InstanceType("slow.1x", 0.7), InstanceType("slow.1x", 0.7)]
    n_requests, max_seq = (12, 32) if quick else (24, 48)

    results = {}
    for name, router_cls in ROUTERS.items():
        cl = ServingCluster(cfg, params, fleet, router=router_cls(),
                            dt=1.0, batch_size=2, max_seq=max_seq,
                            rebalance_lead=6.0, notice_deadline=4.0)
        reqs = synthetic_requests(n_requests, cfg.vocab_size, seed=0,
                                  prompt_len=(3, 9), max_new=(4, 12))
        cl.attach_arrivals(make_arrivals(arrival, reqs, seed=0))
        cl.inject_interruption(t=4.0, replica_rid=0)
        out = cl.run(max_time=10_000)
        results[name] = out
        # count loss only over requests actually offered (a short trace
        # file truncates the request list; that is not a drain drop)
        offered = [r for r in reqs if r.rid in cl.metrics.traces]
        lost = sum(r.max_new_tokens - len(r.out_tokens) for r in offered)
        tag = f"cluster_hetero_{name}"
        row(f"{tag}_p50", out["p50_latency"] * 1e6,
            f"virtual_s={out['p50_latency']:.1f};arrival={arrival}")
        row(f"{tag}_p99", out["p99_latency"] * 1e6,
            f"virtual_s={out['p99_latency']:.1f}")
        row(f"{tag}_throughput", 0.0,
            f"tok_per_s={out['tok_per_s']:.2f};"
            f"makespan_s={out['virtual_seconds']:.0f}")
        row(f"{tag}_drain", out["interruption_overhead_s"] * 1e6,
            f"dropped={out['dropped']};migrated={out['migrated_slots']};"
            f"tokens_lost={lost}")
        assert out["dropped"] == 0 and lost == 0, \
            f"{name}: drain dropped work"
    ra, rr = results["rate_aware"], results["round_robin"]
    wins = (ra["p99_latency"] < rr["p99_latency"]
            and ra["tok_per_s"] > rr["tok_per_s"])
    row("cluster_hetero_summary", 0.0,
        f"rate_aware_beats_round_robin={wins};"
        f"p99={ra['p99_latency']:.1f}vs{rr['p99_latency']:.1f};"
        f"tok_per_s={ra['tok_per_s']:.2f}vs{rr['tok_per_s']:.2f}")
    assert wins, "rate-aware routing did not beat round-robin"


# ------------------------------------------------------------------ SLOs
def cluster_slo(quick: bool = False):
    """SLO scheduling A/B (the elastic-scheduler deadline layer on top of
    §III rate-aware balancing).

    The same 2-fast/2-slow fleet serves an identical seeded Poisson mix
    of interactive (tight deadline) and batch (loose deadline,
    lazily-admitted) requests, with the same injected spot interruption:

    * FIFO      — ``RateAwareRouter``, FIFO admission, no rebalancer
                  (PR-1 behaviour);
    * SLO-aware — ``DeadlineAwareRouter`` (GreedyRefine + predicted-miss
                  repair), priority admission (batch held until backlog
                  headroom), and the recurring mid-stream migration pass.

    SLO-aware scheduling must strictly improve interactive-class deadline
    attainment AND interactive p99 latency, drop nothing, and — because
    greedy decode is placement/migration-independent — emit bit-identical
    per-request tokens to the FIFO run.
    """
    import jax
    from repro.cluster import (DeadlineAwareRouter, InstanceType,
                               RateAwareRouter, ServingCluster)
    from repro.configs import get_config
    from repro.models import model_zoo as zoo
    from repro.runtime import FaultTrace
    from repro.serving.workload import (PoissonArrivals, SLOClass,
                                        classed_requests)

    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    fleet = [InstanceType("fast.2x", 2.0), InstanceType("fast.2x", 2.0),
             InstanceType("slow.1x", 0.7), InstanceType("slow.1x", 0.7)]
    interactive = SLOClass("interactive", 0, deadline=12.0)
    batch = SLOClass("batch", 2, deadline=400.0, admit_lazily=True)
    n_requests, rate = (18, 2.5) if quick else (36, 2.0)

    def one_run(slo_aware: bool):
        trace = FaultTrace(rebalance_lead=6.0, notice_deadline=4.0)
        trace.inject(4.0, 0)
        kw = dict(dt=1.0, batch_size=2, max_seq=48, trace=trace)
        if slo_aware:
            cl = ServingCluster(cfg, params, fleet,
                                router=DeadlineAwareRouter(),
                                admission="priority",
                                batch_admit_headroom=24.0,
                                rebalance_interval=2.0, **kw)
        else:
            cl = ServingCluster(cfg, params, fleet,
                                router=RateAwareRouter(), **kw)
        reqs = classed_requests(n_requests, cfg.vocab_size,
                                interactive_frac=0.5, seed=0,
                                interactive=interactive, batch=batch)
        cl.attach_arrivals(PoissonArrivals(reqs, rate, seed=0))
        out = cl.run(max_time=10_000)
        return cl, reqs, out

    results = {}
    for tag, slo_aware in (("fifo", False), ("slo_aware", True)):
        cl, reqs, out = one_run(slo_aware)
        results[tag] = (reqs, out)
        row(f"cluster_slo_{tag}_interactive_p99",
            out["p99_latency_interactive"] * 1e6,
            f"attainment={out['attainment_interactive']:.3f};"
            f"virtual_s={out['p99_latency_interactive']:.1f}")
        row(f"cluster_slo_{tag}_batch",
            out["p99_latency_batch"] * 1e6,
            f"attainment={out['attainment_batch']:.3f}")
        row(f"cluster_slo_{tag}_fleet", 0.0,
            f"tok_per_s={out['tok_per_s']:.2f};dropped={out['dropped']};"
            f"migrated={out['migrated_slots']};"
            f"rebalance_migrations={out['rebalance_migrations']}")
        assert out["dropped"] == 0, f"{tag}: dropped requests"
        assert out["completed"] == n_requests, f"{tag}: incomplete run"

    (fifo_reqs, fifo), (slo_reqs, slo) = (results["fifo"],
                                          results["slo_aware"])
    for a, b in zip(fifo_reqs, slo_reqs):
        assert a.out_tokens == b.out_tokens, \
            f"req{a.rid}: SLO scheduling changed decoded tokens"
    att_f = fifo["attainment_interactive"]
    att_s = slo["attainment_interactive"]
    p99_f = fifo["p99_latency_interactive"]
    p99_s = slo["p99_latency_interactive"]
    wins = att_s > att_f and p99_s < p99_f
    row("cluster_slo_summary", 0.0,
        f"slo_beats_fifo={wins};"
        f"attainment={att_s:.3f}vs{att_f:.3f};"
        f"p99_interactive={p99_s:.1f}vs{p99_f:.1f};"
        f"identical_tokens=True;"
        f"migrations={slo['rebalance_migrations']}")
    assert wins, (
        f"SLO-aware did not strictly improve interactive attainment/p99: "
        f"{att_s:.3f} vs {att_f:.3f}, {p99_s:.1f} vs {p99_f:.1f}")
    assert slo["rebalance_migrations"] > 0, \
        "the mid-stream rebalancer never migrated a slot"


# ------------------------------------------------------------- preemption
def cluster_preempt(quick: bool = False):
    """SLO-aware preemption A/B (migratable WorkUnits as the paper's one
    mechanism, preemption as a ControlPlane *policy* on top).

    A fleet saturated with long batch-class decodes receives a seeded
    interactive surge.  Both runs share the deadline-aware router and an
    SLO-pressure autoscaler; they differ ONLY in the preemption policy:

    * off — the base (hold-only) policy: interactive work waits for a
      batch slot to free naturally; decided deadline misses push the
      autoscaler into buying extra replicas (dollars for attainment).
    * on  — ``SLOPreemption``: batch slots are *paused* through the same
      pack/unpack mechanism as a drain (slot freed, snapshot retained),
      interactive work admits immediately, and the paused streams resume
      bit-identically once the surge clears.

    Preemption must strictly improve interactive attainment at
    equal-or-lower fleet dollar cost, with bit-identical per-request
    token streams and zero dropped/incomplete requests.
    """
    import jax
    from repro.cluster import (DeadlineAwareRouter, InstanceType,
                               ServingCluster, SLOPreemption)
    from repro.configs import get_config
    from repro.models import model_zoo as zoo
    from repro.serving.engine import Request
    from repro.serving.workload import SLOClass

    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    n_rep = 2 if quick else 3
    fleet = [InstanceType("std.1x", 1.0, cost_per_hour=1.0)
             for _ in range(n_rep)]
    interactive = SLOClass("interactive", 0, deadline=22.0)
    batch = SLOClass("batch", 2, deadline=2000.0, admit_lazily=True)
    n_batch = 2 * n_rep + 2              # saturate every slot + a queue
    n_int = 2 * n_rep                    # one surge wave per slot-pair
    surge_t = 8.0

    def requests():
        rng = np.random.default_rng(7)
        reqs = []
        for rid in range(n_batch):       # long batch decodes at t=0
            reqs.append((0.0, Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(6, 10)),
                                    dtype=np.int32),
                max_new_tokens=int(rng.integers(30, 38)), slo=batch)))
        for rid in range(n_batch, n_batch + n_int):   # the surge
            reqs.append((surge_t, Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(3, 6)),
                                    dtype=np.int32),
                max_new_tokens=int(rng.integers(4, 7)),
                slo=interactive)))
        return reqs

    def one_run(preempt: bool):
        cl = ServingCluster(
            cfg, params, fleet, router=DeadlineAwareRouter(),
            dt=1.0, batch_size=2, max_seq=48, decode_block=2,
            preemption=(SLOPreemption(max_preempts_per_pass=2 * n_rep)
                        if preempt else None),
            autoscaler_kw=dict(scale_up_backlog=100_000.0,
                               scale_up_patience=2.0,
                               replacement_latency=12.0,
                               max_replicas=n_rep + 2,
                               slo_scale_up=True))
        reqs = requests()
        for at, req in reqs:
            cl.submit(req, at=at)
        out = cl.run(max_time=10_000)
        return cl, [r for _, r in reqs], out

    results = {}
    for tag, preempt in (("off", False), ("on", True)):
        cl, reqs, out = one_run(preempt)
        results[tag] = (reqs, out)
        row(f"cluster_preempt_{tag}_interactive", 0.0,
            f"attainment={out['attainment_interactive']:.3f};"
            f"p99={out['p99_latency_interactive']:.1f}s")
        row(f"cluster_preempt_{tag}_fleet", 0.0,
            f"dollar_cost={out['fleet_dollar_cost']:.4f};"
            f"replicas={len(cl.replicas)};"
            f"preemptions={out['preemptions']};"
            f"resumes={out['resumes']}")
        assert out["dropped"] == 0, f"{tag}: dropped requests"
        assert out["completed"] == n_batch + n_int, f"{tag}: incomplete"

    (off_reqs, off), (on_reqs, on) = results["off"], results["on"]
    for a, b in zip(off_reqs, on_reqs):
        assert a.out_tokens == b.out_tokens, \
            f"req{a.rid}: preemption changed decoded tokens"
    att_off, att_on = (off["attainment_interactive"],
                       on["attainment_interactive"])
    cost_off, cost_on = off["fleet_dollar_cost"], on["fleet_dollar_cost"]
    wins = att_on > att_off and cost_on <= cost_off + 1e-9
    row("cluster_preempt_summary", 0.0,
        f"preempt_beats_scaleup={wins};"
        f"attainment={att_on:.3f}vs{att_off:.3f};"
        f"dollar_cost={cost_on:.4f}vs{cost_off:.4f};"
        f"preemptions={on['preemptions']};resumes={on['resumes']};"
        f"identical_tokens=True")
    assert on["preemptions"] > 0 and on["resumes"] == on["preemptions"], \
        "SLO preemption never paused (or never resumed) a batch slot"
    assert off["preemptions"] == 0, "baseline run must not preempt"
    assert wins, (
        f"preemption did not strictly improve interactive attainment at "
        f"equal-or-lower cost: attainment {att_on:.3f} vs {att_off:.3f}, "
        f"dollars {cost_on:.4f} vs {cost_off:.4f}")


# ----------------------------------------------------- vertical elasticity
def cluster_vertical(quick: bool = False):
    """Vertical elasticity A/B: in-place resize + QoS vs horizontal-only.

    A small fleet saturated with batch-class decodes takes an
    interactive surge, then a quiet tail.  Both arms see the same
    requests and the same *peak* slot capacity; they differ only in how
    capacity appears:

    * horizontal — a fixed batch width per replica; the autoscaler buys
      up to two extra replicas on sustained backlog and pays a
      ``replacement_latency`` before they serve (then bills them until
      idle scale-down).
    * vertical — the fleet is pinned, and a ``FixedThresholdVertical``
      recommender grows each replica's lanes in place through the
      canonical pack/unpack path (no drain, surviving slots untouched)
      the moment backlog per lane crosses the threshold — and shrinks
      back in the quiet tail, with ``QoSPolicy`` holding BestEffort
      arrivals out of the Guaranteed reservation and ordering any
      shrink evictions BestEffort-first.

    Vertical must reach at-least-equal interactive attainment at
    strictly lower fleet dollar cost, with zero lost WorkUnits and
    bit-identical per-request streams across the arms.
    """
    import jax
    from repro.cluster import DeadlineAwareRouter, InstanceType, \
        ServingCluster
    from repro.configs import get_config
    from repro.models import model_zoo as zoo
    from repro.serving.engine import Request
    from repro.serving.workload import SLOClass
    from repro.vertical import FixedThresholdVertical, QoSPolicy

    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    n_rep = 2
    base_batch, max_batch = 2, 4
    fleet = [InstanceType("std.1x", 1.0, spot=False, cost_per_hour=1.0)
             for _ in range(n_rep)]
    interactive = SLOClass("interactive", 0, deadline=26.0)
    batch = SLOClass("batch", 2, deadline=4000.0, admit_lazily=True)
    n_batch = 6 if quick else 8
    n_int = 4 if quick else 6
    surge_t = 6.0
    decode_span = (18, 24) if quick else (28, 36)

    def requests():
        rng = np.random.default_rng(11)
        reqs = []
        for rid in range(n_batch):       # the batch floor at t=0
            reqs.append((0.0, Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(6, 10)),
                                    dtype=np.int32),
                max_new_tokens=int(rng.integers(*decode_span)),
                slo=batch)))
        for rid in range(n_batch, n_batch + n_int):   # the surge
            reqs.append((surge_t, Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(3, 6)),
                                    dtype=np.int32),
                max_new_tokens=int(rng.integers(4, 7)),
                slo=interactive)))
        return reqs

    def one_run(vertical: bool):
        if vertical:
            qos = QoSPolicy()
            kw = dict(
                vertical=FixedThresholdVertical(
                    min_batch=base_batch, max_batch=max_batch, step=2,
                    grow_backlog=12.0, shrink_backlog=3.0,
                    cooldown=4.0, qos=qos),
                qos=qos,
                # the fleet is pinned: capacity moves only vertically
                autoscaler_kw=dict(scale_up_backlog=1e9,
                                   slo_scale_up=False,
                                   max_replicas=n_rep))
        else:
            # equal peak capacity: up to 2 extra replicas at base_batch
            # lanes each == n_rep replicas at max_batch lanes
            kw = dict(
                autoscaler_kw=dict(scale_up_backlog=12.0 * base_batch,
                                   scale_up_patience=2.0,
                                   replacement_latency=12.0,
                                   max_replicas=n_rep + 2,
                                   scale_down_idle=20.0,
                                   slo_scale_up=True))
        cl = ServingCluster(cfg, params, fleet,
                            router=DeadlineAwareRouter(), dt=1.0,
                            batch_size=base_batch, max_seq=48,
                            decode_block=2, admission="priority", **kw)
        reqs = requests()
        for at, req in reqs:
            cl.submit(req, at=at)
        out = cl.run(max_time=10_000)
        return cl, [r for _, r in reqs], out

    results = {}
    for tag, vertical in (("horizontal", False), ("vertical", True)):
        cl, reqs, out = one_run(vertical)
        results[tag] = (reqs, out)
        row(f"cluster_vertical_{tag}_interactive", 0.0,
            f"attainment={out['attainment_interactive']:.3f};"
            f"p99={out['p99_latency_interactive']:.1f}s")
        row(f"cluster_vertical_{tag}_fleet", 0.0,
            f"dollar_cost={out['fleet_dollar_cost']:.4f};"
            f"replicas={len(cl.replicas)};"
            f"grows={out['vertical_grows']};"
            f"shrinks={out['vertical_shrinks']};"
            f"evictions={out['vertical_evictions']};"
            f"qos_guaranteed_slot_s={out['qos_guaranteed_slot_s']:.1f};"
            f"qos_best_effort_slot_s={out['qos_best_effort_slot_s']:.1f}")
        assert out["dropped"] == 0, f"{tag}: dropped requests"
        assert out["completed"] == n_batch + n_int, f"{tag}: incomplete"

    (h_reqs, h), (v_reqs, v) = results["horizontal"], results["vertical"]
    for a, b in zip(h_reqs, v_reqs):
        assert a.out_tokens == b.out_tokens, \
            f"req{a.rid}: vertical resize changed decoded tokens"
    att_h, att_v = (h["attainment_interactive"],
                    v["attainment_interactive"])
    cost_h, cost_v = h["fleet_dollar_cost"], v["fleet_dollar_cost"]
    wins = att_v >= att_h - 1e-9 and cost_v < cost_h - 1e-9
    row("cluster_vertical_summary", 0.0,
        f"vertical_beats_horizontal={wins};"
        f"attainment={att_v:.3f}vs{att_h:.3f};"
        f"dollar_cost={cost_v:.4f}vs{cost_h:.4f};"
        f"grows={v['vertical_grows']};shrinks={v['vertical_shrinks']};"
        f"evictions={v['vertical_evictions']};lost=0;"
        f"identical_tokens=True")
    assert v["vertical_grows"] > 0, "vertical arm never grew a replica"
    assert v["vertical_shrinks"] > 0, \
        "vertical arm never shrank back in the quiet tail"
    assert h["vertical_grows"] == h["vertical_shrinks"] == 0, \
        "horizontal arm must not resize"
    assert wins, (
        f"vertical+QoS did not match attainment at strictly lower cost: "
        f"attainment {att_v:.3f} vs {att_h:.3f}, "
        f"dollars {cost_v:.4f} vs {cost_h:.4f}")


# ------------------------------------------------------------ spot market
def cluster_spot_market(quick: bool = False):
    """Spot-market shopping A/B (priced markets + interruption models).

    One fleet of identical instances is bought on a two-market exchange:
    *volatile* opens at a quarter of the on-demand rate but carries a
    scheduled mid-run price spike with price-coupled interruption
    intensity; *steady* costs more and almost never interrupts.  Both
    runs serve the same seeded Poisson interactive/batch mix with the
    ``different_market`` fallback on spot notices; they differ ONLY in
    the exchange's shopping mode:

    * naive    — buys the cheapest spot rate *right now* (volatile),
                 then pays spike prices and eats the interruption churn;
    * adjusted — prices each market as mean rate + predicted
                 interruption rate x interruption dollars over a
                 lookahead window, sees the spike coming, and buys
                 steady up front.

    Adjusted must deliver strictly higher savings vs all-on-demand at
    equal-or-better interactive attainment, drop nothing, and emit
    bit-identical per-request tokens.
    """
    import jax
    from repro.cluster import (DeadlineAwareRouter, InstanceType,
                               ServingCluster)
    from repro.configs import get_config
    from repro.market import MarketCatalog, SpotExchange, SpotMarket
    from repro.models import model_zoo as zoo
    from repro.serving.workload import (PoissonArrivals, SLOClass,
                                        classed_requests)

    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    n_rep, n_requests, rate = (2, 14, 2.5) if quick else (3, 30, 2.0)
    fleet = [InstanceType("std.1x", 1.0, cost_per_hour=1.0)
             for _ in range(n_rep)]
    interactive = SLOClass("interactive", 0, deadline=15.0)
    batch = SLOClass("batch", 2, deadline=500.0, admit_lazily=True)

    def exchange(mode):
        cat = MarketCatalog()
        cat.add_market(SpotMarket(
            "volatile", base_rate=0.25, volatility=0.06,
            spikes=((10.0, 400.0, 5.0),), interruptions_per_hour=4.0,
            price_power=3.0, seed=1))
        cat.add_market(SpotMarket(
            "steady", base_rate=0.45, volatility=0.02,
            interruptions_per_hour=0.05, seed=2))
        for it in set(fleet):
            cat.list_instance(it, markets=("volatile", "steady"))
        return SpotExchange(cat, seed=0, mode=mode, sample_until=500.0)

    def one_run(mode):
        cl = ServingCluster(
            cfg, params, fleet, router=DeadlineAwareRouter(),
            dt=1.0, batch_size=2, max_seq=48,
            admission="priority", batch_admit_headroom=24.0,
            rebalance_lead=6.0, notice_deadline=4.0,
            market=exchange(mode), fallback="different_market",
            autoscaler_kw=dict(replacement_latency=10.0,
                               scale_up_backlog=100_000.0,
                               scale_down_idle=10_000.0))
        reqs = classed_requests(n_requests, cfg.vocab_size,
                                interactive_frac=0.5, seed=0,
                                interactive=interactive, batch=batch)
        cl.attach_arrivals(PoissonArrivals(reqs, rate, seed=0))
        out = cl.run(max_time=10_000)
        return cl, reqs, out

    results = {}
    for mode in ("naive", "adjusted"):
        cl, reqs, out = one_run(mode)
        results[mode] = (reqs, out)
        row(f"cluster_spot_market_{mode}_cost", 0.0,
            f"market_dollars={out['market_dollar_cost']:.4f};"
            f"on_demand_dollars={out['on_demand_dollar_cost']:.4f};"
            f"savings={out['savings_pct']:.1f}%;"
            f"interruptions={out['spot_interruptions']}")
        row(f"cluster_spot_market_{mode}_slo", 0.0,
            f"attainment={out['attainment_interactive']:.3f};"
            f"p99_interactive={out['p99_latency_interactive']:.1f}s;"
            f"dropped={out['dropped']}")
        # the by-market/by-strategy ledger breakdown must surface in the
        # run summary (the README's market-report contract)
        for m in ("volatile", "steady"):
            assert f"market_{m}_purchases" in out, f"no {m} breakdown"
        assert "strategy_initial_purchases" in out, "no strategy breakdown"
        assert out["dropped"] == 0, f"{mode}: dropped requests"
        assert out["completed"] == n_requests, f"{mode}: incomplete run"

    (nai_reqs, nai), (adj_reqs, adj) = (results["naive"],
                                        results["adjusted"])
    for a, b in zip(nai_reqs, adj_reqs):
        assert a.out_tokens == b.out_tokens, \
            f"req{a.rid}: market shopping changed decoded tokens"
    sav_n, sav_a = nai["savings_pct"], adj["savings_pct"]
    att_n, att_a = (nai["attainment_interactive"],
                    adj["attainment_interactive"])
    wins = sav_a > sav_n and att_a >= att_n
    row("cluster_spot_market_summary", 0.0,
        f"adjusted_beats_naive={wins};"
        f"savings={sav_a:.1f}%vs{sav_n:.1f}%;"
        f"attainment={att_a:.3f}vs{att_n:.3f};"
        f"interruptions={adj['spot_interruptions']}vs"
        f"{nai['spot_interruptions']};identical_tokens=True")
    assert nai["spot_interruptions"] > 0, \
        "the naive shopper never got interrupted (no churn to avoid)"
    assert wins, (
        f"interruption-adjusted shopping did not beat naive-cheapest: "
        f"savings {sav_a:.1f}% vs {sav_n:.1f}%, attainment "
        f"{att_a:.3f} vs {att_n:.3f}")


def cluster_chaos(quick: bool = False):
    """Chaos fault model + checkpoint-based recovery A/B.

    One fixed chaos soup hits a 2-replica fleet mid-stream: a zero-notice
    ``hard_kill`` on the busiest replica, a 3x ``slowdown`` window on the
    survivor, a fabric-wide ``network_contention`` window, and a
    transient ``endpoint_failure``.  Three runs over the identical seeded
    request set:

    * fault_free    — the reference streams (per-request tokens);
    * recovery_on   — periodic WorkUnit checkpoints + heartbeat failure
                      detection + straggler quarantine: the kill is
                      discovered by silence, checkpointed slots restore
                      and re-decode their lost tail deterministically,
                      un-checkpointed requests readmit from the prompt;
    * recovery_off  — same soup, no checkpoints/detector: the killed
                      replica's work is demonstrably lost.

    Recovery must complete every request with final streams bit-identical
    to the fault-free reference (greedy decode is placement-independent)
    at strictly higher goodput than the no-recovery run, with bounded
    replayed-token overhead.
    """
    import jax
    from repro.cluster import (CheckpointPolicy, FailureDetector,
                               InstanceType, ServingCluster,
                               StragglerPolicy)
    from repro.configs import get_config
    from repro.models import model_zoo as zoo
    from repro.runtime import FaultTrace
    from repro.serving.workload import synthetic_requests

    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    n_requests = 12 if quick else 20
    fleet = [InstanceType("std.1x", 1.0, cost_per_hour=1.0)
             for _ in range(2)]

    def chaos_trace():
        trace = FaultTrace()
        # mid-step-cadence kill: tokens decoded since the last
        # checkpoint are genuinely lost and must re-decode
        trace.inject_hard_kill(9.5, 0)
        trace.inject_slowdown(4.0, 1, factor=3.0, duration=10.0)
        trace.inject_contention(5.0, factor=2.0, duration=8.0)
        trace.inject_endpoint_failure(2.0, 0, count=1)
        return trace

    def one_run(mode):
        kw = {}
        if mode == "recovery_on":
            # an interval that does NOT divide the kill time, so the
            # last checkpoint predates the kill and a real lost tail
            # gets re-decoded (the replayed-token overhead the guard
            # bounds)
            kw = dict(checkpoint=CheckpointPolicy(interval=3.0),
                      health=FailureDetector(heartbeat_interval=1.0,
                                             check_interval=1.0,
                                             suspect_after=2.5,
                                             confirm_after=5.0),
                      straggler=StragglerPolicy())
        trace = FaultTrace() if mode == "fault_free" else chaos_trace()
        cl = ServingCluster(cfg, params, fleet, trace=trace, dt=1.0,
                            batch_size=2, max_seq=32, **kw)
        reqs = synthetic_requests(n_requests, cfg.vocab_size, seed=0,
                                  prompt_len=(3, 8))
        for i, r in enumerate(reqs):
            cl.submit(r, at=0.3 * i)
        out = cl.run(max_time=10_000)
        useful = sum(len(r.out_tokens) for r in reqs if r.done)
        goodput = useful / max(out["virtual_seconds"], 1e-9)
        return reqs, out, goodput

    results = {}
    for mode in ("fault_free", "recovery_on", "recovery_off"):
        reqs, out, goodput = one_run(mode)
        results[mode] = (reqs, out, goodput)
        row(f"cluster_chaos_{mode}", 0.0,
            f"completed={out['completed']}/{n_requests};"
            f"lost={out['requests_lost']};goodput={goodput:.3f}tok/s;"
            f"hard_kills={out['hard_kills']};"
            f"checkpoints={out['checkpoints']};"
            f"recovered={out['requests_recovered']};"
            f"replayed={out['replayed_tokens']}")

    ref_reqs, _, _ = results["fault_free"]
    on_reqs, on, goodput_on = results["recovery_on"]
    off_reqs, off, goodput_off = results["recovery_off"]

    identical = all(a.out_tokens == b.out_tokens
                    for a, b in zip(ref_reqs, on_reqs))
    useful_on = sum(len(r.out_tokens) for r in on_reqs if r.done)
    replay_frac = on["replayed_tokens"] / max(useful_on, 1)
    row("cluster_chaos_summary", 0.0,
        f"goodput={goodput_on:.3f}vs{goodput_off:.3f}tok/s;"
        f"lost={on['requests_lost']}vs{off['requests_lost']};"
        f"bit_identical={identical};"
        f"recovered={on['requests_recovered']};"
        f"replay_frac={replay_frac:.3f};"
        f"hard_kills={on['hard_kills']};"
        f"recovery_latency={on['recovery_latency_s']:.1f}s")
    assert on["hard_kills"] >= 1, "the chaos soup never killed anyone"
    assert on["dropped"] == 0 and on["requests_lost"] == 0, \
        "recovery lost requests despite checkpoints + detection"
    assert on["completed"] == n_requests, "recovery run incomplete"
    assert identical, "recovered streams diverged from fault-free"
    assert off["requests_lost"] > 0, \
        "the no-recovery run lost nothing (the kill never bit)"
    assert goodput_on > goodput_off, (
        f"recovery goodput {goodput_on:.3f} tok/s did not beat "
        f"no-recovery {goodput_off:.3f} tok/s")


# ------------------------------------------------------------------ engine
def engine_throughput(quick: bool = False):
    """ServingEngine hot-path A/B: chunked bulk prefill + sync-free
    batched decode vs the streamed per-token baseline.

    Measures (a) prefill tokens/sec for a 64-token prompt — streamed
    feeds one prompt token per full-batch decode dispatch, chunked runs
    one ``make_prefill`` bucket and scatters the cache columns; (b)
    batched decode tokens/sec at decode blocks of 1 and 8 (a block-8
    window is one dispatch and zero device->host transfers).  Generated
    tokens must be bit-identical across modes, and chunked prefill must
    be >= 3x streamed.
    """
    import jax
    from repro.configs import get_config
    from repro.models import model_zoo as zoo
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.workload import prefill_heavy_requests

    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    max_seq, prompt_len = 96, 64
    max_new = 4 if quick else 16
    reps = 2 if quick else 4

    def engine(mode):
        return ServingEngine(cfg, params, batch_size=4, max_seq=max_seq,
                             prefill_mode=mode)

    # warm the compile caches (shared module-level, once per mode): both
    # prefill buckets (64-token measured prompt AND the 8-token decode
    # workload -> bucket 16) plus the block-1 and block-8 decode loops,
    # so no timed region below pays a jit compile
    for mode in ("streamed", "chunked"):
        e = engine(mode)
        for r in prefill_heavy_requests(1, cfg.vocab_size,
                                        prompt_len=prompt_len,
                                        max_new=max_new, seed=99):
            e.submit(r)
        for r in prefill_heavy_requests(1, cfg.vocab_size, prompt_len=8,
                                        max_new=max_new, seed=98,
                                        start_rid=1):
            e.submit(r)
        while e.n_active or e.n_queued:
            e.step()
        e.step_many(8)

    results = {}
    for mode in ("streamed", "chunked"):
        tps = []
        tokens = None
        for rep in range(reps):
            e = engine(mode)
            req, = prefill_heavy_requests(1, cfg.vocab_size,
                                          prompt_len=prompt_len,
                                          max_new=max_new, seed=rep)
            e.submit(req)
            t0 = time.perf_counter()
            while e.fed_tokens(0) < prompt_len - 1:
                e.step()        # streamed: one dispatch per prompt token
            jax.block_until_ready(e.sample.fed)
            tps.append((prompt_len - 1) / (time.perf_counter() - t0))
            e.run_until_idle()
            if rep == 0:
                tokens = list(req.out_tokens)
        results[mode] = {"prefill_tps": max(tps), "tokens": tokens}
        row(f"engine_prefill_{mode}", 1e6 / max(tps),
            f"prefill_tok_per_s={max(tps):.0f};prompt={prompt_len}")

    assert results["streamed"]["tokens"] == results["chunked"]["tokens"], \
        "chunked prefill diverged from the streamed baseline"
    speedup = (results["chunked"]["prefill_tps"]
               / results["streamed"]["prefill_tps"])
    row("engine_prefill_speedup", 0.0,
        f"chunked_over_streamed={speedup:.1f}x;identical_tokens=True")
    assert speedup >= 3.0, \
        f"chunked prefill only {speedup:.1f}x streamed (need >= 3x)"

    # batched decode: block-1 (one dispatch + bookkeeping per step) vs
    # block-8 (one dispatch per 8 steps, zero transfers in the window)
    n_req = 4 if quick else 8
    decode_new = 24 if quick else 48
    for block in (1, 8):
        e = engine("chunked")
        for r in prefill_heavy_requests(n_req, cfg.vocab_size,
                                        prompt_len=8, max_new=decode_new,
                                        seed=5):
            e.submit(r)
        t0 = time.perf_counter()
        emitted = 0
        while e.n_active or e.n_queued:
            emitted += e.step_many(block)["emitted"]
        jax.block_until_ready(e.sample.fed)
        dt = time.perf_counter() - t0
        row(f"engine_decode_block{block}", 1e6 * dt / max(emitted, 1),
            f"decode_tok_per_s={emitted/dt:.0f};"
            f"host_syncs={e.host_syncs};tokens={emitted}")


# ------------------------------------------------------------------ churn
def engine_churn(quick: bool = False):
    """Paged-cache A/B under slot churn (the PR-7 tentpole claim).

    The same Poisson-paced stream of short mixed-length requests is
    served twice at IDENTICAL kv-cache memory: a dense engine with
    ``dense_lanes`` slots (each slot owns a full ``max_seq`` cache
    column) vs a paged engine with twice the lanes sharing a block pool
    sized to exactly the dense engine's kv footprint
    (``dense_lanes * max_seq / block_size`` blocks).  Under churn the
    dense engine queues on lanes while the paged engine keeps more
    requests in flight on the same memory, so it must win decode
    tokens/sec; greedy decode is batch-composition independent, so the
    per-request token streams must stay bit-identical.  Each mode is
    timed best-of-``reps`` (identical work every rep — the min is the
    least-perturbed sample of the same computation, which is what a
    shared CI box needs).  A separate probe asserts the paged steady
    state performs zero device->host fetches mid-generation (continuous
    batching does not break the sync-free decode window).
    """
    import jax
    from repro.configs import get_config
    from repro.models import model_zoo as zoo
    from repro.serving.engine import ServingEngine
    from repro.serving.workload import synthetic_requests

    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    max_seq, block, dense_lanes, paged_lanes = 64, 8, 4, 8
    pool = dense_lanes * (max_seq // block)   # == dense kv memory
    n_req = 16 if quick else 40
    rng = np.random.default_rng(11)
    arrival_steps = np.cumsum(rng.exponential(1.2, n_req))

    def engine(mode):
        kw = dict(batch_size=dense_lanes) if mode == "dense" else dict(
            batch_size=paged_lanes, cache_mode="paged", block_size=block,
            kv_pool_blocks=pool)
        return ServingEngine(cfg, params, max_seq=max_seq,
                             prefill_buckets=(16,), **kw)

    def requests(seed):
        return synthetic_requests(n_req, cfg.vocab_size, seed=seed,
                                  prompt_len=(3, 15), max_new=(8, 24))

    def drive(mode, seed):
        e = engine(mode)
        reqs = requests(seed)
        i, steps = 0, 0.0
        t0 = time.perf_counter()
        while i < n_req or e.n_active or e.n_queued:
            while i < n_req and arrival_steps[i] <= steps:
                e.submit(reqs[i])
                i += 1
            e.step_many(4)
            steps += 4          # virtual clock: 4 decode steps per batch
        jax.block_until_ready(e.sample.fed)
        dt = time.perf_counter() - t0
        emitted = sum(len(r.out_tokens) for r in reqs)
        assert all(r.done for r in reqs)
        return e, {r.rid: list(r.out_tokens) for r in reqs}, emitted / dt

    for mode in ("dense", "paged"):    # warm the shared compile caches
        drive(mode, seed=99)

    reps = 2 if quick else 3
    results = {}
    for mode in ("dense", "paged"):
        best = None
        for _ in range(reps):
            e, streams, tps = drive(mode, seed=11)
            if best is None or tps > best[2]:
                best = (e, streams, tps)
        e, streams, tps = best
        occ = e.occupancy()
        results[mode] = (streams, tps, occ)
        row(f"engine_churn_{mode}", 1e6 / tps,
            f"decode_tok_per_s={tps:.0f};requests={n_req};"
            f"peak_slots={occ['max_concurrent_slots']};"
            f"peak_blocks={occ['peak_blocks_in_use']};"
            f"host_syncs={e.host_syncs}")

    # sync-free steady state: mid-generation paged decode windows must
    # perform zero device->host fetches (admission/poll cost nothing
    # while nobody completes)
    probe = engine("paged")
    for r in synthetic_requests(2, cfg.vocab_size, seed=3,
                                prompt_len=(4, 8), max_new=40):
        probe.submit(r)
    probe.step_many(4)                       # admission + first window
    syncs0 = probe.host_syncs
    for _ in range(5):
        probe.step_many(4)                   # nobody completes here
    steady_syncs = probe.host_syncs - syncs0
    probe.run_until_idle()

    (dense_streams, dense_tps, _) = results["dense"]
    (paged_streams, paged_tps, paged_occ) = results["paged"]
    identical = dense_streams == paged_streams
    speedup = paged_tps / dense_tps
    row("engine_churn_summary", 0.0,
        f"churn_speedup={speedup:.2f}x;bit_identical={identical};"
        f"paged_peak_slots={paged_occ['max_concurrent_slots']};"
        f"dense_lanes={dense_lanes};pool_blocks={pool};"
        f"steady_syncs={steady_syncs}")
    assert identical, "paged cache changed decoded tokens under churn"
    assert steady_syncs == 0, \
        f"paged steady-state decode performed {steady_syncs} host syncs"
    assert paged_occ["max_concurrent_slots"] > dense_lanes, (
        f"paged never exceeded the dense slot ceiling "
        f"({paged_occ['max_concurrent_slots']} <= {dense_lanes}) at "
        f"equal cache memory")
    assert speedup > 1.0, (
        f"paged decode only {speedup:.2f}x dense under churn "
        f"(must be strictly faster at equal cache memory)")


# ------------------------------------------------------------------ roofline
def cluster_matrix(quick: bool = False):
    """Million-request scenario matrix (ISSUE 9, arXiv:2410.10655 /
    arXiv:2510.15147 methodology).

    Behaviour shapes x {rate_aware, slo_aware} x {preemption off, on} x
    {uniform, hetero} fleets on the token-accounting ``SimEngine`` —
    40 cells of behaviour-shaped load through the REAL control plane
    (router, preemptor, autoscaler, metrics), plus one diurnal
    million-request mega-cell exercising the bounded-memory path
    (streaming metrics, digest-only journal, place_cap routing).
    Emits one consolidated BENCH_matrix.json the guard holds floors on:
    per-cell attainment, a global sim_events_per_sec, and the section
    wall clock.
    """
    from repro.cluster.cluster import ServingCluster
    from repro.cluster.control import SLOPreemption
    from repro.cluster.replica import InstanceType
    from repro.cluster.router import DeadlineAwareRouter, RateAwareRouter
    from repro.serving.shapes import make_shape

    n_cell = 60 if quick else 400
    n_mega = 20_000 if quick else 1_000_000

    # capacity model (replica.step_once): prefill chunk tokens are
    # serialized per replica at `prefill_discount/speed` virtual-seconds
    # each, while decode steps amortize across the batch lanes — so one
    # request costs (0.35*P_mean + out_mean/batch)/speed replica-seconds.
    # Workload mix (ShapedArrivals): 30% interactive (P~5.5, out~5),
    # 70% batch (P~10, out~14).
    p_mean, out_mean = 8.65, 11.3

    def fleet_rate(fleet, batch, util):
        per_req_speed_s = 0.35 * p_mean + out_mean / batch
        return util * sum(it.speed for it in fleet) / per_req_speed_s

    shapes = ["pulse_spikes", "sawtooth", "staircase", "epochs",
              "staged_plateau"]
    fleets = {
        "uniform": [InstanceType("std.1x", 4.0, spot=False)
                    for _ in range(4)],
        "hetero": ([InstanceType("fast.2x", 8.0, spot=False,
                                 cost_per_hour=2.0) for _ in range(2)]
                   + [InstanceType("slow.1x", 4.0, spot=False)
                      for _ in range(2)]),
    }
    routers = {"rate_aware": RateAwareRouter,
               "slo_aware": DeadlineAwareRouter}
    total_events, total_wall, n_cells = 0, 0.0, 0

    for fleet_name, mk_fleet in fleets.items():
        # offered mean rate = 70% of capacity, so every shape's peak
        # (1.5-3x mean) transiently overloads and its trough underloads
        # the same fleet
        rate = fleet_rate(mk_fleet, 8, 0.7)
        for shape_name in shapes:
            for router_name, router_cls in routers.items():
                for pre in (False, True):
                    cl = ServingCluster(
                        None, None, list(mk_fleet), engine="sim",
                        router=router_cls(), batch_size=8, max_seq=64,
                        decode_block=4, seed=0,
                        admission="priority" if pre else "fifo",
                        preemption=SLOPreemption() if pre else None)
                    cl.attach_arrivals(make_shape(
                        shape_name, n_cell, rate=rate, period=60.0,
                        seed=7))
                    t0 = time.perf_counter()
                    s = cl.run(max_time=200_000.0)
                    wall = time.perf_counter() - t0
                    total_events += cl.loop.dispatched
                    total_wall += wall
                    n_cells += 1
                    att = s.get("attainment_interactive", 1.0)
                    row(f"matrix_{shape_name}_{router_name}_"
                        f"{'pre' if pre else 'nopre'}_{fleet_name}",
                        wall * 1e6 / max(s["completed"], 1),
                        f"attainment={att:.3f};"
                        f"p99={s['p99_latency']:.2f};"
                        f"tok_per_s={s['tok_per_s']:.2f};"
                        f"dollar={s['fleet_dollar_cost']:.4f};"
                        f"completed={s['completed']}")

    # ---- the 10^6-request diurnal mega-cell: bounded-memory path ----
    mega_fleet = [InstanceType("std.2x", 8.0, spot=False)
                  for _ in range(8)]
    rate = fleet_rate(mega_fleet, 64, 0.6)  # peak 1.6x -> ~0.96 capacity
    day = n_mega / rate                     # the trace spans ~one "day"
    cl = ServingCluster(
        None, None, mega_fleet, engine="sim",
        router=RateAwareRouter(place_cap=128),
        batch_size=64, max_seq=64, decode_block=8, seed=0,
        journal=False, retain_traces=False, timeline_cap=10_000,
        dispatch_coalesce=0.25)
    cl.attach_arrivals(make_shape("diurnal", n_mega, rate=rate,
                                  period=day, seed=11))
    t0 = time.perf_counter()
    s = cl.run(max_time=day * 20.0)
    wall = time.perf_counter() - t0
    total_events += cl.loop.dispatched
    total_wall += wall
    n_cells += 1
    assert s["completed"] == n_mega, \
        f"mega cell dropped work: {s['completed']}/{n_mega}"
    att = s.get("attainment_interactive", 1.0)
    row("matrix_diurnal_mega", wall * 1e6 / max(s["completed"], 1),
        f"attainment={att:.3f};p99={s['p99_latency']:.2f};"
        f"tok_per_s={s['tok_per_s']:.2f};"
        f"dollar={s['fleet_dollar_cost']:.4f};"
        f"completed={s['completed']};"
        f"events={cl.loop.dispatched};"
        f"cell_events_per_sec={cl.loop.dispatched / max(wall, 1e-9):.0f}")

    row("matrix_total", total_wall * 1e6 / max(total_events, 1),
        f"sim_events_per_sec={total_events / max(total_wall, 1e-9):.0f};"
        f"events={total_events};wall_s={total_wall:.1f};"
        f"cells={n_cells}")


def roofline():
    from repro.launch.roofline import load_table
    try:
        rows = load_table()
    except Exception as e:
        row("roofline_missing", 0.0, str(e))
        return
    for r in rows:
        if "skipped" in r or "error" in r:
            continue
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        row(f"roofline_{r['arch']}_{r['shape']}", bound * 1e6,
            f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f};"
            f"useful={r['useful_ratio']:.2f}")


SECTIONS = [fig2_overdecomp, fig3_loadbalance, fig5_interrupt_cpu,
            fig6_interrupt_dev, fig7_modes, fig8_endtoend, kernels,
            cluster_hetero, cluster_slo, cluster_preempt,
            cluster_vertical, cluster_spot_market, cluster_chaos,
            cluster_matrix,
            engine_throughput, engine_churn, roofline]

# sections whose --json artifact keeps a historical filename
_JSON_NAME = {"cluster_matrix": "BENCH_matrix.json"}


def main() -> None:
    import inspect

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("sections", nargs="*",
                    help="section names to run (default: all)")
    ap.add_argument("--scenario", action="append", default=[],
                    help="alias for a positional section name")
    ap.add_argument("--arrival", default="batch",
                    help="offered-load model for cluster scenarios: "
                         "batch | poisson:<rate> | trace:<file>")
    ap.add_argument("--quick", action="store_true",
                    help="reduced problem sizes (CI smoke)")
    ap.add_argument("--json", action="store_true",
                    help="persist each section's rows to "
                         "BENCH_<section>.json at the repo root")
    args = ap.parse_args()
    names = list(args.sections) + list(args.scenario)
    known = {fn.__name__ for fn in SECTIONS}
    unknown = set(names) - known
    if unknown:
        ap.error(f"unknown section(s): {sorted(unknown)}; "
                 f"choose from {sorted(known)}")
    opts = {"arrival": args.arrival, "quick": args.quick}
    print("name,us_per_call,derived")
    for fn in SECTIONS:
        if names and fn.__name__ not in names:
            continue
        accepted = inspect.signature(fn).parameters
        t0 = time.perf_counter()
        _ROWS.clear()
        fn(**{k: v for k, v in opts.items() if k in accepted})
        elapsed = time.perf_counter() - t0
        print(f"# section {fn.__name__} took {elapsed:.1f}s", flush=True)
        if args.json:
            path = os.path.join(_REPO_ROOT, _JSON_NAME.get(
                fn.__name__, f"BENCH_{fn.__name__}.json"))
            with open(path, "w") as fh:
                json.dump({"scenario": fn.__name__,
                           "quick": args.quick,
                           "section_seconds": round(elapsed, 1),
                           "unit": "us_per_call",
                           "rows": list(_ROWS)}, fh, indent=1)
                fh.write("\n")
            print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
