"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (one per measured point).
Sections:
  fig2_overdecomp     weak-scaling analogue: time/iter vs ODF (+latency)
  fig3_loadbalance    heterogeneous fleet: no-LB vs GreedyRefine (rate-aware)
  fig5_interrupt_cpu  rescale stage breakdown, host-memory store
  fig6_interrupt_dev  rescale stage breakdown, device-resident store
  fig7_modes          interruption-handling overhead, modes A/B/C
  fig8_endtoend       total runtime vs #simultaneous interruptions
  kernels             per-kernel throughput (ref path) + allclose check
  roofline            summary over artifacts/dryrun (§Roofline)
"""

from __future__ import annotations

import sys
import time

import numpy as np


def row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


# ------------------------------------------------------------------ fig 2
def fig2_overdecomp():
    from repro.apps.jacobi2d import run_jacobi
    for latency_us, tag in ((0, "fast-net"), (500, "cloud-tcp")):
        base = None
        for odf in (1, 2, 4, 8):
            out = run_jacobi(grid_size=512, n_pes=4, odf=odf, iters=14,
                             comm_latency_s=latency_us * 1e-6)
            us = out.time_per_iter * 1e6
            base = base or us
            row(f"fig2_overdecomp_{tag}_odf{odf}", us,
                f"speedup_vs_odf1={base/us:.2f}")


# ------------------------------------------------------------------ fig 3
def fig3_loadbalance():
    rates = {"cpu_fleet": [1.0, 0.85, 0.6, 1.0],
             "gpu_fleet": [1.0, 1.0, 0.55, 0.55]}
    from repro.apps.jacobi2d import run_jacobi
    for fleet, mult in rates.items():
        res = {}
        for strat, aware, tag in ((None, False, "nolb"),
                                  ("greedy_refine", False, "refine_blind"),
                                  ("greedy_refine", True, "refine_rate")):
            out = run_jacobi(grid_size=768, n_pes=4, odf=4, iters=20,
                             kernel="lulesh", pe_rate_multipliers=mult,
                             lb_strategy=strat, lb_every=6, rate_aware=aware)
            tail = out.per_iter[-6:]
            us = float(np.mean([m["time_per_iter"] for m in tail])) * 1e6
            res[tag] = us
            imp = (1 - us / res["nolb"]) * 100 if "nolb" in res else 0.0
            row(f"fig3_lb_{fleet}_{tag}", us, f"improvement={imp:.1f}%")


# ------------------------------------------------------------- fig 5 / 6
def _interrupt_breakdown(store_kind: str, tag: str):
    from repro.configs import ARCHS, SHAPES
    from repro.launch.train import ElasticTrainer
    cfg = ARCHS["granite-8b"].reduced()
    shape = SHAPES["train_4k"].reduced()
    tr = ElasticTrainer(cfg, shape, n_devices=1, store_kind=store_kind)
    tr.train(2, log_every=0)
    ev_shrink = tr.runtime.rescale_to(1)   # simulated interruption rescale
    tr.train(1, log_every=0)
    ev_expand = tr.runtime.rescale_to(1)
    for ev, kind in ((ev_shrink, "shrink"), (ev_expand, "expand")):
        for stage, sec in ev.stages.items():
            row(f"{tag}_{kind}_{stage}", sec * 1e6,
                f"total={ev.total:.3f}s")


def fig5_interrupt_cpu():
    _interrupt_breakdown("memory", "fig5_cpu")


def fig6_interrupt_dev():
    _interrupt_breakdown("device", "fig6_dev")


# ------------------------------------------------------------------ fig 7
def fig7_modes():
    from benchmarks.measure import calibrated_cost_model
    from repro.core.cloud import CloudManager, Mode
    cost = calibrated_cost_model(state_bytes=16 * 64e6)
    for accel, hw in ((False, "cpu"), (True, "gpu")):
        cost_hw = cost.__class__(**{**cost.__dict__, "accelerator": accel})
        for mode in Mode:
            cm = CloudManager(n_instances=16, mode=mode, cost=cost_hw,
                              total_iters=5000, iter_seconds=0.2)
            cm.inject_interruption(t=100.0, count=1)
            rep = cm.run()
            total_overhead = rep.total_time - rep.ideal_time
            row(f"fig7_modes_{hw}_mode{mode.value}",
                total_overhead * 1e6,
                f"overhead_s={total_overhead:.1f};"
                f"rescales={len(rep.rescales)}")


# ------------------------------------------------------------------ fig 8
def fig8_endtoend():
    from benchmarks.measure import calibrated_cost_model
    from repro.core.cloud import CloudManager, Mode
    cost = calibrated_cost_model(state_bytes=16 * 64e6)
    for accel, hw, iters in ((False, "cpu", 5000), (True, "gpu", 30000)):
        cost_hw = cost.__class__(**{**cost.__dict__, "accelerator": accel})
        for n_int in (0, 1, 2, 4, 8):
            for mode in (Mode.B_REACTIVE, Mode.C_PROACTIVE):
                cm = CloudManager(n_instances=16, mode=mode, cost=cost_hw,
                                  total_iters=iters, iter_seconds=0.2)
                if n_int:
                    cm.inject_interruption(t=100.0, count=n_int)
                rep = cm.run()
                row(f"fig8_endtoend_{hw}_mode{mode.value}_int{n_int}",
                    rep.total_time * 1e6,
                    f"overhead={100*rep.overhead_frac:.2f}%")


# ------------------------------------------------------------------ kernels
def kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels.jacobi.ref import jacobi_step_ref
    from repro.models.layers import blockwise_attention
    from repro.models.mamba2 import ssd_intra_chunk_ref

    g = jax.random.normal(jax.random.PRNGKey(0), (1024, 1024))
    f = jax.jit(jacobi_step_ref)
    f(g).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        out = f(g)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / 20 * 1e6
    row("kernel_jacobi_ref_1024", us,
        f"GBps={1024*1024*4*5/(us/1e6)/1e9:.1f}")

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 1024, 8, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 1024, 2, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 1024, 2, 64), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: blockwise_attention(
        q, k, v, causal=True, block_q=256, block_kv=256))
    f(q, k, v).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        out = f(q, k, v)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    flops = 2 * 2 * 1024 * 1024 * 8 * 64 / 2  # causal half
    row("kernel_flash_ref_1k", us, f"GFLOPs={flops/(us/1e6)/1e9:.1f}")

    b, nc, l, h, p, n = 1, 8, 128, 8, 64, 64
    xs = jax.random.split(jax.random.PRNGKey(1), 5)
    xr = jax.random.normal(xs[0], (b, nc, l, h, p))
    dtr = jax.nn.softplus(jax.random.normal(xs[1], (b, nc, l, h)))
    dacs = jnp.cumsum(-jnp.abs(jax.random.normal(xs[2], (b, nc, l, h))) * .1,
                      axis=2)
    Br = jax.random.normal(xs[3], (b, nc, l, n))
    Cr = jax.random.normal(xs[4], (b, nc, l, n))
    f = jax.jit(ssd_intra_chunk_ref)
    jax.block_until_ready(f(xr, dtr, dacs, Br, Cr))
    t0 = time.perf_counter()
    for _ in range(5):
        out = f(xr, dtr, dacs, Br, Cr)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / 5 * 1e6
    row("kernel_ssd_ref_1k", us, f"chunk={l}")


# ------------------------------------------------------------------ roofline
def roofline():
    from repro.launch.roofline import load_table
    try:
        rows = load_table()
    except Exception as e:
        row("roofline_missing", 0.0, str(e))
        return
    for r in rows:
        if "skipped" in r or "error" in r:
            continue
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        row(f"roofline_{r['arch']}_{r['shape']}", bound * 1e6,
            f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f};"
            f"useful={r['useful_ratio']:.2f}")


SECTIONS = [fig2_overdecomp, fig3_loadbalance, fig5_interrupt_cpu,
            fig6_interrupt_dev, fig7_modes, fig8_endtoend, kernels,
            roofline]


def main() -> None:
    names = sys.argv[1:]
    print("name,us_per_call,derived")
    for fn in SECTIONS:
        if names and fn.__name__ not in names:
            continue
        t0 = time.perf_counter()
        fn()
        print(f"# section {fn.__name__} took {time.perf_counter()-t0:.1f}s",
              flush=True)


if __name__ == "__main__":
    main()
