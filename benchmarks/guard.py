"""Perf-trajectory guard: fail CI when a persisted BENCH_*.json regresses.

Currently guards the engine hot path: the chunked-bulk-prefill speedup
over the streamed baseline (the ``engine_prefill_speedup`` row written by
``benchmarks/run.py --scenario engine_throughput --json``) must stay at
or above ``--min-speedup``.

Usage:
  python benchmarks/guard.py BENCH_engine_throughput.json --min-speedup 3.0
"""

from __future__ import annotations

import argparse
import json
import re
import sys


def prefill_speedup(bench: dict) -> float:
    """Extract chunked-over-streamed speedup from an engine_throughput
    benchmark dump (derived field ``chunked_over_streamed=<X>x``)."""
    for r in bench.get("rows", []):
        if r.get("name") == "engine_prefill_speedup":
            m = re.search(r"chunked_over_streamed=([0-9.]+)x",
                          r.get("derived", ""))
            if m:
                return float(m.group(1))
    raise SystemExit("guard: no engine_prefill_speedup row in the dump "
                     "(run benchmarks/run.py --scenario engine_throughput "
                     "--json first)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench_json",
                    help="path to BENCH_engine_throughput.json")
    ap.add_argument("--min-speedup", type=float, default=3.0,
                    help="minimum chunked/streamed prefill speedup")
    args = ap.parse_args()
    with open(args.bench_json) as fh:
        bench = json.load(fh)
    speedup = prefill_speedup(bench)
    if speedup < args.min_speedup:
        print(f"guard: FAIL — chunked prefill speedup {speedup:.1f}x "
              f"regressed below {args.min_speedup:.1f}x", file=sys.stderr)
        raise SystemExit(1)
    print(f"guard: OK — chunked prefill speedup {speedup:.1f}x "
          f">= {args.min_speedup:.1f}x")


if __name__ == "__main__":
    main()
