"""Perf-trajectory guard: fail CI when a persisted BENCH_*.json regresses.

Guarded figures, dispatched on the dump's ``scenario`` field:

* ``engine_throughput`` — the chunked-bulk-prefill speedup over the
  streamed baseline (row ``engine_prefill_speedup``) must stay at or
  above ``--min-speedup``.
* ``cluster_slo`` — SLO-aware scheduling's interactive-class deadline
  attainment (row ``cluster_slo_slo_aware_interactive_p99``, derived
  field ``attainment=<X>``) must stay at or above ``--min-attainment``.
* ``cluster_spot_market`` — interruption-adjusted market shopping must
  keep strictly higher savings than the naive-cheapest shopper at
  equal-or-better interactive attainment (summary row fields
  ``savings=<adj>%vs<nai>%`` and ``attainment=<adj>vs<nai>``), and the
  adjusted savings must stay at or above ``--min-savings``.
* ``engine_churn`` — the paged cache must beat the dense engine's decode
  tokens/sec under churn at equal kv memory (summary field
  ``churn_speedup``, floor ``--min-churn-speedup`` and always > 1x),
  with bit-identical streams, a concurrent-slot high-water above the
  dense lane count, and zero steady-state host syncs.
* ``cluster_chaos`` — checkpoint-based recovery must lose ZERO requests
  under the chaos soup with bit-identical final streams, at strictly
  higher goodput than the recovery-off run (which must demonstrably
  lose work), goodput at or above ``--min-chaos-goodput``, and
  replayed-token overhead at or below ``--max-replay-frac``.
* ``cluster_vertical`` — in-place resize + QoS must reach
  at-least-equal interactive attainment at strictly lower fleet dollar
  cost than the horizontal-only arm (and at or below
  ``--max-vertical-dollars``), with both grow and shrink exercised,
  zero lost WorkUnits, and bit-identical streams across the arms.
* ``cluster_matrix`` (BENCH_matrix.json) — every scenario-matrix cell
  (shape x router x preemption x fleet, plus the diurnal mega-cell)
  must be populated with interactive attainment at or above
  ``--min-cell-attainment``; the consolidated simulator throughput
  (``matrix_total`` row, ``sim_events_per_sec``) must stay at or above
  ``--min-sim-events-per-sec``; and the whole section's wall clock
  must stay at or below ``--max-matrix-seconds``.

Usage:
  python benchmarks/guard.py BENCH_engine_throughput.json --min-speedup 3.0
  python benchmarks/guard.py BENCH_cluster_slo.json --min-attainment 0.6
  python benchmarks/guard.py BENCH_cluster_spot_market.json --min-savings 40
  python benchmarks/guard.py BENCH_engine_churn.json --min-churn-speedup 1.0
  python benchmarks/guard.py BENCH_cluster_chaos.json --min-chaos-goodput 1.0
  python benchmarks/guard.py BENCH_matrix.json --min-sim-events-per-sec 2000
  python benchmarks/guard.py BENCH_*.json          # guard all known dumps
"""

from __future__ import annotations

import argparse
import json
import re
import sys


def _derived(bench: dict, row_name: str, pattern: str) -> float:
    for r in bench.get("rows", []):
        if r.get("name") == row_name:
            m = re.search(pattern, r.get("derived", ""))
            if m:
                return float(m.group(1))
    raise SystemExit(
        f"guard: no {row_name} row matching {pattern!r} in the dump "
        f"(re-run benchmarks/run.py --scenario {bench.get('scenario')} "
        f"--json first)")


def prefill_speedup(bench: dict) -> float:
    """Chunked-over-streamed speedup from an engine_throughput dump."""
    return _derived(bench, "engine_prefill_speedup",
                    r"chunked_over_streamed=([0-9.]+)x")


def interactive_attainment(bench: dict) -> float:
    """SLO-aware interactive deadline attainment from a cluster_slo dump."""
    return _derived(bench, "cluster_slo_slo_aware_interactive_p99",
                    r"attainment=([0-9.]+)")


def market_savings(bench: dict) -> tuple:
    """(adjusted, naive) savings % and attainment from a
    cluster_spot_market dump's summary row."""
    row = "cluster_spot_market_summary"
    sav_a = _derived(bench, row, r"savings=([0-9.]+)%vs")
    sav_n = _derived(bench, row, r"savings=[0-9.]+%vs([0-9.]+)%")
    att_a = _derived(bench, row, r"attainment=([0-9.]+)vs")
    att_n = _derived(bench, row, r"attainment=[0-9.]+vs([0-9.]+)")
    return sav_a, sav_n, att_a, att_n


def _derived_str(bench: dict, row_name: str, pattern: str) -> str:
    for r in bench.get("rows", []):
        if r.get("name") == row_name:
            m = re.search(pattern, r.get("derived", ""))
            if m:
                return m.group(1)
    raise SystemExit(
        f"guard: no {row_name} row matching {pattern!r} in the dump "
        f"(re-run benchmarks/run.py --scenario {bench.get('scenario')} "
        f"--json first)")


def churn_stats(bench: dict) -> tuple:
    """(speedup, bit_identical, paged_peak_slots, dense_lanes,
    steady_syncs) from an engine_churn dump's summary row."""
    row = "engine_churn_summary"
    return (_derived(bench, row, r"churn_speedup=([0-9.]+)x"),
            _derived_str(bench, row, r"bit_identical=(\w+)") == "True",
            int(_derived(bench, row, r"paged_peak_slots=([0-9]+)")),
            int(_derived(bench, row, r"dense_lanes=([0-9]+)")),
            int(_derived(bench, row, r"steady_syncs=([0-9]+)")))


def chaos_stats(bench: dict) -> tuple:
    """(goodput_on, goodput_off, lost_on, lost_off, bit_identical,
    replay_frac) from a cluster_chaos dump's summary row."""
    row = "cluster_chaos_summary"
    return (_derived(bench, row, r"goodput=([0-9.]+)vs"),
            _derived(bench, row, r"goodput=[0-9.]+vs([0-9.]+)tok/s"),
            int(_derived(bench, row, r"lost=([0-9]+)vs")),
            int(_derived(bench, row, r"lost=[0-9]+vs([0-9]+)")),
            _derived_str(bench, row, r"bit_identical=(\w+)") == "True",
            _derived(bench, row, r"replay_frac=([0-9.]+)"))


def vertical_stats(bench: dict) -> tuple:
    """(att_v, att_h, cost_v, cost_h, grows, shrinks, lost, identical)
    from a cluster_vertical dump's summary row."""
    row = "cluster_vertical_summary"
    return (_derived(bench, row, r"attainment=([0-9.]+)vs"),
            _derived(bench, row, r"attainment=[0-9.]+vs([0-9.]+)"),
            _derived(bench, row, r"dollar_cost=([0-9.]+)vs"),
            _derived(bench, row, r"dollar_cost=[0-9.]+vs([0-9.]+)"),
            int(_derived(bench, row, r"grows=([0-9]+)")),
            int(_derived(bench, row, r"shrinks=([0-9]+)")),
            int(_derived(bench, row, r"lost=([0-9]+)")),
            _derived_str(bench, row, r"identical_tokens=(\w+)") == "True")


def matrix_cells(bench: dict) -> list:
    """[(name, attainment), ...] for every scenario-matrix cell row."""
    cells = []
    for r in bench.get("rows", []):
        name = r.get("name", "")
        if not name.startswith("matrix_") or name == "matrix_total":
            continue
        m = re.search(r"attainment=([0-9.]+)", r.get("derived", ""))
        if m is None:
            raise SystemExit(f"guard: matrix cell {name} has no "
                             f"attainment field — cell not populated")
        cells.append((name, float(m.group(1))))
    return cells


def check(bench: dict, args) -> bool:
    scenario = bench.get("scenario", "")
    if scenario == "engine_throughput":
        speedup = prefill_speedup(bench)
        if speedup < args.min_speedup:
            print(f"guard: FAIL — chunked prefill speedup {speedup:.1f}x "
                  f"regressed below {args.min_speedup:.1f}x",
                  file=sys.stderr)
            return False
        print(f"guard: OK — chunked prefill speedup {speedup:.1f}x "
              f">= {args.min_speedup:.1f}x")
        return True
    if scenario == "cluster_slo":
        att = interactive_attainment(bench)
        if att < args.min_attainment:
            print(f"guard: FAIL — SLO-aware interactive attainment "
                  f"{att:.3f} regressed below {args.min_attainment:.2f}",
                  file=sys.stderr)
            return False
        print(f"guard: OK — SLO-aware interactive attainment {att:.3f} "
              f">= {args.min_attainment:.2f}")
        return True
    if scenario == "cluster_spot_market":
        sav_a, sav_n, att_a, att_n = market_savings(bench)
        if sav_a <= sav_n:
            print(f"guard: FAIL — adjusted market shopping no longer "
                  f"beats naive on savings ({sav_a:.1f}% vs {sav_n:.1f}%)",
                  file=sys.stderr)
            return False
        if att_a < att_n:
            print(f"guard: FAIL — adjusted shopping lost interactive "
                  f"attainment ({att_a:.3f} vs naive {att_n:.3f})",
                  file=sys.stderr)
            return False
        if sav_a < args.min_savings:
            print(f"guard: FAIL — adjusted savings {sav_a:.1f}% regressed "
                  f"below {args.min_savings:.1f}%", file=sys.stderr)
            return False
        print(f"guard: OK — adjusted savings {sav_a:.1f}% > naive "
              f"{sav_n:.1f}% at attainment {att_a:.3f} >= {att_n:.3f} "
              f"(floor {args.min_savings:.1f}%)")
        return True
    if scenario == "engine_churn":
        speedup, identical, peak, lanes, syncs = churn_stats(bench)
        floor = max(args.min_churn_speedup, 1.0)
        if not identical:
            print("guard: FAIL — paged cache no longer bit-identical to "
                  "dense under churn", file=sys.stderr)
            return False
        if speedup <= 1.0 or speedup < floor:
            print(f"guard: FAIL — paged churn speedup {speedup:.2f}x "
                  f"regressed below {floor:.2f}x", file=sys.stderr)
            return False
        if peak <= lanes:
            print(f"guard: FAIL — paged concurrent-slot high-water {peak} "
                  f"no longer exceeds the dense lane count {lanes} at "
                  f"equal cache memory", file=sys.stderr)
            return False
        if syncs != 0:
            print(f"guard: FAIL — paged steady-state decode performed "
                  f"{syncs} device->host syncs (must be 0)",
                  file=sys.stderr)
            return False
        print(f"guard: OK — paged churn speedup {speedup:.2f}x >= "
              f"{floor:.2f}x, bit-identical, peak slots {peak} > "
              f"{lanes} dense lanes, 0 steady-state syncs")
        return True
    if scenario == "cluster_chaos":
        (gp_on, gp_off, lost_on, lost_off,
         identical, replay) = chaos_stats(bench)
        if lost_on != 0:
            print(f"guard: FAIL — recovery lost {lost_on} request(s) "
                  f"under the chaos soup (must be 0)", file=sys.stderr)
            return False
        if not identical:
            print("guard: FAIL — recovered streams no longer bit-identical "
                  "to the fault-free reference", file=sys.stderr)
            return False
        if lost_off <= 0:
            print("guard: FAIL — the no-recovery run lost nothing: the "
                  "chaos soup no longer bites and the A/B is vacuous",
                  file=sys.stderr)
            return False
        if gp_on <= gp_off:
            print(f"guard: FAIL — recovery goodput {gp_on:.3f} tok/s no "
                  f"longer beats no-recovery {gp_off:.3f} tok/s",
                  file=sys.stderr)
            return False
        if gp_on < args.min_chaos_goodput:
            print(f"guard: FAIL — recovery goodput {gp_on:.3f} tok/s "
                  f"regressed below {args.min_chaos_goodput:.3f}",
                  file=sys.stderr)
            return False
        if replay > args.max_replay_frac:
            print(f"guard: FAIL — replayed-token overhead {replay:.3f} "
                  f"exceeds {args.max_replay_frac:.3f} of useful tokens",
                  file=sys.stderr)
            return False
        print(f"guard: OK — chaos recovery lost 0 (vs {lost_off} without), "
              f"bit-identical, goodput {gp_on:.3f} > {gp_off:.3f} tok/s "
              f">= {args.min_chaos_goodput:.3f}, replay overhead "
              f"{replay:.3f} <= {args.max_replay_frac:.3f}")
        return True
    if scenario == "cluster_vertical":
        (att_v, att_h, cost_v, cost_h,
         grows, shrinks, lost, identical) = vertical_stats(bench)
        if lost != 0:
            print(f"guard: FAIL — vertical resize lost {lost} "
                  f"WorkUnit(s) (must be 0)", file=sys.stderr)
            return False
        if not identical:
            print("guard: FAIL — resized streams no longer bit-identical "
                  "to the horizontal-only reference", file=sys.stderr)
            return False
        if grows <= 0 or shrinks <= 0:
            print(f"guard: FAIL — vertical arm no longer exercises both "
                  f"directions (grows={grows}, shrinks={shrinks}): the "
                  f"A/B is vacuous", file=sys.stderr)
            return False
        if att_v < att_h:
            print(f"guard: FAIL — vertical+QoS interactive attainment "
                  f"{att_v:.3f} fell below horizontal-only {att_h:.3f}",
                  file=sys.stderr)
            return False
        if cost_v >= cost_h:
            print(f"guard: FAIL — vertical fleet dollars {cost_v:.4f} no "
                  f"longer strictly below horizontal {cost_h:.4f}",
                  file=sys.stderr)
            return False
        if cost_v > args.max_vertical_dollars:
            print(f"guard: FAIL — vertical fleet dollars {cost_v:.4f} "
                  f"exceed the {args.max_vertical_dollars:.4f} ceiling",
                  file=sys.stderr)
            return False
        print(f"guard: OK — vertical+QoS attainment {att_v:.3f} >= "
              f"{att_h:.3f} at {cost_v:.4f} < {cost_h:.4f} dollars "
              f"(ceiling {args.max_vertical_dollars:.4f}), "
              f"{grows} grows / {shrinks} shrinks, 0 lost, bit-identical")
        return True
    if scenario == "cluster_matrix":
        cells = matrix_cells(bench)
        # 5 shapes x 2 routers x 2 preemption x 2 fleets + 1 mega cell
        if len(cells) < 41:
            print(f"guard: FAIL — scenario matrix has only {len(cells)} "
                  f"populated cell(s), expected 41", file=sys.stderr)
            return False
        low = [(n, a) for n, a in cells
               if a < args.min_cell_attainment]
        if low:
            for n, a in low:
                print(f"guard: FAIL — matrix cell {n} interactive "
                      f"attainment {a:.3f} below "
                      f"{args.min_cell_attainment:.2f}", file=sys.stderr)
            return False
        evps = _derived(bench, "matrix_total",
                        r"sim_events_per_sec=([0-9.]+)")
        if evps < args.min_sim_events_per_sec:
            print(f"guard: FAIL — simulator throughput {evps:,.0f} "
                  f"events/s regressed below "
                  f"{args.min_sim_events_per_sec:,.0f} (hot-path "
                  f"regression in loop/router/metrics)", file=sys.stderr)
            return False
        wall = float(bench.get("section_seconds", 0.0))
        if wall > args.max_matrix_seconds:
            print(f"guard: FAIL — matrix wall clock {wall:.1f}s exceeds "
                  f"the {args.max_matrix_seconds:.0f}s ceiling",
                  file=sys.stderr)
            return False
        worst = min(cells, key=lambda c: c[1])
        print(f"guard: OK — {len(cells)} matrix cells populated, worst "
              f"attainment {worst[1]:.3f} ({worst[0]}) >= "
              f"{args.min_cell_attainment:.2f}, {evps:,.0f} sim "
              f"events/s >= {args.min_sim_events_per_sec:,.0f}, wall "
              f"{wall:.1f}s <= {args.max_matrix_seconds:.0f}s")
        return True
    print(f"guard: skip — no guard registered for scenario {scenario!r}")
    return True


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench_json", nargs="+",
                    help="path(s) to BENCH_<scenario>.json dumps")
    ap.add_argument("--min-speedup", type=float, default=3.0,
                    help="minimum chunked/streamed prefill speedup "
                         "(engine_throughput dumps)")
    ap.add_argument("--min-attainment", type=float, default=0.6,
                    help="minimum SLO-aware interactive deadline "
                         "attainment (cluster_slo dumps)")
    ap.add_argument("--min-savings", type=float, default=30.0,
                    help="minimum interruption-adjusted savings percent "
                         "vs all-on-demand (cluster_spot_market dumps)")
    ap.add_argument("--min-churn-speedup", type=float, default=1.0,
                    help="minimum paged-over-dense decode tokens/sec "
                         "under churn (engine_churn dumps; always "
                         "strictly > 1x)")
    ap.add_argument("--min-chaos-goodput", type=float, default=1.0,
                    help="minimum recovery-on goodput in tok/s under the "
                         "chaos soup (cluster_chaos dumps; must also "
                         "strictly beat the recovery-off run)")
    ap.add_argument("--max-replay-frac", type=float, default=0.25,
                    help="maximum replayed-token overhead as a fraction "
                         "of useful tokens (cluster_chaos dumps)")
    ap.add_argument("--max-vertical-dollars", type=float, default=0.10,
                    help="fleet-dollar ceiling for the vertical+QoS arm "
                         "(cluster_vertical dumps; it must also stay "
                         "strictly below the horizontal arm)")
    ap.add_argument("--min-cell-attainment", type=float, default=0.6,
                    help="minimum interactive attainment for EVERY "
                         "scenario-matrix cell (cluster_matrix dumps)")
    ap.add_argument("--min-sim-events-per-sec", type=float, default=2000.0,
                    help="minimum consolidated simulator event "
                         "throughput across the matrix (cluster_matrix "
                         "dumps; catches loop/router/metrics hot-path "
                         "regressions)")
    ap.add_argument("--max-matrix-seconds", type=float, default=600.0,
                    help="wall-clock ceiling for the whole matrix "
                         "section (cluster_matrix dumps)")
    args = ap.parse_args()
    ok = True
    for path in args.bench_json:
        with open(path) as fh:
            bench = json.load(fh)
        ok = check(bench, args) and ok
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
