"""Paper Fig 2-3 mini: overdecomposition + rate-aware LB on Jacobi2D.

Sweeps the overdecomposition factor under an injected cloud-like network
latency, then shows rate-aware GreedyRefine on a heterogeneous "fleet".

    PYTHONPATH=src python examples/jacobi_overdecomp.py
"""
from repro.apps.jacobi2d import run_jacobi

print("== overdecomposition under 200us/msg latency (4 PEs) ==")
for odf in (1, 2, 4, 8):
    out = run_jacobi(grid_size=512, n_pes=4, odf=odf, iters=12,
                     comm_latency_s=200e-6)
    print(f"  odf={odf}: {out.time_per_iter*1e3:7.2f} ms/iter")

print("== rate-aware LB on heterogeneous PEs (c7i/c6a/c5a-like rates) ==")
print("   (LULESH proxy: compute-bound, as in paper Fig 3b)")
rates = [1.0, 0.85, 0.6, 1.0]
for strat, aware in ((None, False), ("greedy_refine", False),
                     ("greedy_refine", True)):
    out = run_jacobi(grid_size=1024, n_pes=4, odf=4, iters=24,
                     kernel="lulesh", pe_rate_multipliers=rates,
                     lb_strategy=strat, lb_every=8, rate_aware=aware)
    tail = out.per_iter[-8:]
    tpi = sum(m["time_per_iter"] for m in tail) / len(tail)
    label = "no LB" if strat is None else \
        ("GreedyRefine rate-aware" if aware else "GreedyRefine rate-blind")
    print(f"  {label:26s}: {tpi*1e3:7.2f} ms/iter (steady state)")
