"""Quickstart: train a small LM with the adaptive runtime (CPU, ~1 min).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config, SHAPES
from repro.launch.train import ElasticTrainer

cfg = get_config("llama3.2-3b").reduced()         # small same-family config
shape = SHAPES["train_4k"].reduced()

trainer = ElasticTrainer(cfg, shape, n_devices=len(jax.devices()))
out = trainer.train(n_steps=20, log_every=5)
print(f"\ntrained 20 steps in {out['seconds']:.1f}s; "
      f"final loss {out['final_loss']:.4f}")
assert out["final_loss"] < 6.5, "loss should be at/below ln(vocab)"
