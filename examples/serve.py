"""Serving example: continuous-batching decode with KV-cache slots.

    PYTHONPATH=src python examples/serve.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import model_zoo as zoo
from repro.serving.engine import Request, ServingEngine

cfg = get_config("llama3.2-3b").reduced()
params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
engine = ServingEngine(cfg, params, batch_size=4, max_seq=64)

rng = np.random.default_rng(0)
for rid in range(6):
    plen = int(rng.integers(3, 9))
    engine.submit(Request(rid=rid,
                          prompt=rng.integers(0, cfg.vocab_size, plen,
                                              dtype=np.int32),
                          max_new_tokens=8))
stats = engine.run_until_idle()
print(f"served 6 requests: {stats['tokens']} tokens in "
      f"{stats['seconds']:.2f}s ({stats['tok_per_s']:.1f} tok/s on CPU)")
