"""End-to-end driver: train an LM on a simulated spot fleet with the full
adaptive runtime — elastic shrink/expand on interruption notices, proactive
capacity rebalancing (Mode C), in-memory checkpointing, and bit-exact
training continuity across rescales.

One forced-host device == one "instance".  The CloudManager's event timeline
(rebalance recommendation -> notice -> termination -> replacement) is mapped
onto training steps; rescales are REAL: state is checkpointed to host
memory, the mesh is rebuilt with the surviving devices, state is resharded,
and training resumes on the exact next batch.

    python examples/train_spot_elastic.py            # ~22M-param model
    python examples/train_spot_elastic.py --full     # ~110M-param model
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import time      # noqa: E402

import jax       # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import ModelConfig, ShapeConfig  # noqa: E402
from repro.launch.train import ElasticTrainer            # noqa: E402


def model_cfg(full: bool) -> ModelConfig:
    if full:  # ~110M params (GPT-2-small class)
        return ModelConfig(name="spot-demo-110m", family="dense",
                           num_layers=12, d_model=768, num_heads=12,
                           num_kv_heads=12, d_ff=3072, vocab_size=32768,
                           num_microbatches=2)
    return ModelConfig(name="spot-demo-22m", family="dense",
                       num_layers=6, d_model=384, num_heads=6,
                       num_kv_heads=6, d_ff=1536, vocab_size=16384,
                       num_microbatches=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~110M params")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    a = ap.parse_args()

    cfg = model_cfg(a.full)
    shape = ShapeConfig("train", a.seq, a.batch, "train")
    n = len(jax.devices())
    print(f"fleet: {n} instances (host devices); model {cfg.name}")

    trainer = ElasticTrainer(cfg, shape, n_devices=n)

    # --- phase 1: steady state
    trainer.train(a.steps // 3, log_every=5)
    loss_before = trainer.metrics_log[-1]["loss"]

    # --- phase 2: two instances get rebalance recommendations -> notices.
    # Mode C (proactive): replacements were requested at the recommendation;
    # a SINGLE rescale swaps the doomed instances for replacements.  On this
    # host the device count is fixed, so the swap is shrink->(replacement
    # arrives)->expand with the expand driven by the capacity-rebalancing
    # trigger; stage timings are real.
    print("\n[cloud] rebalance recommendation on 2 instances "
          "(proactive replacements requested)")
    ev1 = trainer.rescale(n - 2)       # emergency shrink at the notice
    trainer.train(a.steps // 3, log_every=5)
    print("[cloud] replacements ready -> single expand rescale")
    ev2 = trainer.rescale(n)
    trainer.train(a.steps - 2 * (a.steps // 3), log_every=5)

    print("\nrescale stage breakdown (seconds):")
    for ev in trainer.runtime.events:
        print(f"  {ev.kind:7s} {ev.from_devices}->{ev.to_devices}: "
              + ", ".join(f"{k}={v:.3f}" for k, v in ev.stages.items()))
    print(f"\nfinal loss {trainer.metrics_log[-1]['loss']:.4f} "
          f"(pre-interruption {loss_before:.4f}); "
          f"training continued across {len(trainer.runtime.events)} rescales")


if __name__ == "__main__":
    main()
