"""Serving-cluster example: the paper's runtime ideas on a serving fleet.

A heterogeneous fleet (two 2.0x replicas, two 0.7x replicas) serves one
batch of requests twice — once with rate-oblivious round-robin routing,
once with rate-aware GreedyRefine routing on *measured* tokens/sec — and
a spot interruption hits a fast replica mid-run both times.  The doomed
replica is drained: its in-flight slots are checkpointed through the
in-memory store and re-admitted on survivors, so zero requests (and zero
decoded tokens) are lost.

    PYTHONPATH=src python examples/serve_cluster.py
"""

import jax

from repro.cluster import InstanceType, ROUTERS, ServingCluster
from repro.configs import get_config
from repro.models import model_zoo as zoo
from repro.serving.workload import PoissonArrivals, synthetic_requests

cfg = get_config("granite-8b").reduced()
params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
fleet = [InstanceType("fast.2x", 2.0), InstanceType("fast.2x", 2.0),
         InstanceType("slow.1x", 0.7), InstanceType("slow.1x", 0.7)]


for name, router_cls in ROUTERS.items():
    cluster = ServingCluster(cfg, params, fleet, router=router_cls(),
                             dt=1.0, batch_size=2, max_seq=32,
                             rebalance_lead=6.0, notice_deadline=4.0)
    # open-loop offered load: 3 req/s Poisson, scheduled one arrival
    # event at a time on the shared runtime loop
    reqs = synthetic_requests(20, cfg.vocab_size, seed=0)
    cluster.attach_arrivals(PoissonArrivals(reqs, 3.0, seed=0))
    cluster.inject_interruption(t=4.0, replica_rid=0)   # FIS analogue
    out = cluster.run()
    print(f"{name:12s} makespan={out['virtual_seconds']:5.0f}s "
          f"p99={out['p99_latency']:5.1f}s "
          f"agg={out['tok_per_s']:.2f} tok/s "
          f"dropped={out['dropped']} migrated={out['migrated_slots']}")
