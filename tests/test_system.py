"""End-to-end behaviour tests for the paper's system.

The paper's claims, as assertions:
  C1 overdecomposition hides injected network latency (Fig 2)
  C2 rate-aware GreedyRefine beats no-LB on heterogeneous PEs (Fig 3)
  C4/C5 proactive rebalancing ~halves reactive overhead; both beat
        filesystem checkpointing (Figs 7-8)
  +  training loss decreases; serving engine completes requests.
"""

import numpy as np
import pytest

from repro.apps.jacobi2d import run_jacobi
from repro.core.cloud import CloudManager, Mode, StageCostModel


def test_c1_overdecomposition_hides_latency():
    """Under cloud-like per-message latency, odf=4 beats odf=1 (Fig 2).

    Compares *accounted* time (measured per-tile unit cost x placement +
    modeled comm, see HostTileRuntime.step), not raw wall-clock, so OS
    scheduling jitter on a contended host cannot flip the assertion."""
    t = {}
    for odf in (1, 4):
        out = run_jacobi(grid_size=512, n_pes=4, odf=odf, iters=14,
                         comm_latency_s=500e-6)
        t[odf] = out.accounted_time_per_iter
    assert t[4] < t[1], t


def test_c2_rate_aware_lb_beats_none():
    """Heterogeneous rates + compute-bound proxy: LB wins 10-25%+ (Fig 3).

    Asserts on accounted time (jitter-free; modeled 0.4x heterogeneity
    and tile placement still fully determine it), median over the
    steady-state tail."""
    rates = [1.0, 0.9, 0.4, 1.0]
    res = {}
    for strat, aware in ((None, False), ("greedy_refine", True)):
        out = run_jacobi(grid_size=768, n_pes=4, odf=4, iters=24,
                         kernel="lulesh", pe_rate_multipliers=rates,
                         lb_strategy=strat, lb_every=6, rate_aware=aware)
        tail = out.per_iter[-8:]
        res[strat] = float(np.median([m["accounted_time_per_iter"]
                                      for m in tail]))
    improvement = 1 - res["greedy_refine"] / res[None]
    assert improvement > 0.05, res   # paper: 10-25% (clean machine: ~30%)


def test_c4_c5_mode_comparison():
    """Fig 7/8: C < B, and C < A; C end-to-end overhead < 1% (CPU)."""
    ov = {}
    for mode in Mode:
        cm = CloudManager(n_instances=16, mode=mode,
                          cost=StageCostModel(state_bytes=16 * 64e6),
                          total_iters=5000, iter_seconds=0.2)
        cm.inject_interruption(t=100.0, count=8)
        ov[mode] = cm.run().overhead_frac
    assert ov[Mode.C_PROACTIVE] < 0.01
    assert ov[Mode.C_PROACTIVE] < 0.5 * ov[Mode.B_REACTIVE]
    assert ov[Mode.B_REACTIVE] < ov[Mode.A_FILESYSTEM] * 2.5


def test_training_loss_decreases():
    from repro.configs import ARCHS, SHAPES
    from repro.launch.train import ElasticTrainer
    from repro.optim import adamw
    cfg = ARCHS["llama3.2-3b"].reduced()
    shape = SHAPES["train_4k"].reduced()
    # default HParams warm up over 100 steps; at 15 test steps the lr is
    # still ~0, so use a test-scale schedule that actually optimizes
    hp = adamw.HParams(lr=1e-3, warmup_steps=2, total_steps=100)
    tr = ElasticTrainer(cfg, shape, n_devices=1, seed=0, hp=hp)
    tr.train(15, log_every=0)
    first = np.mean([m["loss"] for m in tr.metrics_log[:3]])
    last = np.mean([m["loss"] for m in tr.metrics_log[-3:]])
    assert last < first, (first, last)


def test_serving_engine_end_to_end():
    import jax
    from repro.configs import get_config
    from repro.models import model_zoo as zoo
    from repro.serving.engine import Request, ServingEngine
    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    eng = ServingEngine(cfg, params, batch_size=2, max_seq=32)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 200, 4, dtype=np.int32),
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_idle()
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 4 for r in reqs)
    assert stats["tokens"] == 12
