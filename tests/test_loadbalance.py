"""Property tests (hypothesis) for the load-balancing strategies."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import loadbalance as lb
from repro.core.rates import RateMonitor

loads_st = st.lists(st.floats(0.1, 10.0), min_size=4, max_size=64)
npes_st = st.integers(2, 8)


@given(loads=loads_st, n_pes=npes_st, seed=st.integers(0, 100))
@settings(max_examples=60, deadline=None)
def test_greedy_assigns_all_with_lpt_bound(loads, n_pes, seed):
    rng = np.random.default_rng(seed)
    current = rng.integers(0, n_pes, len(loads))
    res = lb.greedy(loads, n_pes, current=current)
    assert res.assignment.shape == (len(loads),)
    assert res.assignment.min() >= 0 and res.assignment.max() < n_pes
    # LPT guarantee: makespan <= (4/3 - 1/3m) OPT; OPT >= max(mean, max load)
    opt_lb = max(sum(loads) / n_pes, max(loads))
    assert res.makespan <= (4 / 3) * opt_lb + 1e-9


@given(loads=loads_st, n_pes=npes_st, seed=st.integers(0, 100))
@settings(max_examples=60, deadline=None)
def test_greedy_refine_never_worse_and_migrations_bounded(loads, n_pes, seed):
    rng = np.random.default_rng(seed)
    current = rng.integers(0, n_pes, len(loads))
    refine = lb.greedy_refine(loads, n_pes, current=current)
    assert refine.makespan <= refine.baseline_makespan + 1e-9
    # migrations are bounded by the object count (and only donors donate)
    per_pe = np.bincount(current, minlength=n_pes)
    assert refine.migrations <= len(loads)
    # objects only ever leave overloaded PEs
    moved = np.nonzero(refine.assignment != current)[0]
    if len(moved):
        scaled = np.zeros(n_pes)
        np.add.at(scaled, current, np.asarray(loads))
        ideal = np.sum(loads) / n_pes
        assert all(scaled[current[o]] > ideal for o in moved)


@given(loads=loads_st, n_pes=npes_st,
       rates=st.lists(st.floats(0.2, 2.0), min_size=8, max_size=8))
@settings(max_examples=60, deadline=None)
def test_rate_aware_greedy_bounds(loads, n_pes, rates):
    rates = rates[:n_pes] + [1.0] * max(0, n_pes - len(rates))
    res = lb.greedy(loads, n_pes, rates=rates)
    # makespan >= ideal lower bound sum(l)/sum(r), <= serial on fastest PE
    ideal = sum(loads) / sum(rates)
    assert res.makespan >= ideal - 1e-9
    assert res.makespan <= sum(loads) / min(rates) + 1e-9


def test_rate_aware_moves_work_off_slow_pe():
    loads = np.ones(16)
    rates = [1.0, 1.0, 0.25, 1.0]
    res = lb.greedy(loads, 4, rates=rates)
    counts = np.bincount(res.assignment, minlength=4)
    assert counts[2] == counts.min()
    assert counts[2] <= 2  # slow PE gets far fewer than 4
    blind = lb.greedy(loads, 4)
    assert res.makespan < lb._makespan(blind.assignment, loads,
                                       np.asarray(rates))


def test_greedy_refine_keeps_balanced_assignment():
    """On a homogeneous, already-balanced system: zero migrations."""
    loads = np.ones(16)
    current = np.arange(16) % 4
    res = lb.greedy_refine(loads, 4, current=current)
    assert res.migrations == 0
    assert np.array_equal(res.assignment, current)


def test_no_lb_is_identity():
    loads = np.ones(8)
    cur = np.array([0, 0, 0, 0, 1, 1, 1, 1])
    res = lb.no_lb(loads, 2, current=cur)
    assert np.array_equal(res.assignment, cur)
    assert res.migrations == 0


# ------------------------------------------------------------ rate monitor
def test_rate_monitor_ewma_and_stragglers():
    mon = RateMonitor(4, alpha=0.5)
    for _ in range(10):
        mon.record_step([4, 4, 4, 4], [1.0, 1.0, 2.5, 1.0])
    r = mon.rates()
    assert r[2] < 0.6 * r[0]
    assert mon.straggler_pes(0.7) == [2]


def test_rate_monitor_resize_preserves_history():
    mon = RateMonitor(4)
    mon.record_step([1, 1, 1, 1], [1.0, 1.0, 4.0, 1.0])
    mon.resize(6)
    assert mon.rates().shape == (6,)
    assert mon.rates()[2] < mon.rates()[0]
