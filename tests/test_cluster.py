"""Serving-cluster behaviour tests (deterministic virtual clock).

The paper's claims transplanted onto serving:
  §III  rate-aware GreedyRefine routing beats rate-oblivious round-robin
        on a heterogeneous (2-fast / 2-slow) fleet;
  §IV   a spot interruption is drained proactively: every in-flight slot
        is checkpointed and re-admitted elsewhere, zero requests dropped,
        and the decoded continuations are bit-identical to an
        uninterrupted run.
"""

import jax
import numpy as np
import pytest

from repro.cluster import (InstanceType, RateAwareRouter, ReplicaState,
                           RoundRobinRouter, ServingCluster)
from repro.configs import get_config
from repro.core import loadbalance as lb
from repro.core.cloud import SpotEventFeed
from repro.models import model_zoo as zoo
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def model():
    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    return cfg, params


HETERO_FLEET = [InstanceType("fast.2x", 2.0), InstanceType("fast.2x", 2.0),
                InstanceType("slow.1x", 0.7), InstanceType("slow.1x", 0.7)]


def make_requests(n=16, seed=0):
    from repro.serving.workload import synthetic_requests
    return synthetic_requests(n, 200, seed=seed, prompt_len=(3, 8))


def run_cluster(model, router, *, interrupt_at=None, n=16, **kw):
    cfg, params = model
    cl = ServingCluster(cfg, params, HETERO_FLEET, router=router, dt=1.0,
                        batch_size=2, max_seq=32, **kw)
    reqs = make_requests(n)
    for r in reqs:
        cl.submit(r, at=0.0)
    if interrupt_at is not None:
        cl.inject_interruption(t=interrupt_at, replica_rid=0)
    out = cl.run(max_time=5000)
    return cl, reqs, out


# ----------------------------------------------------------------- routing
def test_rate_aware_beats_round_robin(model):
    _, _, rr = run_cluster(model, RoundRobinRouter())
    _, _, ra = run_cluster(model, RateAwareRouter())
    assert rr["dropped"] == 0 and ra["dropped"] == 0
    # makespan: the fleet drains strictly sooner under rate-aware routing
    assert ra["virtual_seconds"] < rr["virtual_seconds"], (ra, rr)
    assert ra["p99_latency"] < rr["p99_latency"], (ra, rr)
    assert ra["tok_per_s"] > rr["tok_per_s"], (ra, rr)


def test_virtual_clock_is_deterministic(model):
    _, _, a = run_cluster(model, RateAwareRouter())
    _, _, b = run_cluster(model, RateAwareRouter())
    assert a == b


def test_measured_rates_track_heterogeneity(model):
    cl, _, _ = run_cluster(model, RateAwareRouter())
    rates = cl.rates()
    fast = [rates[r.rid] for r in cl.replicas if r.itype.speed > 1]
    slow = [rates[r.rid] for r in cl.replicas if r.itype.speed < 1]
    assert min(fast) > max(slow), rates


# ----------------------------------------------------------------- drain
def test_interruption_drain_loses_nothing(model):
    _, base_reqs, _ = run_cluster(model, RateAwareRouter())
    cl, reqs, out = run_cluster(model, RateAwareRouter(), interrupt_at=3.0,
                                rebalance_lead=6.0, notice_deadline=4.0)
    assert out["dropped"] == 0
    assert out["completed"] == len(reqs)
    # the doomed replica's in-flight slots were checkpointed and migrated
    assert out["drains"] == 1
    assert out["migrated_slots"] > 0
    victim = cl.replica_by_rid(0)
    assert victim.state == ReplicaState.TERMINATED
    # greedy decode is placement-independent: every drained request's
    # continuation must be IDENTICAL to the uninterrupted run (no token
    # recomputed or lost through the checkpoint/restore migration)
    for a, b in zip(base_reqs, reqs):
        assert a.out_tokens == b.out_tokens, a.rid
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    # a replacement was pre-warmed at the rebalance recommendation
    assert any(r.ready_at > 0 for r in cl.replicas)


def test_drain_requeues_waiting_requests(model):
    """Queued (not yet admitted) work on the doomed replica is re-routed."""
    cfg, params = model
    cl = ServingCluster(cfg, params, HETERO_FLEET[:2],
                        router=RoundRobinRouter(), dt=1.0,
                        batch_size=2, max_seq=32,
                        rebalance_lead=2.0, notice_deadline=2.0)
    for r in make_requests(12, seed=1):
        cl.submit(r, at=0.0)
    cl.inject_interruption(t=1.0, replica_rid=0)
    out = cl.run(max_time=5000)
    assert out["dropped"] == 0 and out["completed"] == 12


# ----------------------------------------------------------------- scaling
def test_autoscaler_scales_up_under_backlog(model):
    cfg, params = model
    cl = ServingCluster(
        cfg, params, [InstanceType("base", 1.0)],
        router=RateAwareRouter(), dt=1.0, batch_size=2, max_seq=32,
        autoscaler_kw=dict(scale_up_backlog=16.0, scale_up_patience=2.0,
                           replacement_latency=3.0, max_replicas=3))
    for r in make_requests(24, seed=2):
        cl.submit(r, at=0.0)
    out = cl.run(max_time=5000)
    assert len(cl.replicas) > 1          # fleet grew
    assert out["dropped"] == 0 and out["completed"] == 24


# ----------------------------------------------------------------- pieces
def test_spot_feed_lifecycle_ordering():
    feed = SpotEventFeed(rebalance_lead=10.0, notice_deadline=5.0)
    feed.inject_interruption(t=100.0, target=7)
    assert feed.poll(99.9) == []
    (rec,) = feed.poll(100.0)
    assert rec.kind == "rebalance_recommendation" and rec.target == 7
    (notice,) = feed.poll(110.0)
    assert notice.kind == "interruption_notice"
    (term,) = feed.poll(1e9)
    assert term.kind == "terminate"
    assert feed.next_event_t == float("inf")


def test_greedy_refine_base_load():
    """Pinned in-flight load steers placement away from busy PEs."""
    res = lb.greedy([4.0, 4.0], 2, rates=[1.0, 1.0], base=[100.0, 0.0])
    assert (res.assignment == 1).all()
    res = lb.greedy_refine([4.0] * 6, 2, rates=[1.0, 1.0],
                           current=[0] * 6, base=[50.0, 0.0])
    # overloaded PE 0 donates work to the empty PE 1
    assert (res.assignment == 1).sum() > 0
    assert res.makespan <= res.baseline_makespan


def test_engine_snapshot_restore_exact(model):
    """Slot migration across engines resumes the exact continuation."""
    cfg, params = model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 200, 5, dtype=np.int32)
    e0 = ServingEngine(cfg, params, batch_size=2, max_seq=32)
    r0 = Request(rid=0, prompt=prompt.copy(), max_new_tokens=8)
    e0.submit(r0)
    e0.run_until_idle()
    e1 = ServingEngine(cfg, params, batch_size=2, max_seq=32)
    r1 = Request(rid=1, prompt=prompt.copy(), max_new_tokens=8)
    e1.submit(r1)
    for _ in range(4):          # prompt bulk-prefilled on admit, then decode
        e1.step()
    units, queued = e1.drain_units()
    assert len(units) == 1 and not queued
    assert 0 < len(r1.out_tokens) < r1.max_new_tokens
    e2 = ServingEngine(cfg, params, batch_size=2, max_seq=32)
    e2.unpack(units)
    e2.run_until_idle()
    assert r1.done and r1.out_tokens == r0.out_tokens
