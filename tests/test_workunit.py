"""WorkUnit lifecycle + ControlPlane policy tests.

The PR-5 tentpole invariants:

* **One verb set** — pack/unpack/preempt/resume are the only migration
  primitives; ANY interleaving of them round-trips to a bit-identical
  greedy token stream (deterministic cases for causal + ssm, mid-decode
  and mid-prefill-chunk, plus a hypothesis property over random
  interleavings).
* **Deprecation** — the old snapshot_slots/restore_slots/
  checkpoint_slots/drain names are gone; the verbs are the only API.
* **Endpoints** — migration payloads stage through the replica's
  ``MigrationEndpoint``; accelerator instances stage device-resident.
* **Policies** — SLO preemption frees batch slots for urgent interactive
  work (and resumes losslessly); cost-aware scaling shops the catalog by
  price-performance; per-replica dollar metering adds up.
"""

import jax
import numpy as np
import pytest

from repro.cluster import (BacklogScaling, ClusterMetrics, CostAwareScaling,
                           DeviceEndpoint, HostEndpoint, InstanceType,
                           Replica, ServingCluster, SLOPreemption)
from repro.configs import get_config
from repro.models import model_zoo as zoo
from repro.serving.engine import Request, ServingEngine
from repro.serving.workload import SLOClass
from repro.serving.workunit import PACKED, PAUSED

from tests._hypothesis_compat import given, settings, st

ARCHS = ["granite-8b", "mamba2-780m"]     # causal + ssm families


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        out[arch] = (cfg,
                     zoo.init_state(cfg, jax.random.PRNGKey(0)).params)
    return out


def _prompt(cfg, n, seed):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, n, dtype=np.int32)


def _engine(cfg, params, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_seq", 64)
    return ServingEngine(cfg, params, **kw)


def _reference_tokens(cfg, params, prompt, max_new):
    eng = _engine(cfg, params)
    req = Request(rid=99, prompt=prompt.copy(), max_new_tokens=max_new)
    eng.submit(req)
    eng.run_until_idle()
    assert req.done
    return req.out_tokens


# --------------------------------------------------- preempt/resume
@pytest.mark.parametrize("arch", ARCHS)
def test_preempt_resume_mid_decode_bit_identical(models, arch):
    """Pause a slot mid-generation; the resumed stream (on a DIFFERENT
    engine) matches the uninterrupted reference exactly."""
    cfg, params = models[arch]
    prompt = _prompt(cfg, 12, seed=1)
    ref = _reference_tokens(cfg, params, prompt, max_new=12)

    eng = _engine(cfg, params)
    req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=12)
    eng.submit(req)
    while eng.fed_tokens(0) <= len(prompt):      # cross into decode
        eng.step()
    units = eng.preempt()
    assert len(units) == 1
    u = units[0]
    assert u.state == PAUSED
    assert eng.preemptions == 1
    assert eng.n_active == 0                     # slot freed
    assert len(prompt) < u.progress < len(prompt) + 11   # mid-decode

    other = _engine(cfg, params)
    other.resume(units)
    assert u.state == PACKED and other.resumes == 1
    other.run_until_idle()
    assert req.done
    assert req.out_tokens == ref


@pytest.mark.parametrize("arch", ARCHS)
def test_preempt_resume_mid_prefill_chunk_bit_identical(models, arch):
    """Preempt right after the bulk prefill chunk, before the prompt is
    fully fed; the resumed continuation is still exact."""
    cfg, params = models[arch]
    prompt = _prompt(cfg, 30, seed=2)
    ref = _reference_tokens(cfg, params, prompt, max_new=8)

    eng = _engine(cfg, params, prefill_buckets=(16,))
    req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=8)
    eng.submit(req)
    eng.step()                   # admit: one 16-token chunk + 1 step
    assert eng.chunk_prefills == 1
    assert eng.fed_tokens(0) < len(prompt) - 1   # still mid-prefill
    units = eng.preempt()
    assert len(units) == 1 and units[0].progress < len(prompt)
    assert req.out_tokens == []

    other = _engine(cfg, params)
    other.resume(units)
    other.run_until_idle()
    assert req.done
    assert req.out_tokens == ref


def test_workunit_metadata(models):
    """Identity, SLO class, measured progress, and load accounting ride
    the unit across a pack -> unpack hop — and the uid plus the hop
    journal survive a re-pack (end-to-end traceability)."""
    cfg, params = models["granite-8b"]
    eng = _engine(cfg, params)
    slo = SLOClass("batch", 2, deadline=100.0, admit_lazily=True)
    req = Request(rid=7, prompt=_prompt(cfg, 6, seed=3),
                  max_new_tokens=10, slo=slo)
    eng.submit(req)
    for _ in range(3):
        eng.step()
    (u,) = eng.pack()
    assert u.state == PACKED and u.rid == 7
    assert u.slo_name == "batch" and u.preemptible
    assert u.progress == u.snapshot.fed > 0
    assert u.remaining_cost() > 0
    assert u.n_hops == 0
    u.record_hop(0, 1.0, "interruption")
    other = _engine(cfg, params)
    other.unpack([u])
    u.record_hop(1, 2.0, "land")
    assert u.n_hops == 2
    assert [(h.rid, h.reason) for h in u.hops] \
        == [(0, "interruption"), (1, "land")]
    # the admitted slot exposes the unit's identity and journal, and a
    # re-pack hands back the SAME uid with the journal intact
    other.step()
    (prov,) = other.slot_provenance().values()
    assert prov == (u.uid, tuple(u.hops))
    (again,) = other.pack()
    assert again.uid == u.uid and again.origin == u.origin
    assert [h.reason for h in again.hops] == ["interruption", "land"]
    # distinct units still never collide
    req2 = Request(rid=8, prompt=_prompt(cfg, 6, seed=4),
                   max_new_tokens=10, slo=slo)
    eng2 = _engine(cfg, params)
    eng2.submit(req2)
    eng2.step()
    (fresh,) = eng2.pack()
    assert fresh.uid != again.uid


@given(ops=st.lists(st.tuples(st.integers(0, 3),
                              st.sampled_from(["pack", "preempt"])),
                    min_size=1, max_size=4))
@settings(max_examples=8, deadline=None)
def test_any_interleaving_roundtrips_identically(models, ops):
    """Property: an arbitrary interleaving of run/pack/unpack/preempt/
    resume hops between two engines reproduces the reference stream."""
    cfg, params = models["granite-8b"]
    prompt = _prompt(cfg, 10, seed=4)
    ref = _reference_tokens(cfg, params, prompt, max_new=10)

    engines = [_engine(cfg, params), _engine(cfg, params)]
    req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=10)
    cur = 0
    engines[cur].submit(req)
    for steps, verb in ops:
        for _ in range(steps):
            engines[cur].step()
        if req.done:
            break
        units = (engines[cur].preempt() if verb == "preempt"
                 else engines[cur].pack())
        nxt = 1 - cur
        if verb == "preempt":
            engines[nxt].resume(units)
        else:
            engines[nxt].unpack(units)
        cur = nxt
    for _ in range(200):
        if req.done:
            break
        engines[cur].step()
    engines[cur].pop_completed()
    assert req.done
    assert req.out_tokens == ref


# ------------------------------------------------------- deprecation
def test_deprecated_verbs_removed(models):
    """The PR-5 deprecation shims are gone: the PUP verbs (pack/unpack/
    drain_units on the engine, pack_slots/unpack/drain_units on the
    replica) are the only spelling."""
    cfg, params = models["granite-8b"]
    eng = _engine(cfg, params)
    for old in ("snapshot_slots", "restore_slots", "drain"):
        assert not hasattr(eng, old), old
    rep = Replica(0, cfg, params, InstanceType("r0", 1.0),
                  batch_size=2, max_seq=64)
    for old in ("checkpoint_slots", "restore", "drain"):
        assert not hasattr(rep, old), old


# --------------------------------------------------------- endpoints
def test_accelerator_replica_stages_device_resident(models):
    """An accelerator InstanceType drains through the DeviceStore
    endpoint (HBM-to-HBM analogue) and the stream stays exact."""
    cfg, params = models["granite-8b"]
    prompt = _prompt(cfg, 8, seed=6)
    ref = _reference_tokens(cfg, params, prompt, max_new=10)

    src = Replica(0, cfg, params,
                  InstanceType("gpu.1x", 1.0, accelerator=True),
                  batch_size=2, max_seq=64)
    assert isinstance(src.endpoint, DeviceEndpoint)
    req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=10)
    src.submit(req)
    for _ in range(2):
        src.step_once(now=0.0)
    units, queued, (ckpt_s, restore_s) = src.drain_units()
    assert len(units) == 1 and not queued
    assert units[0].residency == "device"
    assert ckpt_s > 0.0 and restore_s > 0.0     # stages really ran

    dst = Replica(1, cfg, params, InstanceType("cpu.1x", 1.0),
                  batch_size=2, max_seq=64)
    assert isinstance(dst.endpoint, HostEndpoint)
    dst.unpack(units)
    while dst.has_work():
        dst.step_once(now=0.0)
    dst.engine.pop_completed()
    assert req.done and req.out_tokens == ref


# ----------------------------------------------------- cluster policy
def _mini_cluster(cfg, params, *, preempt, n_rep=1):
    fleet = [InstanceType("std.1x", 1.0, cost_per_hour=2.0)
             for _ in range(n_rep)]
    return ServingCluster(
        cfg, params, fleet, batch_size=2, max_seq=48, dt=1.0,
        decode_block=2,
        preemption=SLOPreemption() if preempt else None,
        autoscaler_kw=dict(scale_up_backlog=1e9, slo_scale_up=False))


def test_slo_preemption_frees_batch_for_interactive(models):
    """A batch-saturated replica pauses batch slots for an interactive
    surge; everything completes, streams match the no-preemption run."""
    cfg, params = models["granite-8b"]
    interactive = SLOClass("interactive", 0, deadline=16.0)
    batch = SLOClass("batch", 2, deadline=2000.0, admit_lazily=True)

    def reqs():
        rng = np.random.default_rng(11)
        out = [(0.0, Request(rid=i,
                             prompt=rng.integers(0, cfg.vocab_size, 6,
                                                 dtype=np.int32),
                             max_new_tokens=30, slo=batch))
               for i in range(2)]
        out += [(6.0, Request(rid=2 + i,
                              prompt=rng.integers(0, cfg.vocab_size, 4,
                                                  dtype=np.int32),
                              max_new_tokens=5, slo=interactive))
                for i in range(2)]
        return out

    outs = {}
    for preempt in (False, True):
        cl = _mini_cluster(cfg, params, preempt=preempt)
        rs = reqs()
        for at, r in rs:
            cl.submit(r, at=at)
        out = cl.run(max_time=5000)
        outs[preempt] = (rs, out)
        assert out["completed"] == 4 and out["dropped"] == 0

    (rs0, off), (rs1, on) = outs[False], outs[True]
    assert on["preemptions"] > 0
    assert on["resumes"] == on["preemptions"]    # nothing stays parked
    assert off["preemptions"] == 0
    # preemption strictly improves interactive latency, tokens unchanged
    assert (on["p99_latency_interactive"]
            < off["p99_latency_interactive"])
    for (_, a), (_, b) in zip(rs0, rs1):
        assert a.out_tokens == b.out_tokens, a.rid


def test_preemption_counts_in_traces(models):
    """The preempted batch request's trace records the pause."""
    cfg, params = models["granite-8b"]
    interactive = SLOClass("interactive", 0, deadline=16.0)
    batch = SLOClass("batch", 2, deadline=2000.0, admit_lazily=True)
    cl = _mini_cluster(cfg, params, preempt=True)
    rng = np.random.default_rng(12)
    for i in range(2):
        cl.submit(Request(rid=i,
                          prompt=rng.integers(0, cfg.vocab_size, 6,
                                              dtype=np.int32),
                          max_new_tokens=30, slo=batch), at=0.0)
    cl.submit(Request(rid=2,
                      prompt=rng.integers(0, cfg.vocab_size, 4,
                                          dtype=np.int32),
                      max_new_tokens=5, slo=interactive), at=6.0)
    out = cl.run(max_time=5000)
    assert out["completed"] == 3
    assert out["preemptions"] >= 1
    assert sum(tr.preemptions for tr in cl.metrics.traces.values()) \
        == out["preemptions"]
    assert all(tr.slo == "batch" for tr in cl.metrics.traces.values()
               if tr.preemptions)


# ------------------------------------------------------------ scaling
def test_cost_aware_scaling_shops_by_price_performance(models):
    """The catalog's best speed-per-dollar type wins scale-ups AND spot
    replacements; pool-incompatible entries are ignored."""
    cfg, params = models["granite-8b"]
    big = InstanceType("big.2x", 2.0, cost_per_hour=4.0)      # 0.5 /$
    lean = InstanceType("lean.1x", 1.0, cost_per_hour=0.8)    # 1.25/$
    other = InstanceType("other", 9.0, cost_per_hour=0.1,
                         model_id="other-pool")
    policy = CostAwareScaling([big, lean, other])
    cl = ServingCluster(cfg, params, [big], batch_size=2, max_seq=48,
                        scaling=policy)
    rep = cl.replicas[0]
    assert policy.select_itype(cl.view, "default", [rep]) is lean
    assert policy.replacement(cl.view, rep) is lean
    assert any("cost-aware pick lean.1x" in m for _, m in cl.timeline)
    with pytest.raises(ValueError):
        CostAwareScaling([])


def test_default_itype_pool_validated_at_construction(models):
    """A default_itype serving NO pool is rejected up front; a default
    serving a DIFFERENT pool is substituted with a logged fallback."""
    cfg, params = models["granite-8b"]
    fleet = [InstanceType("std.1x", 1.0)]
    with pytest.raises(ValueError, match="no fleet instance"):
        ServingCluster(cfg, params, fleet, batch_size=2, max_seq=48,
                       autoscaler_kw=dict(default_itype=InstanceType(
                           "ghost", 1.0, model_id="missing-pool")))
    # two pools, default belongs to pool "b": scaling pool "default"
    # must fall back to the pool's own type and log the substitution
    fleet2 = [InstanceType("std.1x", 1.0),
              InstanceType("b.1x", 1.0, model_id="b")]
    cl = ServingCluster(cfg, params, fleet2, batch_size=2, max_seq=48,
                        models={"b": (cfg, params)},
                        autoscaler_kw=dict(default_itype=fleet2[1]))
    policy = cl.autoscaler.policy
    picked = policy.select_itype(cl.view, "default", [cl.replicas[0]])
    assert picked is cl.replicas[0].itype
    assert any("using std.1x instead" in m for _, m in cl.timeline)


# ------------------------------------------------------------- dollars
def test_replica_dollar_metering():
    """Per-pool dollar cost integrates launch->terminate (or horizon)."""
    m = ClusterMetrics()
    m.on_launch(0, "a", model_id="default", cost_per_hour=3600.0, t=0.0)
    m.on_launch(1, "b", model_id="other", cost_per_hour=1800.0, t=100.0)
    m.on_terminate(0, 50.0)
    pools = m.pool_dollar_cost(horizon=200.0)
    assert pools["default"] == pytest.approx(50.0)    # retired at 50
    assert pools["other"] == pytest.approx(50.0)      # alive 100->200
    assert m.fleet_dollar_cost(200.0) == pytest.approx(100.0)
    # a replica launched after the horizon bills nothing (clamped)
    m.on_launch(2, "c", model_id="late", cost_per_hour=3600.0, t=500.0)
    assert m.pool_dollar_cost(200.0)["late"] == 0.0
