"""Paged KV/SSM cache tests (PR-7 tentpole).

* **Kernel** — the Pallas paged-attention kernel (interpret mode)
  matches the gather-based reference; the reference itself is
  *bit-identical* to the dense decode attention (masked positions
  contribute exact zeros), which is the root of every stream-equality
  claim below.  Sentinel (out-of-range) table entries are harmless.
* **Engine equivalence** — a paged engine emits bit-identical token
  streams to the dense engine across causal / ssm / hybrid families,
  including prompts longer than the largest prefill bucket (multi-chunk
  state-continued prefill) and pools smaller than ``lanes x max_seq``
  (capacity-gated admission).
* **Migration** — a mid-decode WorkUnit packs from a paged engine and
  unpacks into a paged engine with a DIFFERENT block size (and into a
  dense engine), resuming bit-identically: snapshots are canonical
  contiguous, so block geometry is a per-engine detail.
* **Block lifecycle** — hypothesis properties: any allocate/release
  interleaving on the ``BlockAllocator`` and any admit/step/preempt/
  resume/pack interleaving on a live engine never leaks or double-frees
  a block (the allocator partition invariant holds at every step).
* **Zero-sync** — steady-state paged decode performs no device->host
  fetches, same as dense.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import ClusterMetrics
from repro.configs import get_config
from repro.kernels.paged_attention import (gather_pages, paged_attention,
                                           paged_attention_ref)
from repro.kernels.paged_attention.kernel import paged_attention as \
    paged_kernel
from repro.models import model_zoo as zoo
from repro.models.layers import full_attention
from repro.serving.engine import BlockAllocator, Request, ServingEngine

from tests._hypothesis_compat import given, settings, st

ARCHS = ["granite-8b", "mamba2-780m", "zamba2-2.7b"]


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        out[arch] = (cfg,
                     zoo.init_state(cfg, jax.random.PRNGKey(0)).params)
    return out


def _requests(n, seed=0, plen=(3, 24), max_new=(4, 10), vocab=250):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(
                        1, vocab, rng.integers(*plen)).astype(np.int32),
                    max_new_tokens=int(rng.integers(*max_new)))
            for i in range(n)]


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    engine.run_until_idle()
    assert all(r.done for r in reqs)
    return {r.rid: list(r.out_tokens) for r in reqs}


def _engine(cfg, params, **kw):
    kw.setdefault("batch_size", 3)
    kw.setdefault("max_seq", 96)
    kw.setdefault("prefill_buckets", (16, 64))
    return ServingEngine(cfg, params, **kw)


# ------------------------------------------------------------- kernel
@pytest.mark.parametrize("heads,kv_heads,blocks_used", [(4, 4, 3),
                                                        (8, 2, 4)])
def test_paged_kernel_matches_ref(heads, kv_heads, blocks_used):
    b, d, bs, nb, mb = 3, 16, 8, 12, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, heads, d), jnp.float32)
    k_pool = jax.random.normal(ks[1], (nb, bs, kv_heads, d), jnp.float32)
    v_pool = jax.random.normal(ks[2], (nb, bs, kv_heads, d), jnp.float32)
    rng = np.random.default_rng(3)
    bt = np.full((b, mb), nb, np.int32)
    kv_len = np.zeros(b, np.int32)
    for i in range(b):
        used = rng.permutation(nb)[:blocks_used]
        bt[i, :blocks_used] = used
        kv_len[i] = rng.integers(1, blocks_used * bs + 1)
    bt, kv_len = jnp.asarray(bt), jnp.asarray(kv_len)
    ref = paged_attention_ref(q, k_pool, v_pool, bt, kv_len)
    out = paged_kernel(q, k_pool, v_pool, jnp.clip(bt, 0, nb - 1), kv_len,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_ref_bit_identical_to_dense_attention():
    """Gather-through-the-table + full_attention == dense decode
    attention, bit for bit — including with sentinel table entries and
    garbage in unreferenced pool blocks."""
    b, h, d, bs, nb, mb = 2, 4, 16, 8, 10, 3
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    dense_k = jax.random.normal(ks[1], (b, mb * bs, h, d), jnp.float32)
    dense_v = jax.random.normal(ks[2], (b, mb * bs, h, d), jnp.float32)
    kv_len = jnp.asarray([5, 17], jnp.int32)
    # scatter the dense rows into arbitrary pool blocks + garbage rest
    pool_k = jax.random.normal(ks[3], (nb, bs, h, d), jnp.float32) * 50
    pool_v = pool_k + 1.0
    bt = np.full((b, mb), nb, np.int32)     # sentinel everywhere...
    rng = np.random.default_rng(0)
    rows = rng.permutation(nb)[:b * mb].reshape(b, mb)
    for i in range(b):
        n_needed = -(-int(kv_len[i]) // bs)
        bt[i, :n_needed] = rows[i, :n_needed]   # ...except live blocks
        for j in range(n_needed):
            blk = rows[i, j]
            pool_k = pool_k.at[blk].set(dense_k[i, j * bs:(j + 1) * bs])
            pool_v = pool_v.at[blk].set(dense_v[i, j * bs:(j + 1) * bs])
    ref = full_attention(q, dense_k, dense_v, causal=False,
                         kv_len=kv_len)[:, 0]
    out = paged_attention_ref(q[:, 0], pool_k, pool_v, jnp.asarray(bt),
                              kv_len)
    assert bool(jnp.all(out == ref))
    # and the jit'd dispatch entry point agrees with itself on ref impl
    out2 = paged_attention(q[:, 0], pool_k, pool_v, jnp.asarray(bt),
                           kv_len, impl="ref")
    assert bool(jnp.all(out2 == ref))


def test_gather_pages_clamps_sentinels():
    pool = jnp.arange(4 * 2 * 1 * 2, dtype=jnp.float32).reshape(4, 2, 1, 2)
    bt = jnp.asarray([[1, 4, 4]], jnp.int32)     # 4 == sentinel (nb)
    rows = gather_pages(pool, bt)
    assert rows.shape == (1, 6, 1, 2)
    assert bool(jnp.all(rows[0, :2] == pool[1]))  # real block intact


# ----------------------------------------------------- engine equivalence
@pytest.mark.parametrize("arch", ARCHS)
def test_paged_engine_bit_identical(models, arch):
    cfg, params = models[arch]
    dense = _run(_engine(cfg, params), _requests(8, seed=2))
    paged = _run(_engine(cfg, params, cache_mode="paged", block_size=8),
                 _requests(8, seed=2))
    assert dense == paged


def test_multichunk_long_prompt_bit_identical(models):
    """Prompts beyond the largest bucket: the paged engine appends
    multiple state-continued chunks (no streamed tail for pad-safe
    families) and still matches dense exactly."""
    cfg, params = models["granite-8b"]
    reqs = _requests(3, seed=7, plen=(70, 93), max_new=(3, 6))
    dense = _run(_engine(cfg, params),
                 [Request(r.rid, r.prompt.copy(), r.max_new_tokens)
                  for r in reqs])
    eng = _engine(cfg, params, cache_mode="paged", block_size=8)
    paged = _run(eng, reqs)
    assert dense == paged
    assert eng.chunk_prefills > len(reqs)    # > one chunk per request


def test_small_pool_capacity_gated(models):
    """A pool far smaller than lanes x max_seq still completes every
    request bit-identically — admission queues on free blocks instead
    of overcommitting."""
    cfg, params = models["granite-8b"]
    dense = _run(_engine(cfg, params),
                 _requests(6, seed=9, plen=(3, 12), max_new=(3, 6)))
    eng = _engine(cfg, params, cache_mode="paged", block_size=8,
                  kv_pool_blocks=6)
    paged = _run(eng, _requests(6, seed=9, plen=(3, 12), max_new=(3, 6)))
    assert dense == paged
    assert eng.occupancy()["peak_blocks_in_use"] <= 6


# ------------------------------------------------------------ migration
@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-780m"])
def test_cross_block_size_migration(models, arch):
    """Mid-decode pack from block_size=4, unpack into block_size=16:
    resumed streams match the uninterrupted dense reference exactly."""
    cfg, params = models[arch]
    reqs = _requests(3, seed=3, plen=(5, 30), max_new=(6, 12))
    ref = _run(_engine(cfg, params),
               [Request(r.rid, r.prompt.copy(), r.max_new_tokens)
                for r in reqs])
    src = _engine(cfg, params, cache_mode="paged", block_size=4)
    for r in reqs:
        src.submit(r)
    src.step_many(3)
    units = src.pack()
    assert units and src.n_active == 0
    src._alloc.check_invariants()
    assert src._alloc.free_count == src.pool_blocks   # all returned
    dst = _engine(cfg, params, cache_mode="paged", block_size=16)
    dst.unpack(units)
    dst.run_until_idle()
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref


def test_paged_to_dense_migration(models):
    cfg, params = models["granite-8b"]
    reqs = _requests(3, seed=5, plen=(6, 20), max_new=(5, 9))
    ref = _run(_engine(cfg, params),
               [Request(r.rid, r.prompt.copy(), r.max_new_tokens)
                for r in reqs])
    src = _engine(cfg, params, cache_mode="paged", block_size=8)
    for r in reqs:
        src.submit(r)
    src.step_many(4)
    units = src.pack()
    dst = _engine(cfg, params)                        # dense target
    dst.unpack(units)
    dst.run_until_idle()
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref


# -------------------------------------------------------- block lifecycle
@given(ops=st.lists(st.tuples(st.integers(0, 1), st.integers(0, 7),
                              st.integers(1, 6)), max_size=60))
@settings(max_examples=50, deadline=None)
def test_block_allocator_never_leaks(ops):
    """Any allocate/release interleaving keeps free + owned an exact
    partition of the pool; misuse raises instead of corrupting."""
    alloc = BlockAllocator(16)
    for kind, slot, n in ops:
        if kind == 0:
            if slot in alloc._owned or not alloc.can_allocate(n):
                with pytest.raises(ValueError):
                    alloc.allocate(slot, n)
            else:
                blocks = alloc.allocate(slot, n)
                assert len(blocks) == len(set(blocks)) == n
        else:
            if slot in alloc._owned:
                alloc.release(slot)
            else:
                with pytest.raises(ValueError):
                    alloc.release(slot)
        alloc.check_invariants()
    assert alloc.peak_in_use <= alloc.num_blocks


@given(script=st.lists(st.integers(0, 4), min_size=1, max_size=12))
@settings(max_examples=6, deadline=None)
def test_engine_interleaving_never_leaks_blocks(models, script):
    """Random admit/step/preempt/resume/pack interleavings on a live
    paged engine: the allocator partition invariant holds after every
    op, and a drained engine has every block back in the pool."""
    cfg, params = models["granite-8b"]
    eng = _engine(cfg, params, cache_mode="paged", block_size=8,
                  kv_pool_blocks=18)
    rng = np.random.default_rng(0)
    rid = [0]
    parked = []

    def submit():
        eng.submit(Request(rid=rid[0],
                           prompt=rng.integers(1, 250, int(
                               rng.integers(3, 14))).astype(np.int32),
                           max_new_tokens=int(rng.integers(3, 7))))
        rid[0] += 1

    for op in script:
        if op == 0:
            submit()
        elif op == 1:
            eng.step_many(2)
        elif op == 2:
            occupied = [s for s, r in enumerate(eng._slots)
                        if r is not None]
            if occupied:
                parked.extend(eng.preempt(occupied[:1]))
        elif op == 3 and parked:
            eng.resume([parked.pop(0)])
        elif op == 4:
            eng.unpack(eng.pack())
        eng._alloc.check_invariants()
        assert eng._alloc.in_use <= eng.pool_blocks
    eng.resume(parked)
    eng.run_until_idle()
    eng._alloc.check_invariants()
    assert eng._alloc.free_count == eng.pool_blocks


# ------------------------------------------------------------- zero-sync
def test_paged_steady_state_is_sync_free(models):
    cfg, params = models["granite-8b"]
    eng = ServingEngine(cfg, params, cache_mode="paged", block_size=8,
                        batch_size=2, max_seq=96, prefill_buckets=(16,))
    for r in _requests(2, seed=1, plen=(4, 8), max_new=(40, 41)):
        eng.submit(r)
    eng.step_many(4)                       # admission window
    syncs0 = eng.host_syncs
    for _ in range(5):
        eng.step_many(4)                   # nobody completes here
    assert eng.host_syncs == syncs0
    eng.run_until_idle()


# ----------------------------------------------------- occupancy metrics
def test_occupancy_threads_into_cluster_summary(models):
    cfg, params = models["granite-8b"]
    eng = _engine(cfg, params, cache_mode="paged", block_size=8)
    _run(eng, _requests(5, seed=4))
    occ = eng.occupancy()
    assert occ["max_concurrent_slots"] >= 1
    assert 0 < occ["peak_blocks_in_use"] <= occ["pool_blocks"]
    assert occ["active_slots"] == occ["blocks_in_use"] == 0   # drained

    metrics = ClusterMetrics()
    metrics.on_launch(0, "t.small")
    metrics.on_occupancy(0, occ)
    metrics.on_occupancy(99, occ)          # unknown replica: ignored
    summary = metrics.summary(now=1.0)
    assert summary["max_concurrent_slots"] == occ["max_concurrent_slots"]
    assert summary["peak_block_occupancy"] == pytest.approx(
        occ["peak_blocks_in_use"] / occ["pool_blocks"])


def test_dense_engine_occupancy_is_slot_only(models):
    cfg, params = models["granite-8b"]
    eng = _engine(cfg, params)
    _run(eng, _requests(4, seed=6))
    occ = eng.occupancy()
    assert occ["max_concurrent_slots"] >= 1
    assert occ["pool_blocks"] == occ["peak_blocks_in_use"] == 0
