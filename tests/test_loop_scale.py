"""EventLoop at scale: the three hot-path fixes behind the
million-request scenario matrix, each pinned by a regression test.

  * ``run(max_events=...)`` raises instead of silently truncating a
    simulation that still has live work due (a truncated sim must not
    report partial metrics as if complete);
  * cancelled-entry heap compaction keeps the heap proportional to
    LIVE events and is provably order-preserving: the dispatch journal
    is bit-identical to an uncompacted reference;
  * ``pending`` is an O(1) counter, exact under any mix of schedule /
    cancel / dispatch, and the CRC journal digest is identical whether
    or not the full journal list is retained.
"""

import math

import pytest

from repro.runtime import EventLoop, VirtualClock


def _loop(journal=True):
    loop = EventLoop(VirtualClock(), journal=journal)
    loop.register("noop", lambda ev, t: None)
    return loop


# ------------------------------------------------------- max_events guard
def test_run_raises_when_cap_truncates_live_work():
    loop = _loop()

    def rearm(ev, t):
        loop.schedule(t + 1.0, "chain")

    loop.register("chain", rearm)
    loop.schedule(0.0, "chain")
    with pytest.raises(RuntimeError, match=r"max_events=25"):
        loop.run(until=math.inf, max_events=25)


def test_run_cap_error_names_the_next_due_event():
    loop = _loop()
    for i in range(10):
        loop.schedule(float(i), "noop")
    with pytest.raises(RuntimeError, match=r"next at t=5"):
        loop.run(max_events=5)


def test_run_exact_cap_with_drained_loop_is_fine():
    loop = _loop()
    for i in range(10):
        loop.schedule(float(i), "noop")
    assert loop.run(max_events=10) == 10      # drained AT the cap: ok
    assert loop.pending == 0


def test_run_cap_ignores_events_beyond_until():
    loop = _loop()
    for i in range(10):
        loop.schedule(float(i), "noop")
    # only 3 events are due at t<=2.5; the rest are beyond the horizon,
    # so a cap of 3 truncates nothing
    assert loop.run(until=2.5, max_events=3) == 3


# ---------------------------------------------------------- compaction
def test_compaction_triggers_and_shrinks_the_heap():
    loop = _loop()
    evs = [loop.schedule(float(i), "noop") for i in range(1000)]
    for ev in evs[::2]:
        loop.cancel(ev)
    assert loop.compactions >= 1
    assert len(loop._heap) == loop.pending == 500


def test_compaction_journal_bit_identical_to_small_reference():
    """Drive the same schedule/cancel pattern at a size that compacts
    and assert the surviving dispatch order equals the (t, seq)-sorted
    survivors — the order an uncompacted heap would produce."""
    loop = _loop()
    evs = [loop.schedule(float(i % 97) * 0.5, "noop", i=i)
           for i in range(2000)]
    cancelled = {id(ev) for ev in evs if ev.seq % 3 != 0}
    for ev in evs:
        if id(ev) in cancelled:
            loop.cancel(ev)
    assert loop.compactions >= 1
    expected = sorted((ev.t, ev.seq, ev.kind) for ev in evs
                      if id(ev) not in cancelled)
    assert loop.run() == len(expected)
    assert loop.journal == expected


def test_compaction_digest_matches_cancel_order_permutation():
    """The same cancelled SET in a different cancel ORDER (different
    compaction points) must still dispatch bit-identically."""
    def drive(order):
        loop = _loop()
        evs = [loop.schedule(float(i) * 0.25, "noop") for i in range(1200)]
        doomed = [ev for ev in evs if ev.seq % 2 == 0]
        for ev in (doomed if order == "fwd" else doomed[::-1]):
            loop.cancel(ev)
        loop.run()
        return loop.journal_digest, loop.journal

    d_fwd, j_fwd = drive("fwd")
    d_rev, j_rev = drive("rev")
    assert d_fwd == d_rev
    assert j_fwd == j_rev


def test_cancelled_events_never_dispatch_after_compaction():
    loop = _loop()
    seen = []
    loop.register("mark", lambda ev, t: seen.append(ev.payload["i"]))
    evs = [loop.schedule(float(i), "mark", i=i) for i in range(500)]
    for ev in evs:
        if ev.payload["i"] % 2 == 1:
            loop.cancel(ev)
    loop.run()
    assert seen == list(range(0, 500, 2))


# ------------------------------------------------------- O(1) pending
def test_pending_tracks_schedule_cancel_dispatch_exactly():
    loop = _loop()
    evs = [loop.schedule(float(i), "noop") for i in range(300)]
    assert loop.pending == 300
    for ev in evs[:100]:
        loop.cancel(ev)
    assert loop.pending == 200
    loop.cancel(evs[0])                 # double-cancel: no double count
    assert loop.pending == 200
    loop.run(until=150.0)
    assert loop.pending == 300 - 100 - sum(1 for ev in evs[100:]
                                           if ev.t <= 150.0)
    loop.run()
    assert loop.pending == 0
    loop.cancel(evs[-1])                # cancel-after-dispatch: no-op
    assert loop.pending == 0


# ------------------------------------------------- digest vs journal mode
def test_digest_identical_with_journal_off():
    def drive(journal):
        loop = _loop(journal=journal)
        for i in range(200):
            loop.schedule(float(i % 13), "noop")
        loop.run()
        return loop

    on, off = drive(True), drive(False)
    assert on.journal_digest == off.journal_digest != 0
    assert len(on.journal) == 200
    assert off.journal == []            # bounded memory: digest only
