"""Behaviour-shaped arrival generators (serving/shapes.py): seeded
determinism, stream invariants, per-segment rate fidelity, and the
reduced scenario-matrix smoke with bit-identical event journals.

The acceptance bar for the million-request load library:
  * same seed -> bit-identical (t, rid, prompt, max_new) streams, and
    the stream is re-iterable (it is a generator recipe, not a spent
    iterator);
  * timestamps never decrease, exactly ``n`` requests are produced,
    rids are sequential from ``start_rid``;
  * empirical per-segment arrival counts track each shape's nominal
    ``segments()`` rate profile (Poisson tolerance);
  * the reduced matrix cell drives the REAL cluster twice to the same
    ``journal_digest`` and summary, with the digest independent of
    whether the full journal is retained.
"""

import numpy as np
import pytest

from repro.cluster import InstanceType, RateAwareRouter, ServingCluster
from repro.serving.shapes import SHAPES, ShapedArrivals, make_shape

ALL_SHAPES = sorted(SHAPES)


def _stream(name, n=400, rate=8.0, period=40.0, seed=5):
    return make_shape(name, n, rate=rate, period=period, seed=seed)


def _key(t, req):
    return (t, req.rid, req.prompt.tobytes(), req.max_new_tokens,
            req.slo.name, req.model_id)


# ------------------------------------------------------------ determinism
@pytest.mark.parametrize("name", ALL_SHAPES)
def test_same_seed_bit_identical_stream(name):
    a = [_key(t, r) for t, r in _stream(name)]
    b = [_key(t, r) for t, r in _stream(name)]
    assert a == b


@pytest.mark.parametrize("name", ALL_SHAPES)
def test_reiterable_not_a_spent_iterator(name):
    shape = _stream(name, n=50)
    assert [t for t, _ in shape] == [t for t, _ in shape]


@pytest.mark.parametrize("name", ALL_SHAPES)
def test_different_seed_different_stream(name):
    a = [t for t, _ in _stream(name, seed=5)]
    b = [t for t, _ in _stream(name, seed=6)]
    assert a != b


# ------------------------------------------------------- stream invariants
@pytest.mark.parametrize("name", ALL_SHAPES)
def test_monotone_count_and_rids(name):
    pairs = list(_stream(name))
    assert len(pairs) == 400
    ts = [t for t, _ in pairs]
    assert all(b >= a for a, b in zip(ts, ts[1:]))
    assert ts[0] >= 0.0
    assert [r.rid for _, r in pairs] == list(range(400))


def test_start_rid_offsets_the_stream():
    shape = make_shape("sawtooth", 10, rate=5.0, seed=1)
    shape.start_rid = 700
    assert [r.rid for _, r in shape] == list(range(700, 710))


def test_rate_max_is_an_envelope():
    for name in ALL_SHAPES:
        shape = _stream(name, n=1)
        ts = np.linspace(0.0, 200.0, 4001)
        assert max(shape.rate(float(t)) for t in ts) <= shape.rate_max + 1e-9


# --------------------------------------------------- segment rate fidelity
@pytest.mark.parametrize("name", ALL_SHAPES)
def test_per_segment_empirical_rate(name):
    """Pool same-rate segments of the nominal profile and hold the
    empirical arrival count to the Poisson expectation (5 sigma)."""
    n, rate = 4000, 20.0
    pairs = list(_stream(name, n=n, rate=rate, period=40.0, seed=9))
    ts = np.asarray([t for t, _ in pairs])
    until = float(ts[-1]) + 1e-9
    pooled = {}  # rounded nominal rate -> [duration, observed]
    profile = _stream(name, n=1, rate=rate, period=40.0)
    for start, end, seg_rate in profile.segments(until):
        key = round(seg_rate, 6)
        dur = end - start
        obs = int(np.sum((ts >= start) & (ts < end)))
        acc = pooled.setdefault(key, [0.0, 0])
        acc[0] += dur
        acc[1] += obs
    assert sum(o for _, o in pooled.values()) == n
    for seg_rate, (dur, obs) in pooled.items():
        exp = seg_rate * dur
        assert abs(obs - exp) <= 5.0 * np.sqrt(exp) + 1.0, (
            f"{name}: pooled rate {seg_rate}: observed {obs} vs "
            f"expected {exp:.1f} over {dur:.1f}s")


@pytest.mark.parametrize("name", ALL_SHAPES)
def test_long_run_mean_tracks_target_rate(name):
    n, rate = 4000, 20.0
    ts = [t for t, _ in _stream(name, n=n, rate=rate, period=40.0, seed=2)]
    assert ts[-1] == pytest.approx(n / rate, rel=0.12)


def test_make_shape_unknown_name():
    with pytest.raises(ValueError, match="unknown shape"):
        make_shape("nope", 10, rate=1.0)


def test_base_class_is_abstract():
    shape = ShapedArrivals(3)
    with pytest.raises(NotImplementedError):
        shape.rate(0.0)


# --------------------------------------------- reduced matrix cell smoke
def _matrix_cell(journal=True, retain_traces=True, seed=3):
    fleet = [InstanceType("std.1x", 4.0, spot=False) for _ in range(2)]
    cl = ServingCluster(None, None, fleet, engine="sim",
                        router=RateAwareRouter(place_cap=16),
                        batch_size=8, max_seq=64, decode_block=4,
                        seed=0, journal=journal,
                        retain_traces=retain_traces)
    cl.attach_arrivals(make_shape("pulse_spikes", 80, rate=1.5,
                                  period=30.0, seed=seed))
    summary = cl.run(max_time=50_000.0)
    return cl, summary


def test_matrix_cell_journal_bit_identical_across_runs():
    cl1, s1 = _matrix_cell()
    cl2, s2 = _matrix_cell()
    assert cl1.loop.journal == cl2.loop.journal
    assert cl1.loop.journal_digest == cl2.loop.journal_digest
    assert s1["completed"] == s2["completed"] == 80
    assert s1["tok_per_s"] == s2["tok_per_s"]
    assert s1["p99_latency"] == s2["p99_latency"]


def test_matrix_cell_digest_independent_of_journal_retention():
    """The bounded-memory path (journal=False, streaming metrics) must
    replay the exact same event timeline as the full-capture run."""
    cl_full, s_full = _matrix_cell(journal=True, retain_traces=True)
    cl_lean, s_lean = _matrix_cell(journal=False, retain_traces=False)
    assert cl_lean.loop.journal == []
    assert cl_lean.loop.journal_digest == cl_full.loop.journal_digest
    assert s_lean["completed"] == s_full["completed"]
    assert s_lean["tok_per_s"] == s_full["tok_per_s"]


def test_streaming_metrics_keep_no_per_request_traces():
    cl, s = _matrix_cell(retain_traces=False)
    assert s["completed"] == 80
    assert len(cl.metrics.traces) == 0
