"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_ref
from repro.kernels.jacobi.kernel import jacobi_step
from repro.kernels.jacobi.ref import jacobi_step_ref
from repro.kernels.ssd.kernel import ssd_intra_chunk
from repro.models.mamba2 import ssd_intra_chunk_ref


# --------------------------------------------------------------- jacobi
@pytest.mark.parametrize("H,W,bh", [
    (64, 64, 16), (128, 64, 64), (64, 128, 64), (256, 32, 32), (32, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_jacobi_kernel(H, W, bh, dtype):
    g = jax.random.normal(jax.random.PRNGKey(0), (H, W)).astype(dtype)
    out = jacobi_step(g, block_rows=bh, interpret=True)
    ref = jacobi_step_ref(g)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    assert out.shape == ref.shape and out.dtype == ref.dtype
    assert float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max()) < tol


def test_jacobi_multi_sweep_matches_reference():
    from repro.core.spmd_stencil import reference_jacobi
    g = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    a, b = g, g
    for _ in range(5):
        a = jacobi_step(a, block_rows=16, interpret=True)
    b = reference_jacobi(g, 5)
    assert float(jnp.abs(a - b).max()) < 1e-5


# --------------------------------------------------------------- flash
@pytest.mark.parametrize("b,h,kv,s,d,bq,bkv", [
    (1, 4, 2, 128, 32, 32, 32),
    (2, 8, 8, 64, 16, 32, 16),
    (1, 4, 4, 128, 64, 64, 64),
    (1, 6, 3, 96, 32, 32, 32),
    (1, 2, 1, 64, 16, 16, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel(b, h, kv, s, d, bq, bkv, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, kv, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, kv, s, d), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_kv=bkv,
                          interpret=True)
    ref = flash_ref(q, k, v, causal=causal)
    assert float(jnp.abs(out - ref).max()) < 2e-5


def test_flash_kernel_bf16():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 64, 32)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 64, 32)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 64, 32)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_kv=32,
                          interpret=True)
    ref = flash_ref(q, k, v, causal=True)
    assert float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max()) < 3e-2


# --------------------------------------------------------------- ssd
@pytest.mark.parametrize("b,nc,l,h,p,n", [
    (1, 2, 16, 2, 8, 16),
    (2, 1, 32, 4, 16, 8),
    (1, 3, 8, 1, 4, 4),
    (1, 1, 64, 2, 32, 16),
])
def test_ssd_kernel(b, nc, l, h, p, n):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    xr = jax.random.normal(ks[0], (b, nc, l, h, p))
    dtr = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, l, h)))
    dA = -jnp.abs(jax.random.normal(ks[2], (b, nc, l, h))) * 0.1
    dA_cs = jnp.cumsum(dA, axis=2)
    Br = jax.random.normal(ks[3], (b, nc, l, n))
    Cr = jax.random.normal(ks[4], (b, nc, l, n))
    y1, s1 = ssd_intra_chunk(xr, dtr, dA_cs, Br, Cr, interpret=True)
    y2, s2 = ssd_intra_chunk_ref(xr, dtr, dA_cs, Br, Cr)
    assert float(jnp.abs(y1 - y2).max()) < 1e-4
    assert float(jnp.abs(s1 - s2).max()) < 1e-4


def test_ssd_chunked_equals_sequential_recurrence():
    """Chunked SSD (any chunk size) == naive per-token state recurrence."""
    from repro.models.mamba2 import ssd_chunked
    b, s, h, p, n = 1, 32, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.abs(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(jax.random.PRNGKey(4), (b, s, n))

    # naive recurrence
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t] * A[None])                      # (b,h)
        xdt = x[:, t] * dt[:, t][..., None]                   # (b,h,p)
        state = state * dA[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xdt, B[:, t])
        ys.append(jnp.einsum("bhpn,bn->bhp", state, C[:, t]))
    y_ref = jnp.stack(ys, axis=1)

    for chunk in (8, 16, 32):
        y, final = ssd_chunked(x, dt, A, B, C, chunk)
        assert float(jnp.abs(y - y_ref).max()) < 1e-3, chunk
        assert float(jnp.abs(final - state).max()) < 1e-3, chunk
