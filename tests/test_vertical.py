"""Vertical elasticity tests: in-place resize + QoS-classed capacity.

The tentpole invariants:

* **Bit-identity** — a mid-flight ``resize`` (grow or shrink, dense or
  paged or sim, causal or ssm) never changes a surviving stream: final
  tokens match a never-resized reference exactly, and evicted units
  resume to the identical continuation.
* **Conservation** — any interleaving of resize/preempt/resume keeps
  every WorkUnit accounted for (active + paused + queued + done covers
  all submissions) and, for paged engines, keeps the block allocator's
  free + owned partition exact.
* **QoS** — SLO classes map onto Guaranteed/Burstable/BestEffort;
  shrinks evict BestEffort first; BestEffort arrivals hold at the door
  until the pool has idle capacity beyond the Guaranteed reservation.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model_zoo as zoo
from repro.serving.engine import Request, ServingEngine
from repro.serving.simengine import SimEngine, sim_token
from repro.serving.workload import (BATCH, INTERACTIVE, STANDARD,
                                    SLOClass, classed_requests,
                                    synthetic_requests)
from repro.serving.workunit import PAUSED
from repro.cluster import (CheckpointPolicy, FailureDetector, InstanceType,
                           ResizeOrder, ServingCluster,
                           VerticalScalingPolicy)
from repro.vertical import (BEST_EFFORT, BURSTABLE, GUARANTEED,
                            FixedThresholdVertical, QoSPolicy,
                            SlidingWindowVertical, qos_for)

from tests._hypothesis_compat import given, settings, st

ARCHS = ["granite-8b", "mamba2-780m"]     # causal + ssm families


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        out[arch] = (cfg,
                     zoo.init_state(cfg, jax.random.PRNGKey(0)).params)
    return out


def _requests(n, seed=3, max_new=6):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(
                        0, 200, int(rng.integers(3, 20))).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _reference_tokens(cfg, params, reqs_factory, **kw):
    """Final streams from a never-resized engine big enough for all."""
    reqs = reqs_factory()
    eng = ServingEngine(cfg, params, batch_size=len(reqs), **kw)
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    return [list(r.out_tokens) for r in reqs]


# ----------------------------------------------------------- bit-identity
@pytest.mark.parametrize("arch", ARCHS)
def test_grow_mid_flight_bit_identical(models, arch):
    """Grow 2 -> 4 lanes mid-decode: the surviving streams and the
    newly-admitted queue both finish exactly as a never-resized engine."""
    cfg, params = models[arch]
    mk = lambda: _requests(4)                               # noqa: E731
    ref = _reference_tokens(cfg, params, mk, max_seq=64)
    reqs = mk()
    eng = ServingEngine(cfg, params, batch_size=2, max_seq=64)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    evicted = eng.resize(batch_size=4)
    assert evicted == [] and eng.resizes == 1
    eng.run_until_idle()
    assert [list(r.out_tokens) for r in reqs] == ref


@pytest.mark.parametrize("arch", ARCHS)
def test_shrink_evict_resume_bit_identical(models, arch):
    """Shrink 4 -> 2 evicts the least-progressed units as PAUSED;
    resuming them continues every stream bit-identically."""
    cfg, params = models[arch]
    mk = lambda: _requests(4)                               # noqa: E731
    ref = _reference_tokens(cfg, params, mk, max_seq=64)
    reqs = mk()
    eng = ServingEngine(cfg, params, batch_size=4, max_seq=64)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    evicted = eng.resize(batch_size=2)
    assert len(evicted) == 2 and eng.resize_evictions == 2
    assert all(u.state is PAUSED for u in evicted)
    eng.resume(evicted)
    eng.run_until_idle()
    assert [list(r.out_tokens) for r in reqs] == ref


def test_paged_resize_grow_shrink_and_pool(models):
    """Paged cache: grow re-pools by default, an explicit kv_pool_blocks
    resize re-blocks through the canonical snapshot path, and the block
    allocator's partition stays exact across every transition."""
    cfg, params = models["granite-8b"]
    mk = lambda: _requests(4)                               # noqa: E731
    ref = _reference_tokens(cfg, params, mk, max_seq=64,
                            cache_mode="paged", block_size=8)
    reqs = mk()
    eng = ServingEngine(cfg, params, batch_size=2, max_seq=64,
                        cache_mode="paged", block_size=8)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    assert eng.resize(batch_size=4) == []     # grow: default pool scales
    assert eng.pool_blocks == 4 * eng.max_blocks
    eng._alloc.check_invariants()
    for _ in range(2):
        eng.step()
    # explicit pool change (same lanes): pure re-block, nothing evicted
    assert eng.resize(kv_pool_blocks=4 * eng.max_blocks + 3) == []
    eng._alloc.check_invariants()
    evicted = eng.resize(batch_size=2)        # shrink evicts two
    assert len(evicted) == 2
    eng._alloc.check_invariants()
    eng.resume(evicted)
    eng.run_until_idle()
    eng._alloc.check_invariants()
    assert [list(r.out_tokens) for r in reqs] == ref


def test_sim_engine_resize_mirrors_real(models):
    """SimEngine speaks the same resize verb: grow admits the queue,
    shrink evicts PAUSED units, resumed streams stay the deterministic
    ``sim_token`` sequence."""
    del models
    reqs = _requests(5)
    eng = SimEngine(batch_size=4, max_seq=64)
    for r in reqs:
        eng.submit(r)
    for _ in range(2):
        eng.step()
    evicted = eng.resize(batch_size=1)
    assert evicted and all(u.state is PAUSED for u in evicted)
    assert eng.resizes == 1 and eng.resize_evictions == len(evicted)
    eng.resume(evicted)
    eng.resize(batch_size=3)
    eng.run_until_idle()
    for r in reqs:
        assert r.done
        assert list(r.out_tokens) == [sim_token(r.rid, i)
                                      for i in range(len(r.out_tokens))]


def test_decode_block_only_resize_is_free(models):
    """Changing only the decode window repacks nothing — same slots,
    same streams, no eviction, no resize counted."""
    cfg, params = models["granite-8b"]
    mk = lambda: _requests(2)                               # noqa: E731
    ref = _reference_tokens(cfg, params, mk, max_seq=64)
    reqs = mk()
    eng = ServingEngine(cfg, params, batch_size=2, max_seq=64)
    for r in reqs:
        eng.submit(r)
    eng.step()
    assert eng.resize(decode_block=1) == []
    assert eng.decode_block == 1 and eng.resizes == 0
    eng.run_until_idle()
    assert [list(r.out_tokens) for r in reqs] == ref


def test_resize_rejects_bad_geometry(models):
    cfg, params = models["granite-8b"]
    dense = ServingEngine(cfg, params, batch_size=2, max_seq=64)
    with pytest.raises(ValueError, match="paged"):
        dense.resize(kv_pool_blocks=64)
    paged = ServingEngine(cfg, params, batch_size=2, max_seq=64,
                          cache_mode="paged", block_size=8)
    with pytest.raises(ValueError, match="full request"):
        paged.resize(kv_pool_blocks=paged.max_blocks - 1)
    with pytest.raises(ValueError):
        paged.resize(batch_size=0)


# ----------------------------------------------------------- conservation
def _interleave(seed: int, *, paged: bool):
    """Random resize/preempt/resume/step interleaving on one engine:
    every submitted request must finish with its deterministic stream
    (sim) and the paged allocator's partition must stay exact."""
    rng = np.random.default_rng(seed)
    if paged:
        cfg = get_config("granite-8b").reduced()
        params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
        eng = ServingEngine(cfg, params, batch_size=3, max_seq=64,
                            cache_mode="paged", block_size=8)
    else:
        eng = SimEngine(batch_size=3, max_seq=64)
    reqs = _requests(6, seed=seed, max_new=5)
    for r in reqs:
        eng.submit(r)
    paused = []
    for _ in range(rng.integers(8, 16)):
        op = rng.integers(0, 4)
        if op == 0:
            eng.step()
        elif op == 1:
            # a resize parks its evictions exactly like a preemption
            paused.extend(eng.resize(batch_size=int(rng.integers(1, 5))))
        elif op == 2:
            paused.extend(eng.preempt())
        elif op == 3 and paused:
            batch, paused = paused, []
            eng.resume(batch)
        if paged:
            eng._alloc.check_invariants()
    eng.resume(paused)
    eng.run_until_idle()
    if paged:
        eng._alloc.check_invariants()
        assert eng._alloc.in_use == 0
    assert all(r.done for r in reqs)
    if not paged:
        for r in reqs:
            assert list(r.out_tokens) == [sim_token(r.rid, i)
                                          for i in range(len(r.out_tokens))]


@pytest.mark.parametrize("seed", range(6))
def test_resize_interleaving_conserves_units_sim(seed):
    _interleave(seed, paged=False)


def test_resize_interleaving_conserves_blocks_paged():
    _interleave(0, paged=True)


@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=25, deadline=None)
def test_resize_interleaving_property(seed):
    _interleave(seed, paged=False)


# ------------------------------------------------------------------- QoS
def test_qos_tier_mapping():
    assert qos_for(INTERACTIVE) is GUARANTEED
    assert qos_for(STANDARD) is BURSTABLE
    assert qos_for(BATCH) is BEST_EFFORT
    assert qos_for(None) is BURSTABLE
    # lazily-admitted classes are BestEffort regardless of priority
    assert qos_for(SLOClass("lazy", 0, admit_lazily=True)) is BEST_EFFORT
    assert qos_for(SLOClass("low", 3)) is BEST_EFFORT


def test_qos_shrink_evicts_best_effort_first():
    """A QoS-keyed shrink takes batch work before interactive even when
    the interactive stream has made less progress."""
    eng = SimEngine(batch_size=4, max_seq=64)
    slos = [BATCH, INTERACTIVE, BATCH, STANDARD]
    reqs = [Request(rid=i, prompt=np.arange(3, dtype=np.int32) + 1,
                    max_new_tokens=8, slo=s)
            for i, s in enumerate(slos)]
    for r in reqs[1:]:          # interactive + batch + standard admitted…
        eng.submit(r)
    eng.step()
    eng.submit(reqs[0])         # …then a late batch stream (least fed)
    eng.step()
    evicted = eng.resize(batch_size=2, evict_key=QoSPolicy.evict_key)
    assert [u.slo_name for u in evicted] == ["batch", "batch"]
    survivors = {r.slo.name for _, r in eng.slot_requests()}
    assert survivors == {"interactive", "standard"}


def test_qos_best_effort_holds_until_idle_capacity():
    """BestEffort arrivals hold at the door while the pool's only free
    lanes are the Guaranteed reservation; they land once load drains."""
    fleet = [InstanceType("std", speed=1.0, spot=False)]
    qos = QoSPolicy(reserve_frac=0.5)
    cl = ServingCluster(None, None, fleet, dt=1.0, batch_size=2,
                        max_seq=64, engine=SimEngine, qos=qos,
                        admission="priority")
    rng = np.random.default_rng(0)
    mk = lambda rid, slo, new: Request(                     # noqa: E731
        rid=rid, prompt=rng.integers(0, 200, 4).astype(np.int32),
        max_new_tokens=new, slo=slo)
    cl.submit(mk(0, INTERACTIVE, 12), at=0.0)
    cl.submit(mk(1, BATCH, 10), at=0.1)     # pool busy: must hold
    out = cl.run(max_time=500)
    assert out["completed"] == 2 and out["dropped"] == 0
    assert out["qos_guaranteed_slot_s"] > 0.0
    assert out["qos_best_effort_slot_s"] > 0.0
    # the shorter batch stream was held at the door, so it finished
    # after the longer interactive one despite arriving right behind it
    traces = cl.metrics.traces
    assert traces[1].done_t > traces[0].done_t


# ---------------------------------------------------- cluster integration
def _fleet(n):
    return [InstanceType("std", speed=1.0, spot=False)] * n


def test_cluster_vertical_grow_shrink_smoke():
    """Backlog grows the lanes, quiet shrinks them back; nothing drops
    and every stream stays deterministic."""
    qos = QoSPolicy()
    vert = FixedThresholdVertical(min_batch=1, max_batch=4, step=1,
                                  grow_backlog=10.0, shrink_backlog=2.0,
                                  cooldown=2.0, qos=qos)
    cl = ServingCluster(None, None, _fleet(2), dt=1.0, batch_size=2,
                        max_seq=64, engine=SimEngine, vertical=vert,
                        qos=qos, admission="priority")
    reqs = classed_requests(24, 200, seed=0)
    for i, r in enumerate(reqs):
        cl.submit(r, at=0.2 * i)
    out = cl.run(max_time=5000)
    assert out["completed"] == 24 and out["dropped"] == 0
    assert out["vertical_grows"] > 0 and out["vertical_shrinks"] > 0
    for r in reqs:
        assert list(r.out_tokens) == [sim_token(r.rid, i)
                                      for i in range(len(r.out_tokens))]


class _ForcedShrink(VerticalScalingPolicy):
    """Issue one shrink-to-one order per replica at the first decision
    tick with live work — the hostile case for conservation."""

    name = "forced"

    def __init__(self):
        self.done = set()

    def decide(self, view, now):
        orders = []
        for rep in view.replicas:
            if (rep.serving and rep.rid not in self.done
                    and rep.engine.n_active > 1):
                self.done.add(rep.rid)
                orders.append(ResizeOrder(rid=rep.rid, batch_size=1,
                                          reason="forced"))
        return orders


def test_cluster_shrink_evictions_never_lose_work():
    """A forced shrink under full load parks evicted units; the resume
    path re-admits every one of them — zero lost, streams exact."""
    cl = ServingCluster(None, None, _fleet(2), dt=1.0, batch_size=3,
                        max_seq=64, engine=SimEngine,
                        vertical=_ForcedShrink(), qos=QoSPolicy())
    reqs = synthetic_requests(12, 200, seed=1, prompt_len=(3, 8))
    for r in reqs:
        cl.submit(r, at=0.0)
    out = cl.run(max_time=5000)
    assert out["completed"] == 12 and out["dropped"] == 0
    assert out["vertical_shrinks"] >= 1 and out["vertical_evictions"] >= 1
    assert out["resumes"] >= out["vertical_evictions"]
    for r in reqs:
        assert list(r.out_tokens) == [sim_token(r.rid, i)
                                      for i in range(len(r.out_tokens))]


def test_sliding_window_policy_needs_history():
    """The windowed recommender never resizes on a single bursty tick."""
    qos = QoSPolicy()
    fixed = FixedThresholdVertical(grow_backlog=1.0, shrink_backlog=0.5,
                                   cooldown=0.0, qos=qos)
    windowed = SlidingWindowVertical(window=100.0, min_samples=3,
                                     grow_backlog=1.0, shrink_backlog=0.5,
                                     cooldown=0.0, qos=qos)

    class _Eng:
        batch = 2

        @staticmethod
        def backlog_tokens():
            return 100.0

    class _Rep:
        rid, model_id, serving = 0, "default", True
        engine = _Eng()

    class _View:
        replicas = [_Rep()]

        def pools(self):
            return ["default"]

        def pool(self, model_id, state="admitting"):
            return [_Rep()]

        def queued_cost(self, model_id):
            return 0.0

    assert fixed.decide(_View(), 0.0)          # instant reaction
    assert not windowed.decide(_View(), 0.0)   # 1 sample: no decision
    assert not windowed.decide(_View(), 1.0)   # 2 samples: still none
    assert windowed.decide(_View(), 2.0)       # 3 samples: acts


# ------------------------------------------------ satellites: S1, S2, S6
def test_detector_suspects_wedged_replica():
    """A replica that heartbeats but stops advancing its progress
    counter while busy is suspected — and cleared when tokens move or
    it goes idle.  Wedge staleness never confirms death by itself."""

    class _Rep:
        def __init__(self, rid):
            self.rid = rid

    fd = FailureDetector(heartbeat_interval=1.0, check_interval=1.0,
                         suspect_after=50.0, confirm_after=100.0,
                         progress_stale_after=5.0)
    rep = _Rep(0)
    fd.beat(0, 0.0, progress=10, busy=True)
    fd.beat(0, 2.0, progress=10, busy=True)      # beating, not moving
    assert fd.scan([rep], 4.0) == ([], [], [])   # not stale yet
    suspects, _, confirmed = fd.scan([rep], 6.0)
    assert suspects == [0] and confirmed == []   # wedged: suspect only
    fd.beat(0, 7.0, progress=11, busy=True)      # progress resumed
    _, cleared, _ = fd.scan([rep], 8.0)
    assert cleared == [0]
    # idle is healthy, not wedged: no suspicion however long it lasts
    fd.beat(0, 9.0, progress=11, busy=False)
    assert fd.scan([rep], 30.0) == ([], [], [])
    # without the cross-check the same silence goes unnoticed
    plain = FailureDetector()
    plain.beat(0, 0.0, progress=10, busy=True)
    plain.beat(0, 2.0, progress=10, busy=True)
    assert plain.scan([_Rep(0)], 6.0) == ([], [], [])


def test_adaptive_checkpoint_interval():
    """Chaos and in-flight work shorten the cadence; a quiet fleet
    relaxes it; a fixed policy never moves; clamps hold at extremes."""

    class _Eng:
        def __init__(self, fed):
            self._fed = fed

        def slot_requests(self):
            return [(i, None) for i in range(len(self._fed))]

        def fed_tokens(self, slot):
            return self._fed[slot]

    class _Rep:
        serving = True

        def __init__(self, fed):
            self.engine = _Eng(fed)

    fixed = CheckpointPolicy(interval=10.0)
    assert fixed.next_interval([_Rep([500, 500])], 0.0) == 10.0

    ad = CheckpointPolicy(interval=10.0, adaptive=True, fault_window=60.0,
                          fault_ref=1.0, tokens_ref=100.0)
    quiet = ad.next_interval([], 0.0)
    assert quiet == 10.0 * ad.quiet_relax        # nothing at risk: relax
    busy = ad.next_interval([_Rep([150, 50])], 0.0)
    assert busy < 10.0                           # live tokens: tighten
    ad.note_fault(1.0)
    ad.note_fault(2.0)
    chaotic = ad.next_interval([_Rep([150, 50])], 3.0)
    assert chaotic < busy                        # chaos tightens further
    assert chaotic >= ad.min_interval
    # faults age out of the window: cadence relaxes back
    assert ad.next_interval([_Rep([150, 50])], 200.0) == busy
    # clamp: absurd pressure still floors at min_interval
    assert ad.next_interval([_Rep([10 ** 9])], 3.0) == ad.min_interval
    with pytest.raises(ValueError, match="min <= interval <= max"):
        CheckpointPolicy(interval=1.0, min_interval=2.0, max_interval=4.0)


def test_summary_schema_zero_fills_vertical_keys():
    """Horizontal-only runs emit every vertical/QoS key zero-filled, so
    downstream JSON consumers see one stable schema (PR 8 S6 pattern)."""
    cl = ServingCluster(None, None, _fleet(1), dt=1.0, batch_size=2,
                        max_seq=64, engine=SimEngine)
    for r in synthetic_requests(3, 200, seed=0, prompt_len=(3, 6)):
        cl.submit(r, at=0.0)
    out = cl.run(max_time=500)
    for key in ("vertical_grows", "vertical_shrinks", "vertical_evictions",
                "resize_stage_s", "qos_guaranteed_slot_s",
                "qos_burstable_slot_s", "qos_best_effort_slot_s"):
        assert key in out and out[key] == 0, key
