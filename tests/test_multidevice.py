"""Multi-device integration tests.

These spawn a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the flag must be set before jax initializes, and the main test process must
keep seeing 1 device), exercising: the shard_map SPMD stencil, sharded
training, and a REAL elastic shrink/expand across device counts.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_subprocess(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        assert len(jax.devices()) == 8
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    return out.stdout


def test_spmd_stencil_matches_reference_8dev():
    run_subprocess("""
        import jax.numpy as jnp
        from repro.core.spmd_stencil import (make_jacobi_spmd_step,
                                             reference_jacobi)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("data",))
        step = make_jacobi_spmd_step(mesh, odf=4, n_iters=5)
        g = jax.random.normal(jax.random.PRNGKey(0), (8 * 4 * 4, 32))
        out = step(g)
        ref = reference_jacobi(g, 5)
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-5, err
        print("SPMD stencil OK", err)
    """)


def test_sharded_training_matches_single_device():
    run_subprocess("""
        import jax.numpy as jnp
        from repro.configs import ARCHS, SHAPES
        from repro.launch.mesh import make_mesh
        from repro.launch.sharding import ShardingRules, use_rules
        from repro.launch.specs import batch_shardings, state_shardings
        from repro.models import model_zoo as zoo

        cfg = ARCHS["granite-8b"].reduced()
        shape = SHAPES["train_4k"].reduced()
        state = zoo.init_state(cfg, jax.random.PRNGKey(0))
        batch = zoo.make_batch(cfg, shape, jax.random.PRNGKey(1))
        # single device
        _, m1 = jax.jit(zoo.make_train_step(cfg))(state, batch)
        # 4x2 mesh (DP x TP)
        mesh = make_mesh((4, 2), ("data", "model"))
        rules = ShardingRules(mesh)
        ssh = state_shardings(cfg, rules)
        bsh = batch_shardings(cfg, shape, rules)
        state_s = jax.device_put(state, ssh)
        batch_s = jax.device_put(batch, bsh)
        with mesh, use_rules(rules):
            _, m8 = jax.jit(zoo.make_train_step(cfg),
                            in_shardings=(ssh, bsh))(state_s, batch_s)
        # bf16 matmuls with f32 accumulation reduce in different orders
        # across shardings; tolerance reflects bf16 forward noise
        d = abs(float(m1["loss"]) - float(m8["loss"]))
        assert d < 8e-3, (float(m1["loss"]), float(m8["loss"]))
        print("sharded-vs-single loss diff", d)
    """)


def test_elastic_shrink_expand_8dev():
    """The paper's §II-B protocol for real: 8 -> 4 -> 8 devices with
    loss-trajectory continuity vs an uninterrupted baseline."""
    run_subprocess("""
        from repro.configs import ARCHS, SHAPES
        from repro.launch.train import ElasticTrainer
        cfg = ARCHS["granite-8b"].reduced()
        shape = SHAPES["train_4k"].reduced()
        a = ElasticTrainer(cfg, shape, n_devices=8, seed=11)
        b = ElasticTrainer(cfg, shape, n_devices=8, seed=11)
        a.train(2, log_every=0)
        b.train(2, log_every=0)
        b.rescale(4)   # shrink: 2 instances interrupted
        b.train(2, log_every=0)
        b.rescale(8)   # expand: replacements arrived
        a.train(4, log_every=0)
        b.train(2, log_every=0)
        la = [m["loss"] for m in a.metrics_log]
        lb = [m["loss"] for m in b.metrics_log]
        # state transfer is exact; different device counts change reduction
        # order, so later losses match to fp tolerance, not bit-for-bit
        assert all(abs(x - y) < 5e-4 for x, y in zip(la, lb)), (la, lb)
        ev = b.runtime.events
        assert [e.kind for e in ev] == ["shrink", "expand"]
        assert all(e.stages["restart"] > 0 for e in ev)
        print("elastic continuity OK", la)
    """)


def test_zero1_state_sharding_compiles_and_runs():
    run_subprocess("""
        import jax.numpy as jnp
        from repro.configs import ARCHS, SHAPES
        from repro.launch.mesh import make_mesh
        from repro.launch.sharding import ShardingRules, use_rules
        from repro.launch.specs import batch_shardings, state_shardings
        from repro.models import model_zoo as zoo
        cfg = ARCHS["granite-8b"].reduced().with_(zero1=True)
        shape = SHAPES["train_4k"].reduced()
        mesh = make_mesh((4, 2), ("data", "model"))
        rules = ShardingRules(mesh)
        ssh = state_shardings(cfg, rules)
        state = jax.device_put(zoo.init_state(cfg, jax.random.PRNGKey(0)),
                               ssh)
        batch = jax.device_put(zoo.make_batch(cfg, shape,
                                              jax.random.PRNGKey(1)),
                               batch_shardings(cfg, shape, rules))
        with mesh, use_rules(rules):
            st2, m = jax.jit(zoo.make_train_step(cfg),
                             in_shardings=(ssh,
                                           batch_shardings(cfg, shape,
                                                           rules)),
                             out_shardings=(ssh, None))(state, batch)
        assert not jnp.isnan(m["loss"])
        print("zero1 OK", float(m["loss"]))
    """)
