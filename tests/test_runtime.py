"""Tile runtime, checkpoint stores, elastic continuity, data pipeline."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.checkpointing import (DeviceStore, FilesystemStore,
                                      InMemoryStore)
from repro.core.overdecomp import (CommModel, HostTileRuntime, TileGrid,
                                   choose_tiling)
from repro.data.pipeline import Prefetcher, SyntheticLM


# ---------------------------------------------------------------- tiles
def test_tile_runtime_matches_global_jacobi():
    """Overdecomposed tiled sweep == single-grid reference sweep."""
    from repro.core.spmd_stencil import reference_jacobi
    grid = TileGrid(32, 32, 4, 4)
    rt = HostTileRuntime(grid, n_pes=4, odf=4)
    ref = np.zeros((32, 32), np.float32)
    ref[0, :] = 1.0  # matches runtime init
    g0 = rt.global_grid()
    for _ in range(6):
        rt.step()
    ref_out = np.asarray(reference_jacobi(jnp.asarray(g0, jnp.float32), 6))
    assert np.abs(rt.global_grid() - ref_out).max() < 1e-5


def test_tile_runtime_lb_preserves_solution():
    grid = TileGrid(32, 32, 4, 4)
    a = HostTileRuntime(grid, n_pes=4, odf=4)
    b = HostTileRuntime(grid, n_pes=4, odf=4,
                        pe_rate_multipliers=[1, 1, 0.5, 1])
    for i in range(8):
        a.step()
        b.step()
        if i == 3:
            b.load_balance("greedy_refine")
    assert np.abs(a.global_grid() - b.global_grid()).max() < 1e-6


def test_tile_runtime_checkpoint_restore_elastic():
    grid = TileGrid(32, 32, 4, 4)
    rt = HostTileRuntime(grid, n_pes=4, odf=4)
    for _ in range(3):
        rt.step()
    snap = rt.checkpoint()
    expected = rt.global_grid()
    rt2 = HostTileRuntime(grid, n_pes=2, odf=8)
    rt2.restore(snap, n_pes=2)   # shrink 4 -> 2 PEs
    assert np.abs(rt2.global_grid() - expected).max() == 0.0
    assert rt2.assignment.max() < 2
    rt2.step()  # still runs


def test_choose_tiling():
    assert choose_tiling(16) == (4, 4)
    assert choose_tiling(8) == (2, 4)
    assert choose_tiling(7) == (1, 7)


def test_comm_model_exposure_shrinks_with_odf():
    res = {}
    for odf in (1, 8):
        grid_n = 4 * odf
        tr, tc = choose_tiling(grid_n)
        rt = HostTileRuntime(TileGrid(64, 64, tr, tc), 4, odf=odf,
                             comm=CommModel(latency_s=5e-3))
        m = [rt.step() for _ in range(4)][-1]
        res[odf] = m["comm_exposed_max"]
    assert res[8] <= res[1]


# ---------------------------------------------------------------- stores
@pytest.mark.parametrize("store_kind", ["memory", "device", "filesystem"])
def test_store_roundtrip(store_kind, tmp_path):
    from repro.core.checkpointing import make_store
    store = make_store(store_kind, root=tmp_path)
    state = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
             "b": [jnp.ones((2,), jnp.bfloat16),
                   jnp.array(3, jnp.int32)]}
    store.save("t", state)
    assert store.exists("t")
    assert store.nbytes("t") > 0
    out = store.restore("t")
    for x, y in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        assert x.dtype == y.dtype
        assert jnp.array_equal(x, y)
    store.drop("t")
    assert not store.exists("t")


def test_store_restore_with_sharding():
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    store = InMemoryStore()
    x = {"w": jnp.arange(16, dtype=jnp.float32)}
    store.save("s", x)
    mesh = make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    out = store.restore("s", sh)
    assert jnp.array_equal(out["w"], x["w"])
    assert out["w"].sharding == sh["w"]


# ---------------------------------------------------------------- data
def test_synthetic_data_deterministic_and_step_addressable():
    from repro.configs import ARCHS, SHAPES
    cfg = ARCHS["granite-8b"].reduced()
    shape = SHAPES["train_4k"].reduced()
    d1 = SyntheticLM(cfg, shape, seed=7)
    d2 = SyntheticLM(cfg, shape, seed=7)
    b5a, b5b = d1.batch_at(5), d2.batch_at(5)
    for k in b5a:
        assert np.array_equal(b5a[k], b5b[k])
    # different steps differ
    assert not np.array_equal(d1.batch_at(5)["tokens"],
                              d1.batch_at(6)["tokens"])
    # restart-resume: iterating from 3 gives batch_at(3)
    it = d1.iterate(start_step=3)
    assert np.array_equal(next(it)["tokens"], d1.batch_at(3)["tokens"])


def test_prefetcher_orders_batches():
    from repro.configs import ARCHS, SHAPES
    cfg = ARCHS["granite-8b"].reduced()
    shape = SHAPES["train_4k"].reduced()
    src = SyntheticLM(cfg, shape, seed=1)
    pf = Prefetcher(src, start_step=2)
    try:
        s0, b0 = pf.next()
        s1, b1 = pf.next()
        assert (s0, s1) == (2, 3)
        assert np.array_equal(np.asarray(b0["tokens"]),
                              src.batch_at(2)["tokens"])
    finally:
        pf.stop()


# ---------------------------------------------------------------- elastic
def test_elastic_trainer_continuity_single_device():
    """A rescale (re-jit + reshard round trip) must not perturb training."""
    from repro.configs import ARCHS, SHAPES
    from repro.launch.train import ElasticTrainer
    cfg = ARCHS["granite-8b"].reduced()
    shape = SHAPES["train_4k"].reduced()
    a = ElasticTrainer(cfg, shape, n_devices=1, seed=3)
    b = ElasticTrainer(cfg, shape, n_devices=1, seed=3)
    a.train(2, log_every=0)
    b.train(2, log_every=0)
    b.rescale(1)                    # checkpoint -> restart -> restore
    a.train(2, log_every=0)
    b.train(2, log_every=0)
    la = [m["loss"] for m in a.metrics_log]
    lb_ = [m["loss"] for m in b.metrics_log]
    assert la == pytest.approx(lb_, abs=1e-6)
