"""Property-based EventLoop tests (hypothesis, via _hypothesis_compat).

The three determinism substrates every downstream guarantee leans on,
now also covering the recurring ``rebalance``-style self-rescheduling
event the SLO layer added:

* same-timestamp events dispatch in schedule order (seq tie-break);
* two identically-driven loops produce bit-identical journals;
* cancelled pending events never dispatch (and cancelling a recurring
  event's current occurrence stops the chain).

Each ``@given`` test skips individually when hypothesis is missing (see
requirements-dev.txt); the plain companions below always run.
"""

import math

import pytest

from repro.runtime import EventLoop, VirtualClock

from tests._hypothesis_compat import given, settings, st


# ------------------------------------------------------------ strategies
# (evaluated at import; harmless stubs when hypothesis is absent)
_times = st.lists(st.floats(min_value=0.0, max_value=100.0,
                            allow_nan=False, allow_infinity=False),
                  min_size=1, max_size=40)


def _drive(times, cancel_idx=(), rebalance_every=None,
           rebalance_stop=math.inf):
    """Build a loop, schedule one 'a' event per time (in list order),
    optionally a self-rescheduling 'rebalance' chain, cancel the given
    schedule indices, run to completion.  Returns (loop, dispatched)."""
    loop = EventLoop(VirtualClock())
    dispatched = []
    loop.register("a", lambda ev, t: dispatched.append(
        ("a", t, ev.payload["i"])))

    state = {"ev": None}

    def rebalance(ev, t):
        dispatched.append(("rebalance", t, -1))
        state["ev"] = None
        if rebalance_every is not None and t + rebalance_every \
                <= rebalance_stop:
            state["ev"] = loop.schedule(t + rebalance_every, "rebalance")

    loop.register("rebalance", rebalance)
    events = [loop.schedule(t, "a", i=i) for i, t in enumerate(times)]
    if rebalance_every is not None:
        state["ev"] = loop.schedule(rebalance_every, "rebalance")
    for i in cancel_idx:
        loop.cancel(events[i % len(events)])
    loop.run()
    return loop, dispatched


# ------------------------------------------------------------- properties
@settings(max_examples=60, deadline=None)
@given(_times)
def test_same_timestamp_ties_break_by_schedule_order(times):
    _, dispatched = _drive(times)
    assert len(dispatched) == len(times)
    # stable sort by time == dispatch order (seq is schedule order)
    expected = sorted(range(len(times)), key=lambda i: (times[i], i))
    assert [i for _, _, i in dispatched] == expected


@settings(max_examples=40, deadline=None)
@given(_times, st.integers(min_value=1, max_value=7))
def test_journal_bit_identical_across_runs(times, every):
    """Identical inputs (including a recurring rebalance chain) give
    bit-identical journals AND dispatch orders."""
    stop = max(times) if times else 0.0
    a = _drive(times, rebalance_every=float(every), rebalance_stop=stop)
    b = _drive(times, rebalance_every=float(every), rebalance_stop=stop)
    assert a[0].journal == b[0].journal
    assert a[1] == b[1]
    assert a[0].journal                 # journalled something


@settings(max_examples=60, deadline=None)
@given(_times, st.sets(st.integers(min_value=0, max_value=39),
                       max_size=10))
def test_cancelled_events_never_dispatch(times, cancel):
    _, dispatched = _drive(times, cancel_idx=sorted(cancel))
    cancelled = {i % len(times) for i in cancel}
    seen = {i for _, _, i in dispatched}
    assert seen == set(range(len(times))) - cancelled


# --------------------------------------------- deterministic companions
# (always run, hypothesis or not — the same three properties at fixed
# inputs, plus recurring-event cancellation mid-chain)
def test_tie_break_fixed():
    _, dispatched = _drive([5.0, 1.0, 5.0, 5.0, 0.5])
    assert [i for _, _, i in dispatched] == [4, 1, 0, 2, 3]


def test_journal_identity_with_recurring_rebalance_fixed():
    times = [0.7, 3.0, 3.0, 9.5, 2.2]
    a = _drive(times, rebalance_every=2.0, rebalance_stop=9.5)
    b = _drive(times, rebalance_every=2.0, rebalance_stop=9.5)
    assert a[0].journal == b[0].journal and a[1] == b[1]
    rebalances = [t for kind, t, _ in a[1] if kind == "rebalance"]
    assert rebalances == [2.0, 4.0, 6.0, 8.0]   # the chain self-armed


def test_cancelling_recurring_event_stops_the_chain():
    loop = EventLoop(VirtualClock())
    fired = []
    state = {"ev": None}

    def rebalance(ev, t):
        fired.append(t)
        state["ev"] = loop.schedule(t + 1.0, "rebalance")
        if len(fired) == 3:
            loop.cancel(state["ev"])    # a handler cancels its successor
            state["ev"] = None

    loop.register("rebalance", rebalance)
    state["ev"] = loop.schedule(1.0, "rebalance")
    loop.run(until=100.0)
    assert fired == [1.0, 2.0, 3.0]
    assert loop.pending == 0


def test_cancel_is_idempotent_and_none_safe():
    loop = EventLoop(VirtualClock())
    loop.register("a", lambda ev, t: None)
    ev = loop.schedule(1.0, "a")
    loop.cancel(ev)
    loop.cancel(ev)                     # double-cancel: no-op
    loop.cancel(None)                   # None: no-op
    assert loop.run() == 0
    assert loop.peek() is None and loop.peek_t() == math.inf
