"""Per-arch smoke tests (reduced configs) + model-level invariants."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES
from repro.configs.base import ShapeConfig
from repro.models import model_zoo as zoo
from repro.models import transformer as T

ARCH_NAMES = sorted(ARCHS)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


# ------------------------------------------------------------- smoke: train
@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_train_step(name, rng):
    cfg = ARCHS[name].reduced()
    shape = SHAPES["train_4k"].reduced()
    state = zoo.init_state(cfg, rng)
    batch = zoo.make_batch(cfg, shape, rng)
    step = jax.jit(zoo.make_train_step(cfg))
    state2, metrics = step(state, batch)
    assert int(state2.step) == 1
    assert not jnp.isnan(metrics["loss"]), name
    state2, metrics = step(state2, batch)  # step 2: warmup lr > 0
    assert not jnp.isnan(metrics["loss"]), name
    # params changed and have the same structure/shapes
    p0 = jax.tree.leaves(state.params)
    p1 = jax.tree.leaves(state2.params)
    assert len(p0) == len(p1)
    assert all(a.shape == b.shape for a, b in zip(p0, p1))
    assert any(float(jnp.abs(a - b).max()) > 0 for a, b in zip(p0, p1))


# ------------------------------------------------------------- smoke: serve
@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_prefill_decode(name, rng):
    cfg = ARCHS[name].reduced()
    pshape = SHAPES["prefill_32k"].reduced()
    state = zoo.init_state(cfg, rng)
    prefill = jax.jit(zoo.make_prefill(cfg, pshape))
    logits, dstate = prefill(state.params, zoo.make_batch(cfg, pshape, rng))
    assert logits.shape == (pshape.global_batch, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    dshape = SHAPES["decode_32k"].reduced()
    serve = jax.jit(zoo.make_serve_step(cfg, dshape))
    ds = zoo.init_decode_state(cfg, dshape)
    lg, ds2 = serve(state.params, ds, zoo.make_batch(cfg, dshape, rng))
    assert lg.shape == (dshape.global_batch, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(lg).any())
    assert int(ds2.cache_len[0]) == int(ds.cache_len[0]) + 1


# ------------------------------------------------------- decode == forward
@pytest.mark.parametrize("name", ["granite-8b", "mamba2-780m", "zamba2-2.7b",
                                  "llama3.2-3b"])
def test_decode_matches_forward(name, rng):
    cfg = ARCHS[name].reduced().with_(remat="none", capacity_factor=100.0)
    state = zoo.init_state(cfg, rng)
    S = 10
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, S), 0,
                              cfg.vocab_size)
    h, _ = T.decoder_forward(state.params, toks, cfg)
    full_logits = T.lm_logits(state.params, h, cfg)

    shape = ShapeConfig("t", S + 2, 2, "decode")
    step = jax.jit(zoo.make_serve_step(cfg, shape))
    ds = zoo.init_decode_state(cfg, shape, fill_len=0)
    outs = []
    for i in range(S):
        lg, ds = step(state.params, ds,
                      {"tokens": toks[:, i:i + 1],
                       "active": jnp.ones((2,), jnp.int32)})
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.abs(dec - full_logits).max()) / (
        float(jnp.abs(full_logits).max()) + 1e-9)
    assert rel < 1e-3, (name, rel)


def test_inactive_slots_frozen():
    """Continuous batching: inactive slots must not change cache or length."""
    cfg = ARCHS["granite-8b"].reduced()
    state = zoo.init_state(cfg, jax.random.PRNGKey(0))
    shape = ShapeConfig("t", 8, 2, "decode")
    step = jax.jit(zoo.make_serve_step(cfg, shape))
    ds = zoo.init_decode_state(cfg, shape, fill_len=2)
    tok = jnp.array([[3], [5]], jnp.int32)
    _, ds2 = step(state.params, ds,
                  {"tokens": tok, "active": jnp.array([1, 0], jnp.int32)})
    assert int(ds2.cache_len[0]) == 3 and int(ds2.cache_len[1]) == 2
    # slot 1's cache rows unchanged
    k_old = ds.cache["k"][:, 1]
    k_new = ds2.cache["k"][:, 1]
    assert float(jnp.abs(k_old - k_new).max()) == 0.0


# ------------------------------------------------------- attention oracle
def test_blockwise_attention_matches_full():
    from repro.models.layers import blockwise_attention, full_attention
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    for causal in (True, False):
        o1 = blockwise_attention(q, k, v, causal=causal, block_q=16,
                                 block_kv=16)
        o2 = full_attention(q, k, v, causal=causal)
        assert float(jnp.abs(o1 - o2).max()) < 1e-4


# ------------------------------------------------------- microbatch invariance
def test_grad_accum_matches_single_batch():
    """n_micro=4 grad accumulation == single-shot full batch (fp32)."""
    cfg = ARCHS["granite-3-2b"].reduced().with_(
        remat="none", num_microbatches=4)
    cfg1 = cfg.with_(num_microbatches=1)
    shape = SHAPES["train_4k"].reduced()
    state = zoo.init_state(cfg, jax.random.PRNGKey(0))
    batch = zoo.make_batch(cfg, shape, jax.random.PRNGKey(1))
    _, m4 = jax.jit(zoo.make_train_step(cfg))(state, batch)
    _, m1 = jax.jit(zoo.make_train_step(cfg1))(state, batch)
    assert abs(float(m4["loss"]) - float(m1["loss"])) < 5e-3


# ------------------------------------------------------- vocab padding
def test_padded_vocab_masked():
    cfg = ARCHS["granite-3-2b"].reduced()  # vocab 256 -> padded 256
    cfg = cfg.with_(vocab_size=250)        # force padding
    state = zoo.init_state(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 4), jnp.int32)
    h, _ = T.decoder_forward(state.params, toks, cfg)
    logits = T.lm_logits(state.params, h, cfg)
    assert logits.shape[-1] == cfg.padded_vocab
    assert float(logits[..., cfg.vocab_size:].max()) <= -1e29


def test_param_counts_plausible():
    """Full-config param counts are in the right ballpark for the names."""
    import numpy as np
    expect = {
        "command-r-35b": (30e9, 40e9),
        "granite-8b": (7e9, 9e9),
        "llama3.2-3b": (3e9, 4.5e9),
        "qwen3-moe-30b-a3b": (25e9, 35e9),
        "mamba2-780m": (0.7e9, 0.9e9),
        "zamba2-2.7b": (2e9, 3.3e9),
    }
    for name, (lo, hi) in expect.items():
        n = zoo.num_params(ARCHS[name])
        assert lo <= n <= hi, (name, n)
    # MoE active < total
    assert zoo.active_params(ARCHS["qwen3-moe-30b-a3b"]) < \
        zoo.num_params(ARCHS["qwen3-moe-30b-a3b"])
