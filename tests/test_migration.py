"""Migration-invariance suite: a slot checkpointed at ANY point in a
request's life — mid-decode or mid-prefill-chunk — and restored on a
*different* replica resumes to a bit-identical token stream, for both
causal (kv-cache) and ssm (recurrent-state) model families.

This is the correctness substrate under both migration consumers: the
§IV spot-drain and the proactive mid-stream rebalancer.
"""

import jax
import numpy as np
import pytest

from repro.cluster import InstanceType, Replica
from repro.configs import get_config
from repro.models import model_zoo as zoo
from repro.serving.engine import Request, ServingEngine

ARCHS = ["granite-8b", "mamba2-780m"]     # causal + ssm families


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        out[arch] = (cfg,
                     zoo.init_state(cfg, jax.random.PRNGKey(0)).params)
    return out


def _prompt(cfg, n, seed):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, n, dtype=np.int32)


def _replica(cfg, params, rid, speed=1.0):
    return Replica(rid, cfg, params, InstanceType(f"r{rid}", speed),
                   batch_size=2, max_seq=64)


def _reference_tokens(cfg, params, prompt, max_new):
    eng = ServingEngine(cfg, params, batch_size=2, max_seq=64)
    req = Request(rid=99, prompt=prompt.copy(), max_new_tokens=max_new)
    eng.submit(req)
    eng.run_until_idle()
    assert req.done
    return req.out_tokens


@pytest.mark.parametrize("arch", ARCHS)
def test_migrate_mid_decode_bit_identical(models, arch):
    """pack_slots mid-generation -> unpack on another replica."""
    cfg, params = models[arch]
    prompt = _prompt(cfg, 12, seed=1)
    ref = _reference_tokens(cfg, params, prompt, max_new=12)

    src = _replica(cfg, params, 0)
    req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=12)
    src.submit(req)
    while src.engine.fed_tokens(0) <= len(prompt):   # cross into decode
        src.step_once(now=0.0)
    # genuinely mid-decode: past the prompt, not yet finished (out_tokens
    # stays empty until a poll — progress lives in the host projection)
    assert len(prompt) < src.engine.fed_tokens(0) < len(prompt) + 11
    occupied = [s for s, _ in src.engine.slot_costs()]
    units, (ckpt_s, restore_s) = src.pack_slots(occupied[:1])
    assert len(units) == 1
    assert units[0].residency == "host"     # staged through the endpoint
    assert 0 < len(req.out_tokens) < 12     # pack poll materialized
    assert ckpt_s >= 0.0 and restore_s >= 0.0   # store stages exercised
    assert src.engine.n_active == 0     # slot released on the source

    dst = _replica(cfg, params, 1)
    dst.unpack(units)
    while dst.has_work():
        dst.step_once(now=0.0)
    dst.engine.pop_completed()
    assert req.done
    assert req.out_tokens == ref


@pytest.mark.parametrize("arch", ARCHS)
def test_migrate_mid_prefill_chunk_bit_identical(models, arch):
    """Snapshot right after the bulk prefill chunk, before the prompt is
    fully fed, and restore on a different replica."""
    cfg, params = models[arch]
    # longer than the smallest bucket so the tail is still streaming
    # when we snapshot (chunk 16 + streamed tail)
    prompt = _prompt(cfg, 30, seed=2)
    ref = _reference_tokens(cfg, params, prompt, max_new=8)

    eng = ServingEngine(cfg, params, batch_size=2, max_seq=64,
                        prefill_buckets=(16,))
    req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=8)
    eng.submit(req)
    eng.step()                          # admit: one 16-token chunk + 1 step
    assert eng.chunk_prefills == 1
    assert eng.fed_tokens(0) < len(prompt) - 1   # still mid-prefill
    units = eng.pack()
    assert len(units) == 1 and units[0].progress < len(prompt)
    assert req.out_tokens == []

    dst = _replica(cfg, params, 1)
    dst.unpack(units)
    while dst.has_work():
        dst.step_once(now=0.0)
    dst.engine.pop_completed()
    assert req.done
    assert req.out_tokens == ref


@pytest.mark.parametrize("arch", ARCHS)
def test_double_migration_bit_identical(models, arch):
    """Two hops (src -> mid -> dst), one mid-prefill and one mid-decode,
    still reproduce the reference stream exactly."""
    cfg, params = models[arch]
    prompt = _prompt(cfg, 24, seed=3)
    ref = _reference_tokens(cfg, params, prompt, max_new=10)

    src = Replica(0, cfg, params, InstanceType("src", 1.0),
                  batch_size=2, max_seq=64)
    src.engine._buckets = tuple(b for b in src.engine._buckets
                                if b <= 16)     # force a streamed tail
    req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=10)
    src.submit(req)
    src.step_once(now=0.0)              # hop 1: mid-prefill
    units, _ = src.pack_slots([s for s, _ in
                                   src.engine.slot_costs()])
    mid = _replica(cfg, params, 1)
    mid.unpack(units)
    while mid.engine.fed_tokens(0) <= len(prompt):  # cross into decode
        mid.step_once(now=0.0)
    assert mid.engine.fed_tokens(0) > len(prompt)   # hop 2: mid-decode
    units, _ = mid.pack_slots([s for s, _ in
                                   mid.engine.slot_costs()])
    assert all(u.residency == "host" for u in units)
    assert 0 < len(req.out_tokens) < 10
    dst = _replica(cfg, params, 2)
    dst.unpack(units)
    while dst.has_work():
        dst.step_once(now=0.0)
    dst.engine.pop_completed()
    assert req.done
    assert req.out_tokens == ref


def test_selective_snapshot_leaves_other_slots_running(models):
    """pack_slots([victim]) must not disturb the co-resident slot:
    it keeps decoding on the source to its reference continuation."""
    cfg, params = models["granite-8b"]
    p0, p1 = _prompt(cfg, 6, seed=4), _prompt(cfg, 6, seed=5)
    ref0 = _reference_tokens(cfg, params, p0, max_new=10)
    ref1 = _reference_tokens(cfg, params, p1, max_new=10)

    src = _replica(cfg, params, 0)
    r0 = Request(rid=0, prompt=p0.copy(), max_new_tokens=10)
    r1 = Request(rid=1, prompt=p1.copy(), max_new_tokens=10)
    src.submit(r0)
    src.submit(r1)
    for _ in range(2):
        src.step_once(now=0.0)
    assert src.engine.n_active == 2
    victim = [s for s, _ in src.engine.slot_costs()
              if src.engine._slots[s].rid == 0]
    units, _ = src.pack_slots(victim)
    assert [u.rid for u in units] == [0]
    assert src.engine.n_active == 1     # r1 still in place

    dst = _replica(cfg, params, 1)
    dst.unpack(units)
    while dst.has_work():
        dst.step_once(now=0.0)
    while src.has_work():
        src.step_once(now=0.0)
    src.engine.pop_completed()
    dst.engine.pop_completed()
    assert r0.done and r0.out_tokens == ref0
    assert r1.done and r1.out_tokens == ref1
