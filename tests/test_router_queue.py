"""Router queue mechanics after the deque rewrite: FIFO admission
order, O(len) requeue that used to be O(queue^2), constant-time
queued-load aggregates, the topology-epoch pool cache, and the
``place_cap`` bounded dispatch mode the million-request matrix runs
under — all without changing a single placement decision (journal
bit-identity is pinned in tests/test_shapes.py on a full cluster run).
"""

import numpy as np
import pytest

from repro.cluster.replica import InstanceType, Replica, ReplicaState
from repro.cluster.router import (DeadlineAwareRouter, RateAwareRouter,
                                  RoundRobinRouter, request_cost)
from repro.serving.engine import Request
from repro.serving.simengine import SimEngine


def _req(rid, plen=6, new=4, model_id="default"):
    return Request(rid=rid,
                   prompt=np.arange(plen, dtype=np.int32) % 17,
                   max_new_tokens=new, model_id=model_id)


def _rep(rid, model_id="default", batch_size=4, speed=4.0):
    return Replica(rid, None, None,
                   InstanceType("std.1x", speed, spot=False,
                                model_id=model_id),
                   batch_size=batch_size, max_seq=64,
                   engine_cls=SimEngine)


# -------------------------------------------------------------- ordering
def test_submit_is_fifo():
    router = RoundRobinRouter()
    for i in range(5):
        router.submit(_req(i))
    assert [r.rid for r in router.queue] == [0, 1, 2, 3, 4]


def test_requeue_prepends_preserving_relative_order():
    router = RoundRobinRouter()
    for i in (10, 11):
        router.submit(_req(i))
    router.requeue([_req(0), _req(1), _req(2)])
    assert [r.rid for r in router.queue] == [0, 1, 2, 10, 11]
    router.requeue([_req(90)])
    assert [r.rid for r in router.queue] == [90, 0, 1, 2, 10, 11]


def test_round_robin_dispatch_drains_in_fifo_order():
    router = RoundRobinRouter()
    rep = _rep(0, batch_size=8)
    for i in range(6):
        router.submit(_req(i))
    woken = router.dispatch([rep], rates={}, now=0.0)
    assert woken == [rep]
    assert [r.rid for r in rep.engine.queued_requests()] == list(range(6))
    assert not router.queue


# ------------------------------------------------------- load aggregates
@pytest.mark.parametrize("router_cls", [RoundRobinRouter, RateAwareRouter,
                                        DeadlineAwareRouter])
def test_queued_aggregates_match_a_fresh_scan(router_cls):
    router = router_cls()
    discount = getattr(router, "prefill_discount", 1.0)
    reqs = [_req(i, plen=3 + i % 5, new=2 + i % 7,
                 model_id="m0" if i % 3 else "m1") for i in range(40)]
    for r in reqs:
        router.submit(r)
    for model_id in (None, "m0", "m1"):
        in_model = [r for r in router.queue
                    if model_id is None or r.model_id == model_id]
        assert router.queued_tokens(model_id) == pytest.approx(
            sum(r.total_tokens for r in in_model))
        assert router.queued_cost(model_id) == pytest.approx(
            sum(request_cost(r, discount) for r in in_model))


def test_queued_aggregates_survive_dispatch_and_requeue():
    router = RateAwareRouter()
    rep = _rep(0, batch_size=4)
    for i in range(10):
        router.submit(_req(i))
    router.dispatch([rep], rates={rep.rid: 4.0}, now=0.0)
    router.requeue([_req(50), _req(51)])
    discount = router.prefill_discount
    assert router.queued_cost() == pytest.approx(
        sum(request_cost(r, discount) for r in router.queue))
    assert router.queued_tokens() == pytest.approx(
        sum(r.total_tokens for r in router.queue))


def test_queued_aggregates_never_go_negative():
    router = RoundRobinRouter()
    req = _req(0)
    router.submit(req)
    router._q_rem(req)
    router._q_rem(req)            # float drift / double-remove clamps at 0
    assert router.queued_tokens() == 0.0
    assert router.queued_cost() == 0.0


# ------------------------------------------------------ pool-index cache
def test_pool_cache_rebuilds_on_topology_epoch_bump():
    router = RoundRobinRouter()
    reps = [_rep(0), _rep(1)]
    pools = router.pools(reps)
    assert [r.rid for r in pools["default"]] == [0, 1]
    assert router.pools(reps) is pools          # cached: same object back
    reps[1].state = ReplicaState.DRAINING       # bumps the epoch
    pools2 = router.pools(reps)
    assert pools2 is not pools
    assert [r.rid for r in pools2["default"]] == [0]
    reps[0].quarantined = True                  # quarantine also bumps
    assert "default" not in router.pools(reps)


# --------------------------------------------------- place_cap fast path
def test_place_cap_fills_engine_headroom_only():
    """Bounded mode never reclaims or over-places: engines receive at
    most their free-slot headroom, the rest of the backlog stays in
    the router deque in FIFO order."""
    router = RateAwareRouter(place_cap=8)
    reps = [_rep(0, batch_size=2), _rep(1, batch_size=2)]
    for i in range(10):
        router.submit(_req(i))
    woken = router.dispatch(reps, rates={0: 4.0, 1: 4.0}, now=0.0)
    assert set(w.rid for w in woken) == {0, 1}
    placed = sorted(r.rid for rep in reps
                    for r in rep.engine.queued_requests())
    assert placed == [0, 1, 2, 3]               # head of the queue
    assert [r.rid for r in router.queue] == [4, 5, 6, 7, 8, 9]
    # engines hold only their headroom: nothing queued beyond slots
    for rep in reps:
        assert rep.engine.n_queued <= rep.engine.free_slots
    # second pass with zero headroom places nothing
    assert router.dispatch(reps, rates={0: 4.0, 1: 4.0}, now=0.0) == []
    assert len(router.queue) == 6


def test_place_cap_scan_window_bounds_work_per_pass():
    router = RateAwareRouter(place_cap=3)
    rep = _rep(0, batch_size=8)
    for i in range(10):
        router.submit(_req(i))
    router.dispatch([rep], rates={rep.rid: 4.0}, now=0.0)
    # only the cap-sized head window was considered this pass
    assert [r.rid for r in rep.engine.queued_requests()] == [0, 1, 2]
    assert [r.rid for r in router.queue] == [3, 4, 5, 6, 7, 8, 9]


def test_place_cap_keeps_aggregates_consistent():
    router = RateAwareRouter(place_cap=4)
    rep = _rep(0, batch_size=4)
    for i in range(8):
        router.submit(_req(i))
    router.dispatch([rep], rates={rep.rid: 4.0}, now=0.0)
    assert router.queued_cost() == pytest.approx(
        sum(request_cost(r, router.prefill_discount)
            for r in router.queue))


def test_place_cap_respects_model_pools():
    router = RateAwareRouter(place_cap=8)
    rep_a = _rep(0, model_id="a", batch_size=4)
    for i in range(4):
        router.submit(_req(i, model_id="a" if i % 2 == 0 else "b"))
    router.dispatch([rep_a], rates={0: 4.0}, now=0.0)
    assert [r.rid for r in rep_a.engine.queued_requests()] == [0, 2]
    # pool-less requests stay queued (and stay counted)
    assert [r.rid for r in router.queue] == [1, 3]
    assert router.queued_tokens("b") > 0.0
