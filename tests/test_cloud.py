"""CloudManager policy tests: trigger conditions + mode comparisons."""

import pytest

from repro.core.cloud import CloudManager, Mode, StageCostModel


def make_cm(mode, *, n=16, count=4, t=100.0, **kw):
    cm = CloudManager(n_instances=n, mode=mode,
                      cost=StageCostModel(state_bytes=n * 64e6),
                      total_iters=2000, iter_seconds=0.2, **kw)
    cm.inject_interruption(t=t, count=count)
    return cm


def _events(rep, key):
    return [e for t, e in rep.timeline if key in e]


def test_mode_c_single_rescale():
    cm = make_cm(Mode.C_PROACTIVE, count=4)
    rep = cm.run()
    assert len(rep.rescales) == 1
    assert rep.rescales[0]["reason"].startswith("proactive")


def test_mode_b_two_rescales_per_interruption_batch():
    cm = make_cm(Mode.B_REACTIVE, count=4)
    rep = cm.run()
    kinds = [r["reason"] for r in rep.rescales]
    assert kinds.count("shrink") == 4 and kinds.count("expand") == 4


def test_mode_ordering_c_best():
    overheads = {}
    for mode in Mode:
        rep = make_cm(mode, count=8).run()
        overheads[mode] = rep.overhead_frac
    assert overheads[Mode.C_PROACTIVE] < overheads[Mode.B_REACTIVE]
    assert overheads[Mode.C_PROACTIVE] < overheads[Mode.A_FILESYSTEM]
    # paper: <1% on a 5000-iter run; this shorter run (2000 iters) scales
    # the same absolute overhead to a larger fraction
    assert overheads[Mode.C_PROACTIVE] < 0.03


def test_complete_replacement_trigger():
    """Replacements ready before notices -> 'complete' trigger fires."""
    cm = make_cm(Mode.C_PROACTIVE, count=2,
                 replacement_latency=60.0, rebalance_lead=300.0)
    rep = cm.run()
    assert any("proactive_complete" == r["reason"] for r in rep.rescales)


def test_emergency_override_trigger():
    """Notice arrives before replacements -> emergency partial replacement."""
    cm = make_cm(Mode.C_PROACTIVE, count=2,
                 replacement_latency=500.0, rebalance_lead=30.0,
                 t_timeout=1000.0)
    rep = cm.run()
    assert any("proactive_emergency" == r["reason"] for r in rep.rescales)


def test_timeout_trigger():
    """No notice, slow replacements -> T_timeout forces the rescale."""
    cm = make_cm(Mode.C_PROACTIVE, count=2,
                 replacement_latency=80.0, rebalance_lead=10_000.0,
                 t_timeout=120.0)
    rep = cm.run()
    reasons = [r["reason"] for r in rep.rescales]
    assert "proactive_timeout" in reasons or "proactive_complete" in reasons
    # the rescale must happen within ~T_timeout of the recommendation
    t_rescale = rep.rescales[0]["t"]
    assert t_rescale <= 100.0 + 120.0 + 1e-6


def test_mode_a_downtime_and_rollback():
    cm = make_cm(Mode.A_FILESYSTEM, count=1)
    rep = cm.run()
    assert _events(rep, "job_down")
    assert _events(rep, "fs_restart")
    # overhead includes the down window -> strictly positive
    assert rep.overhead_frac > 0.01


def test_overhead_scales_with_interruptions_mode_b_not_c():
    b1 = make_cm(Mode.B_REACTIVE, count=1).run().overhead_frac
    b8 = make_cm(Mode.B_REACTIVE, count=8).run().overhead_frac
    c1 = make_cm(Mode.C_PROACTIVE, count=1).run().overhead_frac
    c8 = make_cm(Mode.C_PROACTIVE, count=8).run().overhead_frac
    assert b8 > 3 * b1          # reactive cost grows with interruptions
    assert c8 < 1.5 * c1 + 1e-3  # proactive stays flat (paper Fig 8)


def test_rebalancing_halves_overhead_vs_reactive():
    """Paper: capacity rebalancing cuts interruption-handling overhead ~50%."""
    b = make_cm(Mode.B_REACTIVE, count=1).run()
    c = make_cm(Mode.C_PROACTIVE, count=1).run()
    assert c.interruption_overhead < 0.6 * b.interruption_overhead
