"""FaultTrace contract tests (the §IV schedule the market layer drives).

PR-6 coverage satellites: file round-trips, late injection into a bound
loop (how per-purchase market interruptions arrive), equal-timestamp
delivery order, and purchase-sequence determinism of the market-driven
schedule.
"""

import numpy as np

from repro.runtime import EventLoop, FaultTrace


def test_trace_file_roundtrip(tmp_path):
    """``to_file`` -> ``from_file`` reproduces the schedule exactly,
    including floats with no short decimal form."""
    trace = FaultTrace(rebalance_lead=6.0, notice_deadline=4.0)
    trace.inject(1.0 / 3.0, 2)
    trace.inject(92.94171263538088, 0)
    trace.inject(100.0, 1)
    p = tmp_path / "faults.txt"
    trace.to_file(str(p))
    back = FaultTrace.from_file(str(p), rebalance_lead=6.0,
                                notice_deadline=4.0)
    assert back.interruptions == trace.interruptions
    assert [(n.t, n.kind, n.target) for n in back.events()] \
        == [(n.t, n.kind, n.target) for n in trace.events()]


def test_inject_after_bind_reaches_the_loop():
    """A lifecycle injected AFTER ``bind`` still schedules its events on
    the bound loop — the enabler for market-driven injection, where every
    mid-run fallback purchase samples a fresh interruption."""
    trace = FaultTrace(rebalance_lead=10.0, notice_deadline=5.0)
    trace.inject(50.0, 0)                 # before bind
    loop = EventLoop()
    seen = []
    loop.register("spot", lambda ev, t: seen.append(
        (t, ev.payload["notice"].kind, ev.payload["notice"].target)))
    trace.bind(loop)
    trace.inject(20.0, 1)                 # after bind, BEHIND the first
    loop.run()
    assert seen == [
        (20.0, "rebalance_recommendation", 1),
        (30.0, "interruption_notice", 1),
        (35.0, "terminate", 1),
        (50.0, "rebalance_recommendation", 0),
        (60.0, "interruption_notice", 0),
        (65.0, "terminate", 0)]


def test_equal_timestamp_events_poll_in_injection_order():
    """Ties in time break by injection sequence, and a subscription
    delivers each event exactly once even when a lifecycle lands behind
    an already-polled watermark."""
    trace = FaultTrace(rebalance_lead=0.0, notice_deadline=0.0)
    trace.inject(10.0, 3)
    trace.inject(10.0, 1)                 # same instant, later injection
    sub = trace.subscribe()
    assert [(n.target, n.kind) for n in sub.poll(10.0)] == [
        (3, "rebalance_recommendation"), (3, "interruption_notice"),
        (3, "terminate"),
        (1, "rebalance_recommendation"), (1, "interruption_notice"),
        (1, "terminate")]
    trace.inject(5.0, 2)                  # behind the watermark
    assert [n.target for n in sub.poll(10.0)] == [2, 2, 2]
    assert sub.poll(10.0) == []


def test_market_driven_schedule_is_purchase_deterministic():
    """Same exchange seed + same purchase sequence -> bit-identical
    interruption schedule in the trace (whole-cluster determinism)."""
    from repro.cluster import InstanceType
    from repro.market import MarketCatalog, SpotExchange, SpotMarket

    def build():
        cat = MarketCatalog()
        cat.add_market(SpotMarket("m", base_rate=0.3,
                                  interruptions_per_hour=30.0, seed=5))
        it = InstanceType("std.1x", 1.0, cost_per_hour=1.0)
        cat.list_instance(it, markets=("m",))
        ex = SpotExchange(cat, seed=7, mode="naive")
        trace = FaultTrace(rebalance_lead=6.0, notice_deadline=4.0)
        for rid in range(6):
            _, t_int = ex.purchase(rid, it, t=10.0 * rid, market="m")
            if t_int is not None:
                trace.inject(t_int, rid)
        return trace

    a, b = build(), build()
    assert a.interruptions and a.interruptions == b.interruptions
    assert np.all([x == y for x, y in zip(a.interruptions,
                                          b.interruptions)])
