"""FaultTrace contract tests (the §IV schedule the market layer drives).

PR-6 coverage satellites: file round-trips, late injection into a bound
loop (how per-purchase market interruptions arrive), equal-timestamp
delivery order, and purchase-sequence determinism of the market-driven
schedule.
"""

import numpy as np

from repro.runtime import EventLoop, FaultTrace


def test_trace_file_roundtrip(tmp_path):
    """``to_file`` -> ``from_file`` reproduces the schedule exactly,
    including floats with no short decimal form."""
    trace = FaultTrace(rebalance_lead=6.0, notice_deadline=4.0)
    trace.inject(1.0 / 3.0, 2)
    trace.inject(92.94171263538088, 0)
    trace.inject(100.0, 1)
    p = tmp_path / "faults.txt"
    trace.to_file(str(p))
    back = FaultTrace.from_file(str(p), rebalance_lead=6.0,
                                notice_deadline=4.0)
    assert back.interruptions == trace.interruptions
    assert [(n.t, n.kind, n.target) for n in back.events()] \
        == [(n.t, n.kind, n.target) for n in trace.events()]


def test_inject_after_bind_reaches_the_loop():
    """A lifecycle injected AFTER ``bind`` still schedules its events on
    the bound loop — the enabler for market-driven injection, where every
    mid-run fallback purchase samples a fresh interruption."""
    trace = FaultTrace(rebalance_lead=10.0, notice_deadline=5.0)
    trace.inject(50.0, 0)                 # before bind
    loop = EventLoop()
    seen = []
    loop.register("spot", lambda ev, t: seen.append(
        (t, ev.payload["notice"].kind, ev.payload["notice"].target)))
    trace.bind(loop)
    trace.inject(20.0, 1)                 # after bind, BEHIND the first
    loop.run()
    assert seen == [
        (20.0, "rebalance_recommendation", 1),
        (30.0, "interruption_notice", 1),
        (35.0, "terminate", 1),
        (50.0, "rebalance_recommendation", 0),
        (60.0, "interruption_notice", 0),
        (65.0, "terminate", 0)]


def test_equal_timestamp_events_poll_in_injection_order():
    """Ties in time break by injection sequence, and a subscription
    delivers each event exactly once even when a lifecycle lands behind
    an already-polled watermark."""
    trace = FaultTrace(rebalance_lead=0.0, notice_deadline=0.0)
    trace.inject(10.0, 3)
    trace.inject(10.0, 1)                 # same instant, later injection
    sub = trace.subscribe()
    assert [(n.target, n.kind) for n in sub.poll(10.0)] == [
        (3, "rebalance_recommendation"), (3, "interruption_notice"),
        (3, "terminate"),
        (1, "rebalance_recommendation"), (1, "interruption_notice"),
        (1, "terminate")]
    trace.inject(5.0, 2)                  # behind the watermark
    assert [n.target for n in sub.poll(10.0)] == [2, 2, 2]
    assert sub.poll(10.0) == []


def test_chaos_kinds_file_roundtrip(tmp_path):
    """Chaos faults ride the same trace file as spot lifecycles: mixed
    schedules round-trip exactly, parameters included."""
    trace = FaultTrace(rebalance_lead=6.0, notice_deadline=4.0)
    trace.inject(1.0 / 3.0, 2)                       # spot lifecycle
    trace.inject_hard_kill(7.25, 0)
    trace.inject_slowdown(2.0 / 7.0, 1, factor=3.5, duration=12.5)
    trace.inject_contention(9.0, factor=2.0, duration=8.0)
    trace.inject_endpoint_failure(11.0, 1, count=3)
    p = tmp_path / "chaos.txt"
    trace.to_file(str(p))
    back = FaultTrace.from_file(str(p), rebalance_lead=6.0,
                                notice_deadline=4.0)
    assert back.interruptions == trace.interruptions
    assert [(n.t, n.kind, n.target, n.factor, n.duration, n.count)
            for n in back.chaos] \
        == [(n.t, n.kind, n.target, n.factor, n.duration, n.count)
            for n in trace.chaos]
    assert [(n.t, n.kind, n.target) for n in back.events()] \
        == [(n.t, n.kind, n.target) for n in trace.events()]


def test_chaos_inject_after_bind_reaches_the_loop():
    """Chaos kinds injected after ``bind`` land on the bound loop in
    time order, interleaved with lifecycle events, parameters intact."""
    trace = FaultTrace(rebalance_lead=10.0, notice_deadline=5.0)
    trace.inject_slowdown(40.0, 2, factor=2.0, duration=6.0)  # before bind
    loop = EventLoop()
    seen = []
    loop.register("spot", lambda ev, t: seen.append(
        (t, ev.payload["notice"].kind, ev.payload["notice"].target)))
    trace.bind(loop)
    trace.inject_hard_kill(25.0, 0)       # after bind, BEHIND the first
    trace.inject(20.0, 1)                 # lifecycle interleaves
    notice = trace.inject_endpoint_failure(45.0, 1, count=2)
    assert notice.count == 2
    loop.run()
    assert seen == [
        (20.0, "rebalance_recommendation", 1),
        (25.0, "hard_kill", 0),
        (30.0, "interruption_notice", 1),
        (35.0, "terminate", 1),
        (40.0, "slowdown", 2),
        (45.0, "endpoint_failure", 1)]


def test_chaos_sampled_soup_is_seed_deterministic():
    """One seed, one soup: ``chaos_sampled`` replays identically (the
    recovery-on/off A/B depends on this), and every fault is a known
    chaos kind."""
    from repro.runtime import CHAOS_KINDS
    kw = dict(rate=0.1, horizon=300.0, targets=4, seed=11)
    a = FaultTrace.chaos_sampled(**kw)
    b = FaultTrace.chaos_sampled(**kw)
    assert a.chaos, "soup sampled empty"
    assert [(n.t, n.kind, n.target) for n in a.chaos] \
        == [(n.t, n.kind, n.target) for n in b.chaos]
    assert all(n.kind in CHAOS_KINDS for n in a.chaos)
    c = FaultTrace.chaos_sampled(**{**kw, "seed": 12})
    assert [(n.t, n.kind) for n in a.chaos] \
        != [(n.t, n.kind) for n in c.chaos]


def test_market_driven_schedule_is_purchase_deterministic():
    """Same exchange seed + same purchase sequence -> bit-identical
    interruption schedule in the trace (whole-cluster determinism)."""
    from repro.cluster import InstanceType
    from repro.market import MarketCatalog, SpotExchange, SpotMarket

    def build():
        cat = MarketCatalog()
        cat.add_market(SpotMarket("m", base_rate=0.3,
                                  interruptions_per_hour=30.0, seed=5))
        it = InstanceType("std.1x", 1.0, cost_per_hour=1.0)
        cat.list_instance(it, markets=("m",))
        ex = SpotExchange(cat, seed=7, mode="naive")
        trace = FaultTrace(rebalance_lead=6.0, notice_deadline=4.0)
        for rid in range(6):
            _, t_int = ex.purchase(rid, it, t=10.0 * rid, market="m")
            if t_int is not None:
                trace.inject(t_int, rid)
        return trace

    a, b = build(), build()
    assert a.interruptions and a.interruptions == b.interruptions
    assert np.all([x == y for x, y in zip(a.interruptions,
                                          b.interruptions)])
