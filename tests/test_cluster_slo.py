"""SLO scheduling layer: priority admission, deadline routing, mid-stream
migration, multi-model fleets, closed-loop offered load.

The acceptance bar (paper §III/§IV + the elastic-job-scheduler deadline
layer):

* batch-class arrivals are *held* while the fleet lacks backlog headroom
  and admitted when it opens — interactive work is never held;
* the deadline-aware router strictly improves interactive deadline
  attainment and p99 latency over FIFO rate-aware on the same seeded
  arrival/fault trace, with bit-identical per-request tokens;
* the recurring ``rebalance`` event moves in-flight slots off
  overloaded/slow replicas through the snapshot/restore path, losing no
  token;
* replicas belong to per-model pools; routing, readmission and
  autoscaling never cross pools;
* a closed-loop think-time process keeps at most ``n_users`` requests in
  flight — offered load tracks completions.
"""

import math

import jax
import numpy as np
import pytest

from repro.cluster import (DeadlineAwareRouter, InstanceType,
                           RateAwareRouter, ServingCluster)
from repro.cluster.metrics import ClusterMetrics
from repro.configs import get_config
from repro.models import model_zoo as zoo
from repro.runtime import FaultTrace
from repro.serving.engine import Request
from repro.serving.workload import (BATCH, INTERACTIVE, STANDARD,
                                    ClosedLoopThinkTime, PoissonArrivals,
                                    SLOClass, classed_requests,
                                    synthetic_requests)


@pytest.fixture(scope="module")
def model():
    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    return cfg, params


@pytest.fixture(scope="module")
def ssm_model():
    cfg = get_config("mamba2-780m").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    return cfg, params


FLEET = [InstanceType("fast.2x", 2.0), InstanceType("fast.2x", 2.0),
         InstanceType("slow.1x", 0.7), InstanceType("slow.1x", 0.7)]

TIGHT = SLOClass("interactive", 0, deadline=12.0)
LOOSE = SLOClass("batch", 2, deadline=400.0, admit_lazily=True)


def _mixed_requests(cfg, n=24, seed=0):
    return classed_requests(n, cfg.vocab_size, interactive_frac=0.5,
                            seed=seed, interactive=TIGHT, batch=LOOSE)


def _run(model, *, slo_aware, n=24, rate=2.0, interrupt=True,
         rebalance_interval=2.0, **kw):
    cfg, params = model
    trace = FaultTrace(rebalance_lead=6.0, notice_deadline=4.0)
    if interrupt:
        trace.inject(4.0, 0)
    base = dict(dt=1.0, batch_size=2, max_seq=48, trace=trace)
    base.update(kw)
    if slo_aware:
        cl = ServingCluster(cfg, params, FLEET,
                            router=DeadlineAwareRouter(),
                            admission="priority",
                            batch_admit_headroom=24.0,
                            rebalance_interval=rebalance_interval, **base)
    else:
        cl = ServingCluster(cfg, params, FLEET,
                            router=RateAwareRouter(), **base)
    reqs = _mixed_requests(cfg, n=n)
    cl.attach_arrivals(PoissonArrivals(reqs, rate, seed=0))
    out = cl.run(max_time=10_000)
    return cl, reqs, out


# ----------------------------------------------------------- A/B headline
def test_slo_aware_beats_fifo_on_interactive_attainment(model):
    """The tentpole claim, at test scale: same seeded arrivals + fault
    trace, strictly better interactive attainment AND p99, identical
    decoded tokens, nothing dropped."""
    _, fifo_reqs, fifo = _run(model, slo_aware=False)
    cl, slo_reqs, slo = _run(model, slo_aware=True)
    assert fifo["dropped"] == 0 and slo["dropped"] == 0
    assert slo["attainment_interactive"] > fifo["attainment_interactive"]
    assert (slo["p99_latency_interactive"]
            < fifo["p99_latency_interactive"])
    # greedy decode is placement/migration-independent: the SLO layer may
    # only reorder *time*, never change tokens
    for a, b in zip(fifo_reqs, slo_reqs):
        assert a.out_tokens == b.out_tokens, a.rid
    # and the rebalancer actually exercised mid-stream migration
    assert slo["rebalance_migrations"] > 0
    assert any("rebalance req" in msg for _, msg in cl.timeline)


def test_slo_run_is_deterministic(model):
    runs = [_run(model, slo_aware=True) for _ in range(2)]
    (cl_a, _, out_a), (cl_b, _, out_b) = runs
    assert cl_a.loop.journal == cl_b.loop.journal
    assert cl_a.timeline == cl_b.timeline
    drop = "interruption_overhead_s"
    assert ({k: v for k, v in out_a.items() if k != drop}
            == {k: v for k, v in out_b.items() if k != drop})


# ----------------------------------------------------- priority admission
def test_priority_admission_holds_batch_until_headroom(model):
    """With a tiny headroom, batch arrivals wait at the door while
    interactive arrivals are admitted immediately; held work is admitted
    later (nothing starves) once backlog drains."""
    cfg, params = model
    cl = ServingCluster(cfg, params, FLEET[:2],
                        router=DeadlineAwareRouter(),
                        admission="priority", batch_admit_headroom=4.0,
                        dt=1.0, batch_size=2, max_seq=48)
    reqs = _mixed_requests(cfg, n=16, seed=3)
    for r in reqs:
        cl.submit(r, at=0.0)
    out = cl.run(max_time=10_000)
    held = [msg for _, msg in cl.timeline if msg.startswith("hold req")]
    admitted = [msg for _, msg in cl.timeline
                if msg.startswith("admit req")]
    assert held, "no batch request was ever held"
    assert len(admitted) == len(held), "held work starved"
    for msg in held:
        assert "(batch" in msg          # only the lazy class is held
    assert out["completed"] == len(reqs) and out["dropped"] == 0


def test_fifo_admission_never_holds(model):
    cfg, params = model
    cl = ServingCluster(cfg, params, FLEET[:2], router=RateAwareRouter(),
                        dt=1.0, batch_size=2, max_seq=48,
                        batch_admit_headroom=0.1)   # ignored under fifo
    for r in _mixed_requests(cfg, n=8, seed=4):
        cl.submit(r, at=0.0)
    out = cl.run(max_time=10_000)
    assert not any(msg.startswith("hold req") for _, msg in cl.timeline)
    assert out["completed"] == 8


# ------------------------------------------------------- deadline routing
def _stub_target(free_slots=0, slot_costs=(), restores=()):
    """A replica stand-in exposing just what ``_slot_free_times`` reads."""
    from types import SimpleNamespace
    eng = SimpleNamespace(
        free_slots=free_slots,
        slot_costs=lambda: [(i, c) for i, c in enumerate(slot_costs)],
        restore_costs=lambda discount=None: list(restores))
    return SimpleNamespace(engine=eng)


def test_deadline_router_repairs_predicted_misses(model):
    """A request that GreedyRefine would leave behind a long-running
    slot on the fast replica is relocated when that placement predicts
    a deadline miss the other replica's free slot avoids."""
    router = DeadlineAwareRouter()
    pending = [Request(rid=0, prompt=np.zeros(3, np.int32),
                       max_new_tokens=10, slo=TIGHT, arrival_t=0.0)]
    loads = np.asarray([10.0])
    rate = np.asarray([2.0, 1.0])
    base = np.asarray([200.0, 0.0])
    deadlines = np.asarray([12.0])
    # fast replica: every slot busy for 100s; slow replica: a free slot
    targets = [_stub_target(slot_costs=[200.0]), _stub_target(free_slots=1)]
    slot_free = router._slot_free_times(targets, rate)
    assert slot_free == [[100.0], [0.0]]
    # pinned to the fast-but-fully-busy replica: predicted miss
    miss, missed = router._predicted_misses(
        np.asarray([0]), loads, rate, slot_free, deadlines, now=0.0)
    assert miss == 1 and missed == [0]
    fixed = router._refine_assignment(
        np.asarray([0]), targets, pending, loads, rate, base, now=0.0)
    assert fixed[0] == 1                # moved to the idle slow replica
    miss, _ = router._predicted_misses(
        fixed, loads, rate, slot_free, deadlines, now=0.0)
    assert miss == 0


def test_deadline_router_slot_level_parallelism():
    """Two free slots serve two queued requests in parallel: the old
    serial model predicted the second request missing (10s + 10s > 15s
    deadline); the slot-level EDF simulation predicts zero misses — and
    restore-queue units claim slots ahead of fresh work."""
    router = DeadlineAwareRouter()
    rate = np.asarray([1.0])
    loads = np.asarray([10.0, 10.0])
    deadlines = np.asarray([15.0, 15.0])
    slot_free = router._slot_free_times([_stub_target(free_slots=2)], rate)
    assert slot_free == [[0.0, 0.0]]
    miss, _ = router._predicted_misses(
        np.asarray([0, 0]), loads, rate, slot_free, deadlines, now=0.0)
    assert miss == 0
    # a restore-queue unit occupies the earliest slot first
    (free,) = router._slot_free_times(
        [_stub_target(free_slots=2, restores=[8.0])], rate)
    assert sorted(free) == [0.0, 8.0]
    miss, missed = router._predicted_misses(
        np.asarray([0, 0]), loads, rate, [free], deadlines, now=0.0)
    assert miss == 1 and missed == [1]   # 8 + 10 > 15: one slot is late


def test_deadline_router_orders_by_priority_then_deadline():
    router = DeadlineAwareRouter()
    mk = (lambda rid, slo, t: Request(rid=rid,
                                      prompt=np.zeros(3, np.int32),
                                      slo=slo, arrival_t=t))
    batch = mk(0, LOOSE, 0.0)
    late_int = mk(1, TIGHT, 5.0)
    early_int = mk(2, TIGHT, 1.0)
    ordered = router._order_pending([batch, late_int, early_int])
    assert [r.rid for r in ordered] == [2, 1, 0]


# ---------------------------------------------------- mid-stream migration
def test_rebalance_moves_slots_and_loses_no_tokens(model):
    """Force a skewed placement (round-robin is rate-oblivious), enable
    the rebalancer, and check slots migrate off the slow replica with
    bit-identical output vs an unbalanced run."""
    from repro.cluster import RoundRobinRouter
    cfg, params = model
    fleet = [InstanceType("fast.4x", 4.0),
             InstanceType("slow.1x", 0.5)]
    outs = {}
    for interval in (None, 2.0):
        cl = ServingCluster(cfg, params, fleet,
                            router=RoundRobinRouter(), dt=1.0,
                            batch_size=2, max_seq=48,
                            rebalance_interval=interval)
        reqs = synthetic_requests(8, cfg.vocab_size, seed=5,
                                  prompt_len=(3, 8), max_new=(20, 28))
        for r in reqs:
            cl.submit(r, at=0.0)
        out = cl.run(max_time=10_000)
        outs[interval] = (cl, reqs, out)
        assert out["completed"] == 8 and out["dropped"] == 0
    cl_off, reqs_off, out_off = outs[None]
    cl_on, reqs_on, out_on = outs[2.0]
    assert out_off["rebalance_migrations"] == 0
    assert out_on["rebalance_migrations"] > 0
    for a, b in zip(reqs_off, reqs_on):
        assert a.out_tokens == b.out_tokens, a.rid
    # migrating work off the slow replica must not be a pessimization
    assert out_on["virtual_seconds"] <= out_off["virtual_seconds"]
    assert any(msg.startswith("rebalance req")
               for _, msg in cl_on.timeline)


def test_rebalance_respects_balanced_fleets(model):
    """A homogeneous, evenly-loaded fleet sees no spurious migrations."""
    cfg, params = model
    fleet = [InstanceType("base", 1.0), InstanceType("base", 1.0)]
    cl = ServingCluster(cfg, params, fleet, router=RateAwareRouter(),
                        dt=1.0, batch_size=2, max_seq=48,
                        rebalance_interval=1.0)
    reqs = synthetic_requests(8, cfg.vocab_size, seed=6,
                              prompt_len=(4, 5), max_new=12)
    for r in reqs:
        cl.submit(r, at=0.0)
    out = cl.run(max_time=10_000)
    assert out["completed"] == 8
    assert out["rebalance_migrations"] == 0


# -------------------------------------------------------- multi-model fleet
def test_multi_model_fleet_routes_and_scales_per_pool(model, ssm_model):
    """Two model pools (causal + ssm) share one cluster: requests only
    land on their own pool's replicas, both pools complete, and tokens
    per request match a single-model run of the same pool."""
    cfg_a, params_a = model
    cfg_b, params_b = ssm_model
    fleet = [InstanceType("a.fast", 2.0, model_id="granite"),
             InstanceType("a.slow", 1.0, model_id="granite"),
             InstanceType("b.fast", 2.0, model_id="mamba"),
             InstanceType("b.slow", 1.0, model_id="mamba")]
    cl = ServingCluster(cfg_a, params_a, fleet,
                        router=DeadlineAwareRouter(),
                        models={"granite": (cfg_a, params_a),
                                "mamba": (cfg_b, params_b)},
                        dt=1.0, batch_size=2, max_seq=48)
    vocab = min(cfg_a.vocab_size, cfg_b.vocab_size)
    reqs = synthetic_requests(12, vocab, seed=7, prompt_len=(3, 8))
    for i, r in enumerate(reqs):
        r.model_id = "granite" if i % 2 == 0 else "mamba"
        cl.submit(r, at=0.0)
    out = cl.run(max_time=10_000)
    assert out["completed"] == 12 and out["dropped"] == 0
    # replicas only ever served their own pool
    by_model = {"granite": {0, 1}, "mamba": {2, 3}}
    for rep in cl.replicas:
        assert rep.rid in by_model[rep.model_id]
    # single-model reference runs reproduce each pool's tokens exactly
    for model_id, (cfg_m, params_m) in (("granite", (cfg_a, params_a)),
                                        ("mamba", (cfg_b, params_b))):
        sub = [r for r in reqs if r.model_id == model_id]
        ref_cl = ServingCluster(
            cfg_m, params_m,
            [InstanceType("x", 2.0), InstanceType("y", 1.0)],
            router=RateAwareRouter(), dt=1.0, batch_size=2, max_seq=48)
        refs = synthetic_requests(12, vocab, seed=7, prompt_len=(3, 8))
        for i, r in enumerate(refs):
            if (("granite" if i % 2 == 0 else "mamba") == model_id):
                ref_cl.submit(r, at=0.0)
        ref_cl.run(max_time=10_000)
        for a in sub:
            b = next(r for r in refs if r.rid == a.rid)
            assert a.out_tokens == b.out_tokens, (model_id, a.rid)


def test_unserved_model_requests_wait_not_crash(model):
    """A request for a pool with no admitting replica stays queued (and
    the run simply times out with it pending) instead of crashing or
    being mis-placed."""
    cfg, params = model
    cl = ServingCluster(cfg, params, [InstanceType("a", 1.0)],
                        router=DeadlineAwareRouter(), dt=1.0,
                        batch_size=2, max_seq=48)
    good = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                   max_new_tokens=4)
    orphan = Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                     max_new_tokens=4, model_id="missing")
    cl.submit(good, at=0.0)
    cl.submit(orphan, at=0.0)
    out = cl.run(max_time=50)
    assert good.done
    assert not orphan.done and orphan in cl.router.queue


# ------------------------------------------------------------ closed loop
def test_closed_loop_offered_load_tracks_completions():
    """Unit: arrivals beyond the initial ``n_users`` are re-armed one per
    completion, strictly after it."""
    reqs = synthetic_requests(6, 100, seed=8)
    proc = ClosedLoopThinkTime(reqs, n_users=2, think_mean=0.5, seed=1)
    first = proc.initial()
    assert [r.rid for _, r in first] == [0, 1]
    t = 1.0
    in_flight = len(first)
    while True:
        done_req = reqs[len(proc.completed)]
        nxt = proc.on_complete(done_req, t)
        in_flight -= 1
        if nxt is None:
            break
        t_next, r = nxt
        assert t_next >= t            # re-armed after the completion
        in_flight += 1
        assert in_flight <= proc.n_users
        t = t_next + 0.5
    assert len(proc.issued) == len(reqs)
    # every post-initial arrival pairs with the completion that armed it
    for (t_done, _), (t_arr, _) in zip(proc.completed,
                                       proc.issued[proc.n_users:]):
        assert t_arr >= t_done


def test_closed_loop_cluster_never_exceeds_n_users(model):
    cfg, params = model
    cl = ServingCluster(cfg, params, FLEET[:2], router=RateAwareRouter(),
                        dt=1.0, batch_size=2, max_seq=48)
    reqs = synthetic_requests(10, cfg.vocab_size, seed=9,
                              prompt_len=(3, 8))
    proc = ClosedLoopThinkTime(reqs, n_users=3, think_mean=1.0, seed=2)
    cl.attach_closed_loop(proc)
    out = cl.run(max_time=10_000)
    assert out["completed"] == 10 and out["dropped"] == 0
    # offered load tracked completions: at every arrival instant the
    # in-flight population (arrived, not yet done) stayed <= n_users
    traces = sorted(cl.metrics.traces.values(), key=lambda t: t.arrival_t)
    for tr in traces:
        in_flight = sum(
            1 for o in traces
            if o.arrival_t <= tr.arrival_t
            and (o.done_t is None or o.done_t > tr.arrival_t))
        assert in_flight <= proc.n_users, tr.rid


def test_closed_loop_ignores_foreign_completions(model):
    """Mixed traffic: completions of directly-submitted (non-session)
    requests must NOT re-arm the closed loop — sessions free only when
    their own request completes, so in-flight session population stays
    <= n_users throughout."""
    cfg, params = model
    cl = ServingCluster(cfg, params, FLEET[:2], router=RateAwareRouter(),
                        dt=1.0, batch_size=2, max_seq=48)
    session_reqs = synthetic_requests(6, cfg.vocab_size, seed=10,
                                      prompt_len=(3, 6))
    proc = ClosedLoopThinkTime(session_reqs, n_users=2, think_mean=1.0,
                               seed=3)
    cl.attach_closed_loop(proc)
    foreign = synthetic_requests(6, cfg.vocab_size, seed=11,
                                 prompt_len=(3, 6), start_rid=100)
    for r in foreign:
        cl.submit(r, at=0.0)
    out = cl.run(max_time=10_000)
    assert out["completed"] == 12 and out["dropped"] == 0
    # only session completions appear in the process's log (order may
    # interleave across sessions)
    assert {rid for _, rid in proc.completed} == {r.rid
                                                  for r in session_reqs}
    session_traces = sorted(
        (cl.metrics.traces[r.rid] for r in session_reqs),
        key=lambda t: t.arrival_t)
    for tr in session_traces:
        in_flight = sum(
            1 for o in session_traces
            if o.arrival_t <= tr.arrival_t
            and (o.done_t is None or o.done_t > tr.arrival_t))
        assert in_flight <= proc.n_users, tr.rid


# ---------------------------------------------------------------- metrics
def test_metrics_attainment_and_overdue():
    m = ClusterMetrics()
    m.on_submit(0, 0.0, slo="interactive", deadline_t=10.0)
    m.on_submit(1, 0.0, slo="interactive", deadline_t=10.0)
    m.on_submit(2, 0.0, slo="batch", deadline_t=100.0)
    m.on_done(0, 5.0, tokens=4)         # met
    m.on_done(1, 20.0, tokens=4)        # missed (late)
    assert m.class_attainment("interactive") == 0.5
    assert m.class_attainment("batch") == 0.0   # incomplete = missed
    assert m.overdue(now=50.0) == {}            # batch not yet overdue
    assert m.overdue(now=150.0) == {"batch": 1}
    s = m.summary(now=150.0)
    assert s["attainment_interactive"] == 0.5
    assert s["misses_interactive"] == 1
    assert s["misses_batch"] == 1
    assert m.class_attainment("nope") is None


def test_request_deadline_helper():
    r = Request(rid=0, prompt=np.zeros(2, np.int32), slo=TIGHT)
    assert r.deadline_t() == math.inf       # not arrived yet
    r.arrival_t = 3.0
    assert r.deadline_t() == pytest.approx(15.0)
    assert Request(rid=1, prompt=np.zeros(2, np.int32),
                   slo=STANDARD, arrival_t=0.0).deadline_t() == math.inf
    assert INTERACTIVE.priority < STANDARD.priority < BATCH.priority
    assert BATCH.admit_lazily and not INTERACTIVE.admit_lazily
