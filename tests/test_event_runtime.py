"""Shared discrete-event core: ordering, fault traces, determinism.

The acceptance bar for the unified runtime:
  * one EventLoop heap serves every subsystem, ties broken by schedule
    order, so identical inputs give bit-identical event timelines;
  * a single FaultTrace drives CloudManager Mode-C, a ServingCluster
    drain, and the tile runtime with IDENTICAL lifecycle timestamps;
  * open-loop arrival processes are seeded and replayable.
"""

import jax
import numpy as np
import pytest

from repro.cluster import InstanceType, RateAwareRouter, ServingCluster
from repro.configs import get_config
from repro.core.cloud import CloudManager, Mode, StageCostModel
from repro.core.overdecomp import HostTileRuntime, TileGrid, TileRuntimeDriver
from repro.models import model_zoo as zoo
from repro.runtime import EventLoop, FaultTrace, SpotEventFeed, VirtualClock
from repro.serving.workload import (BatchArrivals, PoissonArrivals,
                                    TraceArrivals, make_arrivals,
                                    synthetic_requests)


@pytest.fixture(scope="module")
def model():
    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    return cfg, params


FLEET = [InstanceType("fast.2x", 2.0), InstanceType("slow.1x", 0.7)]


# ----------------------------------------------------------------- loop
def test_event_loop_orders_by_time_then_seq():
    loop = EventLoop()
    seen = []
    loop.register("a", lambda ev, t: seen.append((t, ev.payload["i"])))
    loop.schedule(2.0, "a", i=0)
    loop.schedule(1.0, "a", i=1)
    loop.schedule(1.0, "a", i=2)    # same t: schedule order breaks the tie
    assert loop.run() == 3
    assert seen == [(1.0, 1), (1.0, 2), (2.0, 0)]
    assert [j[0] for j in loop.journal] == [1.0, 1.0, 2.0]


def test_event_loop_cancel_and_until():
    loop = EventLoop()
    seen = []
    loop.register("a", lambda ev, t: seen.append(t))
    ev = loop.schedule(1.0, "a")
    loop.schedule(2.0, "a")
    loop.schedule(5.0, "a")
    loop.cancel(ev)
    assert loop.run(until=3.0) == 1
    assert seen == [2.0] and loop.now() == 2.0 and loop.peek_t() == 5.0


def test_event_loop_rejects_duplicate_and_unknown_kinds():
    loop = EventLoop()
    loop.register("a", lambda ev, t: None)
    with pytest.raises(ValueError):
        loop.register("a", lambda ev, t: None)
    loop.schedule(1.0, "mystery")
    with pytest.raises(ValueError):
        loop.run()


def test_handlers_can_schedule_during_dispatch():
    loop = EventLoop(VirtualClock())
    seen = []

    def chain(ev, t):
        seen.append(t)
        if t < 3.0:
            loop.schedule(t + 1.0, "chain")

    loop.register("chain", chain)
    loop.schedule(1.0, "chain")
    loop.run()
    assert seen == [1.0, 2.0, 3.0]


# ----------------------------------------------------------------- trace
def test_fault_trace_materializes_lifecycle():
    trace = FaultTrace(rebalance_lead=10.0, notice_deadline=5.0)
    trace.inject(t=100.0, target=7)
    assert [(n.t, n.kind) for n in trace.events()] == [
        (100.0, "rebalance_recommendation"),
        (110.0, "interruption_notice"),
        (115.0, "terminate")]


def test_fault_trace_sampled_is_seeded():
    kw = dict(rate=0.01, horizon=2000.0, targets=4, seed=3)
    a, b = FaultTrace.sampled(**kw), FaultTrace.sampled(**kw)
    assert a.interruptions == b.interruptions and a.interruptions
    assert a.interruptions != FaultTrace.sampled(**{**kw,
                                                    "seed": 4}).interruptions


def test_fault_trace_from_file(tmp_path):
    p = tmp_path / "faults.txt"
    p.write_text("# t target\n5.0 1\n12.5 0\n")
    trace = FaultTrace.from_file(str(p), rebalance_lead=1.0,
                                 notice_deadline=1.0)
    assert trace.interruptions == [(5.0, 1), (12.5, 0)]
    assert trace.events()[0].t == 5.0


def test_feed_is_a_view_over_a_shared_trace():
    trace = FaultTrace(rebalance_lead=10.0, notice_deadline=5.0)
    feed_a, feed_b = (SpotEventFeed(trace=trace),
                      SpotEventFeed(trace=trace))
    feed_a.inject_interruption(t=100.0, target=7)    # lands on the trace
    assert [n.kind for n in feed_b.poll(110.0)] == [
        "rebalance_recommendation", "interruption_notice"]
    assert feed_b.next_event_t == 115.0
    # independent cursors: feed_a has consumed nothing yet
    assert feed_a.next_event_t == 100.0
    # a lifecycle injected BEHIND feed_b's poll watermark still delivers
    trace.inject(t=50.0, target=3)
    assert [(n.t, n.target) for n in feed_b.poll(60.0)] == [
        (50.0, 3), (60.0, 3)]


# ----------------------------------------------------------------- arrivals
def test_arrival_processes():
    reqs = synthetic_requests(8, 200, seed=0)
    assert [t for t, _ in BatchArrivals(reqs)] == [0.0] * 8
    pa, pb = (list(PoissonArrivals(reqs, 2.0, seed=1)),
              list(PoissonArrivals(reqs, 2.0, seed=1)))
    assert [t for t, _ in pa] == [t for t, _ in pb]
    assert all(t1 > t0 for (t0, _), (t1, _) in zip(pa, pa[1:]))
    ta = list(TraceArrivals(reqs, [3.0, 1.0, 2.0]))
    assert [t for t, _ in ta] == [1.0, 2.0, 3.0]     # sorted, truncates


def test_make_arrivals_specs(tmp_path):
    reqs = synthetic_requests(3, 200, seed=0)
    assert isinstance(make_arrivals("batch", reqs), BatchArrivals)
    assert isinstance(make_arrivals("poisson:1.5", reqs), PoissonArrivals)
    p = tmp_path / "arrivals.txt"
    p.write_text("0.5\n1.5\n2.5\n")
    tr = make_arrivals(f"trace:{p}", reqs)
    assert [t for t, _ in tr] == [0.5, 1.5, 2.5]
    with pytest.raises(ValueError):
        make_arrivals("uniform:3", reqs)


# ----------------------------------------------------------------- determinism
def _drive_cluster(model, trace):
    cfg, params = model
    cl = ServingCluster(cfg, params, FLEET, router=RateAwareRouter(),
                        dt=1.0, batch_size=2, max_seq=32, trace=trace)
    reqs = synthetic_requests(8, 200, seed=0, prompt_len=(3, 8))
    cl.attach_arrivals(PoissonArrivals(reqs, 2.0, seed=5))
    return cl, cl.run(max_time=5000)


def test_cluster_event_timeline_bit_identical(model):
    runs = []
    for _ in range(2):
        trace = FaultTrace(rebalance_lead=4.0, notice_deadline=3.0)
        trace.inject(2.0, 0)
        runs.append(_drive_cluster(model, trace))
    (cl_a, out_a), (cl_b, out_b) = runs
    assert cl_a.loop.journal == cl_b.loop.journal   # every event, bit-equal
    assert cl_a.timeline == cl_b.timeline
    # interruption_overhead_s is REAL measured store time (wall-clock);
    # everything virtual must match bit-for-bit
    drop = "interruption_overhead_s"
    assert ({k: v for k, v in out_a.items() if k != drop}
            == {k: v for k, v in out_b.items() if k != drop})


def test_cloud_manager_timeline_bit_identical():
    reports = []
    for _ in range(2):
        cm = CloudManager(n_instances=8, mode=Mode.C_PROACTIVE,
                          cost=StageCostModel(state_bytes=8 * 64e6),
                          total_iters=2000, iter_seconds=0.2)
        cm.inject_interruption(t=100.0, count=3)
        reports.append((cm.run(), cm.loop.journal))
    (rep_a, j_a), (rep_b, j_b) = reports
    assert j_a == j_b
    assert rep_a.timeline == rep_b.timeline
    assert rep_a.total_time == rep_b.total_time
    assert rep_a.rescales == rep_b.rescales


def _lifecycle_ts(timeline, key):
    return [t for t, msg in timeline if msg.startswith(key)]


def test_one_trace_drives_training_and_serving_identically(model):
    """The ROADMAP item: CloudManager and ServingCluster on ONE trace see
    the same notice/terminate timestamps."""
    trace = FaultTrace(rebalance_lead=6.0, notice_deadline=4.0)
    trace.inject(4.0, 0)

    cl, out = _drive_cluster(model, trace)
    assert out["drains"] == 1 and out["dropped"] == 0

    cm = CloudManager(n_instances=4, mode=Mode.C_PROACTIVE,
                      cost=StageCostModel(state_bytes=4 * 64e6),
                      total_iters=2000, iter_seconds=0.2, trace=trace)
    rep = cm.run()

    for key in ("interruption_notice", "terminated"):
        ts_serving = _lifecycle_ts(cl.timeline, key)
        ts_training = _lifecycle_ts(rep.timeline, key)
        assert ts_serving == ts_training == [10.0 if key ==
                                             "interruption_notice" else 14.0]
    # and both match the trace's own materialized schedule
    by_kind = {n.kind: n.t for n in trace.events()}
    assert by_kind["interruption_notice"] == 10.0
    assert by_kind["terminate"] == 14.0


def test_overlapping_lifecycles_on_one_target_hit_distinct_victims():
    """A sampled trace cycles target ids; two in-flight lifecycles with
    the same target must doom/terminate two DIFFERENT instances."""
    cm = CloudManager(n_instances=8, mode=Mode.A_FILESYSTEM,
                      cost=StageCostModel(state_bytes=8 * 64e6),
                      total_iters=20_000, iter_seconds=0.2)
    # second rebalance lands inside the first lifecycle's 300s window
    cm.trace.inject(10.0, 0)
    cm.trace.inject(100.0, 0)
    rep = cm.run()
    terminated = {(t, msg) for t, msg in rep.timeline
                  if msg.startswith("terminated")}
    # lifecycle 1 kills its own victim at 310, lifecycle 2 kills a
    # DIFFERENT one at 400 (pre-fix: both resolved to the second victim)
    assert {t for t, _ in terminated} == {310.0, 400.0}
    assert len({msg for _, msg in terminated}) == 2, rep.timeline


def test_same_timestamp_arrivals_coalesce_to_one_router_pass(model):
    cfg, params = model
    cl = ServingCluster(cfg, params, FLEET, router=RateAwareRouter(),
                        dt=1.0, batch_size=2, max_seq=32)
    calls = []
    inner = cl.router.dispatch
    cl.router.dispatch = lambda *a, **kw: (calls.append(cl.clock.now()),
                                           inner(*a, **kw))[1]
    reqs = synthetic_requests(8, 200, seed=0, prompt_len=(3, 8))
    cl.attach_arrivals(BatchArrivals(reqs))
    out = cl.run(max_time=5000)
    assert out["completed"] == 8
    assert calls.count(0.0) == 1, calls   # 8 arrivals at t=0 -> ONE pass


def test_tile_runtime_replays_same_trace():
    """The stencil app checkpoints at exactly the trace's notice time."""
    trace = FaultTrace(rebalance_lead=2.0, notice_deadline=2.0)
    trace.inject(3.0, 0)
    loop = EventLoop()
    rt = HostTileRuntime(TileGrid(32, 32, 4, 4), n_pes=4, odf=4)
    drv = TileRuntimeDriver(rt, loop, iters=10, step_interval=1.0,
                            lb_interval=4.0, trace=trace)
    loop.run()
    assert rt.iteration == 10
    assert [t for t, _ in drv.checkpoints] == [5.0]   # 3.0 + lead 2.0
    snap_t, snap = drv.checkpoints[0]
    assert snap["iteration"] > 0 and "tiles" in snap
    assert _lifecycle_ts(drv.timeline, "interruption_notice") == [5.0]
    # proactive rebalance fired at the recommendation itself
    assert any(t == 3.0 and msg.startswith("lb") for t, msg in drv.timeline)
