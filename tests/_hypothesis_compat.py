"""Shared hypothesis import fallback for property-test modules.

Without hypothesis installed, ``@given`` tests skip individually (with a
pointer to requirements-dev.txt) while plain unit tests in the same
module still run.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="needs hypothesis (pip install -r requirements-dev.txt)"
        )(f)

    def settings(*a, **k):
        return lambda f: f

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None
    st = _StrategyStub()
