"""MoE dispatch invariants (hypothesis) + routing properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import ARCHS
from repro.models import moe as moe_lib
from repro.models.schema import init_params


def small_cfg(**kw):
    base = ARCHS["qwen3-moe-30b-a3b"].reduced()
    return base.with_(**kw)


@given(e=st.integers(2, 16), k=st.integers(1, 4), t=st.integers(4, 64))
@settings(max_examples=40, deadline=None)
def test_route_topk_valid(e, k, t):
    k = min(k, e)
    cfg = small_cfg(num_experts=e, top_k=k)
    logits = jax.random.normal(jax.random.PRNGKey(t), (t, e))
    idx, w, aux = moe_lib.route(logits, cfg)
    assert idx.shape == (t, k) and w.shape == (t, k)
    assert int(idx.min()) >= 0 and int(idx.max()) < e
    # weights normalized over the k choices
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    # top-1 has the highest weight
    assert bool((w[:, 0] >= w[:, -1] - 1e-6).all())
    assert float(aux) >= 0.0


def test_capacity_bounds_tokens_per_expert():
    cfg = small_cfg(num_experts=4, top_k=2, capacity_factor=1.0)
    T = 32
    C = moe_lib.expert_capacity(cfg, T)
    assert C == max(8, T * 2 // 4)


def test_moe_block_no_drop_equals_dense_computation():
    """With huge capacity, the dispatch/combine path must equal an explicit
    per-token expert sum (no tokens dropped, weights respected)."""
    cfg = small_cfg(num_experts=4, top_k=2, capacity_factor=1e3,
                    num_shared_experts=0)
    sch = moe_lib.moe_schema(cfg)
    p = init_params(sch, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out, aux = moe_lib.moe_block(p, x, cfg)

    # explicit reference: route, then per-token dense expert application
    from repro.models.layers import rms_norm
    h = rms_norm(x, p["norm"], cfg.norm_eps).astype(jnp.bfloat16)
    ht = h.reshape(-1, cfg.d_model)
    logits = jnp.einsum("td,de->te", ht.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    idx, w, _ = moe_lib.route(logits, cfg)
    y = jnp.zeros_like(ht)
    for t in range(ht.shape[0]):
        acc = jnp.zeros((cfg.d_model,), jnp.bfloat16)
        for j in range(cfg.top_k):
            e = int(idx[t, j])
            g = jax.nn.silu(ht[t] @ p["we_gate"][e].astype(jnp.bfloat16))
            u = ht[t] @ p["we_up"][e].astype(jnp.bfloat16)
            acc = acc + w[t, j].astype(jnp.bfloat16) * (
                (g * u) @ p["we_down"][e].astype(jnp.bfloat16))
        y = y.at[t].set(acc)
    ref = x + y.reshape(x.shape).astype(x.dtype)
    err = float(jnp.abs(out - ref).max()) / float(jnp.abs(ref).max())
    assert err < 5e-2, err   # bf16 accumulation-order tolerance


def test_moe_capacity_drops_tokens():
    """With capacity_factor -> tiny, most tokens are dropped and the output
    approaches the residual input (plus shared experts if any)."""
    # moe_groups=1: the per-group capacity floor (8) would otherwise keep
    # most tokens with 16 groups x 16 tokens each
    cfg = small_cfg(num_experts=8, top_k=2, capacity_factor=1e-6,
                    num_shared_experts=0, moe_groups=1)
    sch = moe_lib.moe_schema(cfg)
    p = init_params(sch, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model))
    out, _ = moe_lib.moe_block(p, x, cfg)
    # capacity=8 (floor) x 8 experts = 64 routed slots for 512 tokens
    delta = float(jnp.abs(out - x).mean())
    cfg_full = cfg.with_(capacity_factor=100.0)
    out_full, _ = moe_lib.moe_block(p, x, cfg_full)
    delta_full = float(jnp.abs(out_full - x).mean())
    assert delta < 0.6 * delta_full


def test_shared_experts_applied():
    cfg = small_cfg(num_experts=4, top_k=1, num_shared_experts=2)
    sch = moe_lib.moe_schema(cfg)
    p = init_params(sch, jax.random.PRNGKey(0))
    assert "ws_gate" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))
    out, _ = moe_lib.moe_block(p, x, cfg)
    # zero the shared expert and confirm the output changes
    p2 = dict(p, ws_down=jnp.zeros_like(p["ws_down"]))
    out2, _ = moe_lib.moe_block(p2, x, cfg)
    assert float(jnp.abs(out - out2).max()) > 0
