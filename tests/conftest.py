"""Test-session bootstrap: force 8 host devices before JAX initializes.

Multi-device tests (sharding specs, production meshes, elastic rescale)
need >= 8 devices; on a CPU-only host XLA exposes 1 unless the host
platform is split.  The flag must be in the environment before the first
``import jax`` anywhere in the test session, which is why it lives here
rather than in a fixture.  An operator-provided XLA_FLAGS wins.
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()
