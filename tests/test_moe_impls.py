"""Cross-implementation MoE equivalence (the §Perf ladder's correctness)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import moe as moe_lib
from repro.models.schema import init_params


def _setup(cf=100.0, groups=4):
    cfg = ARCHS["qwen3-moe-30b-a3b"].reduced().with_(
        num_experts=8, top_k=2, capacity_factor=cf, num_shared_experts=0,
        moe_groups=groups)
    p = init_params(moe_lib.moe_schema(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
    return cfg, p, x


def test_grouped_matches_onehot_no_drop():
    cfg, p, x = _setup()
    o1, a1 = moe_lib._moe_grouped(p, x, cfg)
    o2, a2 = moe_lib.moe_block_onehot(p, x, cfg)
    assert float(jnp.abs(o1 - o2).max()) < 1e-3
    assert abs(float(a1) - float(a2)) < 1e-6


def test_grouped_matches_onehot_with_drops_single_group():
    # one group == global capacity semantics -> exact drop agreement
    cfg, p, x = _setup(cf=0.8, groups=1)
    o1, _ = moe_lib._moe_grouped(p, x, cfg)
    o2, _ = moe_lib.moe_block_onehot(p, x, cfg)
    assert float(jnp.abs(o1 - o2).max()) < 1e-3


def test_moe_impl_knob():
    cfg, p, x = _setup()
    o_auto, _ = moe_lib.moe_block(p, x, cfg)              # no mesh -> grouped
    o_hot, _ = moe_lib.moe_block(p, x, cfg.with_(moe_impl="onehot"))
    assert float(jnp.abs(o_auto - o_hot).max()) < 1e-3


def test_grouped_gradients_finite():
    cfg, p, x = _setup(cf=1.0)

    def loss(p, x):
        o, a = moe_lib._moe_grouped(p, x, cfg)
        return (o.astype(jnp.float32) ** 2).mean() + a
    g = jax.grad(loss)(p, x)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf).all())
