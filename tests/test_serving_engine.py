"""ServingEngine hot-path tests: chunked bulk prefill + sync-free decode.

The two tentpole invariants:

* **Equivalence** — chunked bulk prefill (padded bucket ``make_prefill``
  + cache-column scatter) produces bit-identical generated tokens to the
  streamed baseline, for prompts below, at, and across bucket sizes; and
  a slot snapshotted mid-prefill-chunk resumes to the identical
  continuation on another engine.
* **Sync-free decode** — steady-state ``step_many`` windows perform zero
  device->host transfers; the host reconciles progress from its exact
  projection and fetches only at completion/drain boundaries.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model_zoo as zoo
from repro.serving.engine import Request, ServingEngine, request_cost


@pytest.fixture(scope="module")
def model():
    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    return cfg, params


def _prompt(n, seed=0, vocab=200):
    return np.random.default_rng(seed).integers(0, vocab, n, dtype=np.int32)


def _serve(cfg, params, prompts, *, mode, max_seq=96, max_new=6,
           single_step=False, **kw):
    eng = ServingEngine(cfg, params, batch_size=2, max_seq=max_seq,
                        prefill_mode=mode, **kw)
    reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    if single_step:
        steps = 0
        while (eng.n_active or eng.n_queued) and steps < 10_000:
            eng.step()
            steps += 1
    else:
        eng.run_until_idle()
    return reqs, eng


# ------------------------------------------------------------ equivalence
def test_chunked_prefill_bit_identical_to_streamed(model):
    """Prompts below / at / across the bucket sizes, mixed in one batch:
    the bulk-prefilled engine (driven by multi-step fused windows) must
    emit exactly the streamed single-step baseline's tokens."""
    cfg, params = model
    lens = (2, 5, 16, 17, 40, 65)       # buckets are (16, 64) at max_seq=96
    prompts = [_prompt(n, seed=n) for n in lens]
    streamed, _ = _serve(cfg, params, prompts, mode="streamed",
                         single_step=True)
    chunked, eng = _serve(cfg, params, prompts, mode="chunked")
    assert eng.chunk_prefills > 0
    for a, b in zip(streamed, chunked):
        assert a.done and b.done
        assert a.out_tokens == b.out_tokens, (len(a.prompt), a.out_tokens,
                                              b.out_tokens)


def test_bulk_prefill_cache_matches_streamed_cache(model):
    """The scattered cache columns themselves are bit-identical, not just
    the sampled tokens (the stronger invariant behind drain migration)."""
    cfg, params = model
    prompt = _prompt(33, seed=7)
    snaps = {}
    for mode in ("streamed", "chunked"):
        eng = ServingEngine(cfg, params, batch_size=2, max_seq=96,
                            prefill_mode=mode)
        req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=4)
        eng.submit(req)
        # stop right after the prompt is fully in the cache
        while eng.fed_tokens(0) < len(prompt):
            eng.step()
        snaps[mode] = eng.drain_units()[0][0].snapshot
    a, b = snaps["streamed"], snaps["chunked"]
    assert a.fed == b.fed and a.next_tok == b.next_tok
    for k in a.cache:
        # positions beyond fed hold scratch (pad kv / stale columns);
        # only [0, fed) migrates meaning
        seq_ax = None
        axes = zoo.decode_state_logical_axes(cfg).cache[k]
        trimmed = [ax for ax in axes if ax != "cache_batch"]
        if "cache_seq" in trimmed:
            seq_ax = trimmed.index("cache_seq")
        av, bv = a.cache[k], b.cache[k]
        if seq_ax is not None:
            sl = [slice(None)] * av.ndim
            sl[seq_ax] = slice(0, a.fed)
            av, bv = av[tuple(sl)], bv[tuple(sl)]
        assert np.array_equal(av, bv), k


@pytest.mark.parametrize("arch", ["mamba2-780m", "zamba2-2.7b"])
def test_chunked_prefill_recurrent_families(arch):
    """ssm/hybrid bulk prefill (largest fully-real bucket, no pad tokens
    through the recurrence) matches the streamed greedy continuation."""
    cfg = get_config(arch).reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    prompts = [_prompt(20, seed=11, vocab=cfg.vocab_size)]
    streamed, _ = _serve(cfg, params, prompts, mode="streamed", max_seq=48)
    chunked, eng = _serve(cfg, params, prompts, mode="chunked", max_seq=48)
    assert eng.chunk_prefills == 1
    assert streamed[0].out_tokens == chunked[0].out_tokens


def test_snapshot_mid_prefill_chunk_resumes_identically(model):
    """Drain a slot right after its bulk prefill chunk, before the prompt
    is fully fed; the restored continuation must match an uninterrupted
    run bit-for-bit."""
    cfg, params = model
    prompt = _prompt(40, seed=9)        # buckets (16,): chunk 16, tail 23
    ref, _ = _serve(cfg, params, [prompt], mode="chunked", max_new=8)

    eng = ServingEngine(cfg, params, batch_size=2, max_seq=96,
                        prefill_mode="chunked", prefill_buckets=(16,))
    req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=8)
    eng.submit(req)
    eng.step()                          # admit: bulk chunk of 16 + 1 step
    assert eng.chunk_prefills == 1
    assert eng.fed_tokens(0) < len(prompt) - 1     # still mid-prefill
    units, queued = eng.drain_units()
    assert len(units) == 1 and not queued
    assert units[0].progress < len(prompt)   # packed mid-prompt
    assert req.out_tokens == []

    other = ServingEngine(cfg, params, batch_size=2, max_seq=96,
                          prefill_mode="chunked")
    other.unpack(units)
    other.run_until_idle()
    assert req.done
    assert req.out_tokens == ref[0].out_tokens


# ------------------------------------------------------------- sync-free
def test_steady_state_decode_is_sync_free(model, monkeypatch):
    """Mid-generation ``step_many`` windows must perform zero
    device->host transfers; fetches happen only at completion/drain."""
    cfg, params = model
    eng = ServingEngine(cfg, params, batch_size=2, max_seq=96)
    for i in range(2):
        eng.submit(Request(rid=i, prompt=_prompt(10, seed=i),
                           max_new_tokens=60))
    eng.step()                  # admit + first token: prefill boundary
    assert all(eng.fed_tokens(s) >= eng._plen[s] for s in range(2))

    fetches = []
    real_device_get = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda tree: fetches.append(1) or
                        real_device_get(tree))
    syncs0 = eng.host_syncs
    emitted = 0
    for _ in range(6):          # 48 decode steps, nobody completes
        emitted += eng.step_many(8)["emitted"]
    assert emitted == 96
    assert fetches == [], "steady-state decode touched the host"
    assert eng.host_syncs == syncs0
    monkeypatch.undo()

    eng.run_until_idle()        # completion boundary: one poll happens
    assert eng.host_syncs > syncs0
    for req in eng.pop_completed():
        assert len(req.out_tokens) == 60


def test_host_projection_matches_device(model):
    """The host-side progress projection (used for backlog and completion
    detection without syncing) agrees exactly with device truth."""
    cfg, params = model
    eng = ServingEngine(cfg, params, batch_size=2, max_seq=96)
    eng.submit(Request(rid=0, prompt=_prompt(20, seed=3),
                       max_new_tokens=30))
    eng.submit(Request(rid=1, prompt=_prompt(4, seed=4),
                       max_new_tokens=10))
    for _ in range(4):
        eng.step_many(5)
        dev_fed = np.asarray(jax.device_get(eng.sample.fed))
        for slot, req in enumerate(eng._slots):
            if req is not None:
                assert eng.fed_tokens(slot) == int(dev_fed[slot])


# ---------------------------------------------------------- load signals
def test_backlog_discounts_prefill_tokens(model):
    cfg, params = model
    eng = ServingEngine(cfg, params, batch_size=2, max_seq=96)
    long_prompt = Request(rid=0, prompt=_prompt(60, seed=1),
                          max_new_tokens=4)
    eng.submit(long_prompt)
    undiscounted = long_prompt.total_tokens
    assert eng.backlog_tokens() < undiscounted
    assert eng.backlog_tokens() == pytest.approx(
        request_cost(long_prompt, eng.prefill_discount))
    # decode-heavy work is NOT discounted
    decode_heavy = Request(rid=1, prompt=_prompt(2, seed=2),
                           max_new_tokens=40)
    assert request_cost(decode_heavy) > 40
    # a streamed engine pays full decode cost per prompt token, so its
    # backlog must not discount prefill work
    streamed = ServingEngine(cfg, params, batch_size=2, max_seq=96,
                             prefill_mode="streamed")
    assert streamed.prefill_discount == 1.0
    streamed.submit(Request(rid=2, prompt=_prompt(60, seed=1),
                            max_new_tokens=4))
    assert streamed.backlog_tokens() == pytest.approx(60 - 1 + 4)


# ------------------------------------------------------------- EOS exit
def test_eos_early_exit_fewer_steps_identical_tokens(model):
    """Device-side EOS early exit (active-mask clear inside the fused
    loop): the engine finishes in FEWER fused steps, and the emitted
    stream is bit-identical to the non-early-exit run truncated at the
    first EOS."""
    cfg, params = model
    prompt = _prompt(10, seed=21)
    base = ServingEngine(cfg, params, batch_size=2, max_seq=96)
    req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=48)
    base.submit(req)
    base_stats = base.run_until_idle()
    full = list(req.out_tokens)
    assert len(full) == 48
    # pick a token the model actually emits mid-stream as the EOS id
    eos = full[len(full) // 2]
    cut = full.index(eos) + 1           # first occurrence, inclusive

    eng = ServingEngine(cfg, params, batch_size=2, max_seq=96,
                        eos_token=eos)
    req2 = Request(rid=1, prompt=prompt.copy(), max_new_tokens=48)
    eng.submit(req2)
    eos_stats = eng.run_until_idle()
    assert req2.done
    assert req2.out_tokens == full[:cut]
    assert eos_stats["steps"] < base_stats["steps"]


def test_eos_early_exit_batch_slots_independent(model):
    """One slot EOS-exits early; its batchmate decodes to max_new
    unchanged (the device mask clear never leaks across slots)."""
    cfg, params = model
    pa, pb = _prompt(8, seed=22), _prompt(8, seed=23)
    base = ServingEngine(cfg, params, batch_size=2, max_seq=96)
    ra = Request(rid=0, prompt=pa.copy(), max_new_tokens=30)
    rb = Request(rid=1, prompt=pb.copy(), max_new_tokens=30)
    base.submit(ra)
    base.submit(rb)
    base.run_until_idle()
    eos = ra.out_tokens[8]              # a token only slot 0 hits early
    if eos in rb.out_tokens[:8]:
        pytest.skip("both streams hit the token early; seed collision")

    eng = ServingEngine(cfg, params, batch_size=2, max_seq=96,
                        eos_token=eos)
    ra2 = Request(rid=0, prompt=pa.copy(), max_new_tokens=30)
    rb2 = Request(rid=1, prompt=pb.copy(), max_new_tokens=30)
    eng.submit(ra2)
    eng.submit(rb2)
    eng.run_until_idle()
    assert ra2.out_tokens == ra.out_tokens[:ra.out_tokens.index(eos) + 1]
    bcut = (rb.out_tokens.index(eos) + 1 if eos in rb.out_tokens
            else len(rb.out_tokens))
    assert rb2.out_tokens == rb.out_tokens[:bcut]


def test_bucket_selection(model):
    cfg, params = model
    eng = ServingEngine(cfg, params, batch_size=2, max_seq=96)
    assert eng._buckets == (16, 64)     # 256 exceeds the cache
    assert eng._pick_chunk(0) == (0, 0)
    assert eng._pick_chunk(7) == (16, 7)       # padded up
    assert eng._pick_chunk(16) == (16, 16)
    assert eng._pick_chunk(40) == (64, 40)     # padded up
    assert eng._pick_chunk(80) == (64, 64)     # largest bucket + tail
    ssm = get_config("mamba2-780m").reduced()
    sp = zoo.init_state(ssm, jax.random.PRNGKey(0)).params
    es = ServingEngine(ssm, sp, batch_size=2, max_seq=96)
    assert es._pick_chunk(7) == (0, 0)         # no pads: stream short
    assert es._pick_chunk(40) == (16, 16)      # largest fully-real bucket
    assert es._pick_chunk(70) == (64, 64)
