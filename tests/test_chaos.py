"""Chaos fault model + checkpoint-based recovery (paper §IV stressed).

The tentpole invariants:

* **Hard kills are survivable** — a zero-notice kill loses nothing when
  periodic checkpoints + heartbeat failure detection are on: every
  request completes, checkpointed streams continue bit-identically to a
  fault-free run, and the un-checkpointed tail re-decodes from the
  prompt to the same tokens (greedy decode is placement-independent).
* **Recovery off loses work** — the same seeded chaos soup with no
  detector demonstrably drops the killed replica's in-flight requests
  (the A/B the ``cluster_chaos`` benchmark guards in CI).
* **The rest of the soup degrades, not breaks** — slowdown scales the
  step interval, network contention delays staging and heartbeats,
  endpoint failures retry with backoff, stragglers are quarantined.
"""

import jax
import numpy as np
import pytest

from repro.cluster import (CheckpointPolicy, EndpointUnavailable,
                           FailureDetector, HostEndpoint, InstanceType,
                           QuarantineOrder, ReleaseOrder, Replica,
                           ServingCluster, StragglerPolicy)
from repro.cluster.metrics import ClusterMetrics
from repro.runtime import FaultTrace
from repro.configs import get_config
from repro.models import model_zoo as zoo
from repro.serving.engine import Request, ServingEngine
from repro.serving.workload import INTERACTIVE, synthetic_requests


@pytest.fixture(scope="module")
def model():
    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    return cfg, params


FLEET = [InstanceType("std.1x", 1.0), InstanceType("std.1x", 1.0)]


def _chaos_trace():
    """Fixed mixed soup: kill one busy replica mid-stream, slow the
    other, congest the fabric, and break the endpoint once."""
    trace = FaultTrace()
    trace.inject_hard_kill(6.0, 0)
    trace.inject_slowdown(4.0, 1, factor=3.0, duration=10.0)
    trace.inject_contention(5.0, factor=2.0, duration=8.0)
    trace.inject_endpoint_failure(2.0, 0, count=1)
    return trace


def _run(model, *, chaos, recover, n=12):
    cfg, params = model
    kw = {}
    if recover:
        kw = dict(checkpoint=CheckpointPolicy(interval=2.0),
                  health=FailureDetector(heartbeat_interval=1.0,
                                         check_interval=1.0,
                                         suspect_after=2.5,
                                         confirm_after=5.0),
                  straggler=StragglerPolicy())
    cl = ServingCluster(cfg, params, FLEET,
                        trace=_chaos_trace() if chaos else FaultTrace(),
                        dt=1.0, batch_size=2, max_seq=32, **kw)
    reqs = synthetic_requests(n, 200, seed=0, prompt_len=(3, 8))
    for i, r in enumerate(reqs):
        cl.submit(r, at=0.3 * i)
    out = cl.run(max_time=5000)
    return cl, reqs, out


# ------------------------------------------------------------ tentpole A/B
def test_hard_kill_with_recovery_loses_nothing(model):
    """Chaos soup + checkpoints + failure detection: zero requests lost,
    final streams bit-identical to the fault-free run."""
    _, ref_reqs, _ = _run(model, chaos=False, recover=False)
    cl, reqs, out = _run(model, chaos=True, recover=True)
    assert out["hard_kills"] == 1 and out["recoveries"] == 1
    assert out["dropped"] == 0 and out["requests_lost"] == 0
    assert out["completed"] == len(reqs)
    assert all(r.done for r in reqs)
    assert all(a.out_tokens == b.out_tokens
               for a, b in zip(ref_reqs, reqs)), \
        "recovered streams diverged from the fault-free reference"
    # the soup actually bit: checkpoints were taken, the detector fired,
    # contention delayed at least one staging leg, the endpoint retried
    assert out["checkpoints"] > 0 and out["requests_recovered"] > 0
    assert out["contention_delay_s"] > 0
    assert out["endpoint_retries"] >= 1
    assert out["recovery_latency_s"] > 0
    assert any("recover r0" in m for _, m in cl.timeline)


def test_hard_kill_without_recovery_loses_work(model):
    """Same soup, no detector/checkpoints: the killed replica's
    in-flight and queued requests are demonstrably lost (the loop
    drains — nothing keeps retrying forever)."""
    _, reqs, out = _run(model, chaos=True, recover=False)
    lost = [r for r in reqs if not r.done]
    assert lost, "expected the hard kill to strand requests"
    assert out["completed"] == len(reqs) - len(lost)
    assert out["requests_lost"] == len(lost)
    assert out["recoveries"] == 0 and out["checkpoints"] == 0


def test_chaos_run_is_deterministic(model):
    """Two identical chaos+recovery runs dispatch the identical event
    journal and produce identical streams (virtual-time determinism
    survives the whole kill/detect/recover machinery)."""
    cl_a, reqs_a, _ = _run(model, chaos=True, recover=True, n=8)
    cl_b, reqs_b, _ = _run(model, chaos=True, recover=True, n=8)
    assert cl_a.loop.journal == cl_b.loop.journal
    assert all(a.out_tokens == b.out_tokens
               for a, b in zip(reqs_a, reqs_b))


# ------------------------------------------------- S3: stale-event race
def test_stale_lifecycle_event_against_drained_replica_is_noop(model):
    """Equal-timestamp terminate-vs-drain race: a lifecycle event
    delivered against a replica that an earlier same-timestamp event
    already drained+terminated is a guarded no-op — the run completes
    with identical streams, and the schedule replays journal-identically
    run over run."""
    cfg, params = model

    def run(duplicate):
        trace = FaultTrace(rebalance_lead=0.0, notice_deadline=0.0)
        trace.inject(5.0, 0)     # all three events land at t=5.0
        if duplicate:
            # a second full lifecycle against the same victim at the
            # same instant: every event hits an already-drained replica
            trace.inject(5.0, 0)
        cl = ServingCluster(cfg, params, FLEET, trace=trace, dt=1.0,
                            batch_size=2, max_seq=32)
        reqs = synthetic_requests(8, 200, seed=3, prompt_len=(3, 8))
        for r in reqs:
            cl.submit(r, at=0.0)
        out = cl.run(max_time=5000)
        return cl, reqs, out

    _, ref, _ = run(False)
    cl_a, reqs_a, out_a = run(True)
    cl_b, reqs_b, _ = run(True)
    assert out_a["dropped"] == 0 and all(r.done for r in reqs_a)
    assert all(a.out_tokens == b.out_tokens for a, b in zip(ref, reqs_a))
    # only ONE drain was recorded: the duplicate lifecycle found the
    # replica already gone and changed nothing
    assert out_a["drains"] == 1
    assert cl_a.loop.journal == cl_b.loop.journal


# ---------------------------------------------------------- slowdown
def test_slowdown_scales_step_interval(model):
    cfg, params = model
    rep = Replica(0, cfg, params, InstanceType("std.2x", 2.0),
                  batch_size=2, max_seq=32)
    base = rep.step_interval
    rep.apply_slowdown(3.0, until=10.0)
    assert rep.step_interval == pytest.approx(3.0 * base)
    rep.clear_slowdown(now=5.0)      # before the window ends: no-op
    assert rep.step_interval == pytest.approx(3.0 * base)
    rep.apply_slowdown(3.0, until=10.0)
    rep.clear_slowdown(now=10.0)
    assert rep.step_interval == pytest.approx(base)


# ----------------------------------------------------- endpoint retries
def test_endpoint_retries_transient_failures_with_backoff(model):
    cfg, params = model
    eng = ServingEngine(cfg, params, batch_size=2, max_seq=32)
    req = Request(rid=0, prompt=np.arange(1, 6, dtype=np.int32),
                  max_new_tokens=4)
    eng.submit(req)
    eng.step()
    units = eng.pack()
    ep = HostEndpoint(max_retries=3)
    ep.arm_failures(2)               # transient: within the budget
    ep.put(units, "ckpt_r0")
    assert ep.retries == 2 and ep.backoff_s > 0

    ep.arm_failures(5)               # persistent: exceeds max_retries
    with pytest.raises(EndpointUnavailable):
        ep.put(units, "ckpt_r0")


# ------------------------------------------------ checkpoint mechanics
def test_checkpoint_units_is_non_destructive(model):
    """checkpoint_units observes: the engine decodes on to the same
    stream as an unobserved run, and the snapshot is frozen at the
    checkpoint (later decode does not mutate it)."""
    cfg, params = model
    prompt = np.arange(1, 8, dtype=np.int32)

    def run(observe):
        eng = ServingEngine(cfg, params, batch_size=2, max_seq=32)
        req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=8)
        eng.submit(req)
        for _ in range(3):
            eng.step()
        units = eng.checkpoint_units() if observe else []
        frozen = [list(u.snapshot.request.out_tokens) for u in units]
        eng.run_until_idle()
        return req, units, frozen

    ref, _, _ = run(False)
    req, units, frozen = run(True)
    assert req.done and req.out_tokens == ref.out_tokens
    assert len(units) == 1
    assert frozen[0] == list(units[0].snapshot.request.out_tokens)
    assert len(frozen[0]) < len(req.out_tokens)


def test_checkpoint_resume_restores_sampled_stream(model):
    """A temperature>0 stream checkpointed and resumed into a FRESH
    engine continues bit-identically: the snapshot carries the sampler
    rng state."""
    cfg, params = model
    prompt = np.arange(1, 10, dtype=np.int32)

    def fresh():
        return ServingEngine(cfg, params, batch_size=2, max_seq=48,
                             temperature=0.8, seed=7)

    ref_eng = fresh()
    ref = Request(rid=0, prompt=prompt.copy(), max_new_tokens=10)
    ref_eng.submit(ref)
    ref_eng.run_until_idle()

    eng = fresh()
    req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=10)
    eng.submit(req)
    for _ in range(4):
        eng.step()
    units = eng.checkpoint_units()
    assert len(units) == 1 and units[0].snapshot.rng is not None
    # the kill: the engine vanishes; the checkpointed unit resumes on a
    # fresh engine, rewound to the checkpoint
    resumed = units[0].snapshot.request
    eng2 = fresh()
    eng2.unpack(units)
    eng2.run_until_idle()
    assert resumed.done
    assert list(resumed.out_tokens) == list(ref.out_tokens)


# ------------------------------------------------------ failure detector
def test_failure_detector_ladder():
    class Rep:
        def __init__(self, rid):
            self.rid = rid

    det = FailureDetector(heartbeat_interval=1.0, check_interval=1.0,
                          suspect_after=3.0, confirm_after=6.0)
    reps = [Rep(0), Rep(1)]
    det.beat(0, 0.0)
    det.beat(1, 0.0)
    assert det.scan(reps, 1.0) == ([], [], [])
    det.beat(1, 3.5)                         # r1 keeps beating
    suspects, cleared, confirmed = det.scan(reps, 4.0)
    assert suspects == [0] and not cleared and not confirmed
    det.beat(0, 4.5)                         # late beat (contention)
    suspects, cleared, confirmed = det.scan(reps, 5.0)
    assert not suspects and cleared == [0] and not confirmed
    suspects, cleared, confirmed = det.scan(reps, 11.0)
    assert [r.rid for r in confirmed] == [0, 1]
    assert det.scan(reps, 20.0) == ([], [], [])   # forgotten once confirmed
    with pytest.raises(ValueError):
        FailureDetector(suspect_after=5.0, confirm_after=5.0)


# ------------------------------------------------------- straggler policy
class _FakeEngine:
    def __init__(self, slots):
        self._slots = slots

    @property
    def n_active(self):
        return len(self._slots)

    def slot_requests(self):
        return list(enumerate(self._slots))


class _FakeReplica:
    def __init__(self, rid, slots=()):
        self.rid = rid
        self.serving = True
        self.model_id = "m"
        self.quarantined = False
        self.quarantined_t = 0.0
        self.engine = _FakeEngine(list(slots))


class _FakeView:
    def __init__(self, replicas, rates):
        self.replicas = replicas
        self._rates = rates

    def rates(self):
        return self._rates


def test_straggler_policy_quarantines_and_releases():
    urgent = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4,
                     slo=INTERACTIVE)
    urgent.arrival_t = 0.0          # a finite deadline needs an arrival
    lazy = Request(rid=1, prompt=[1, 2, 3], max_new_tokens=4)
    straggler = _FakeReplica(0, slots=[urgent, lazy])
    healthy = [_FakeReplica(1), _FakeReplica(2)]
    view = _FakeView([straggler] + healthy,
                     {0: 0.2, 1: 1.0, 2: 1.0})
    pol = StragglerPolicy(threshold=0.5, min_fleet=2, probe_after=30.0)
    orders = pol.orders(view, now=10.0)
    assert len(orders) == 1 and isinstance(orders[0], QuarantineOrder)
    assert orders[0].rid == 0
    assert orders[0].slots == (0,)           # only the urgent slot moves

    straggler.quarantined = True
    straggler.quarantined_t = 10.0
    # rate recovers -> release by measurement
    view._rates[0] = 0.9
    orders = pol.orders(view, now=15.0)
    assert [type(o) for o in orders] == [ReleaseOrder]
    # still slow but drained: released by the idle probe, not benched
    view._rates[0] = 0.0
    straggler.engine._slots = []
    assert pol.orders(view, now=15.0) == []          # probe not yet due
    orders = pol.orders(view, now=41.0)
    assert [type(o) for o in orders] == [ReleaseOrder]


# --------------------------------------------------- S6: metrics schema
def test_summary_zero_fills_recovery_counters():
    """A fresh fleet summary carries every chaos/recovery key at zero —
    downstream dashboards never KeyError on a quiet run."""
    s = ClusterMetrics().summary(1.0)
    for key in ("hard_kills", "requests_lost", "requests_recovered",
                "recoveries", "replayed_tokens", "recovery_latency_s",
                "recovery_restore_s", "checkpoints", "checkpointed_units",
                "checkpoint_stage_s", "slowdowns", "contention_windows",
                "contention_delay_s", "endpoint_faults",
                "endpoint_retries", "retry_backoff_s", "quarantines"):
        assert s[key] == 0, key
