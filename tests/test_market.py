"""Spot-market economics tests (PR-6 tentpole).

Priced markets with seeded stochastic rates and price-coupled
interruption intensity; a catalog of per-instance-type listings; an
exchange that shops naive-cheapest or interruption-adjusted; pluggable
fallback strategies on spot notices; and a savings ledger whose
by-market / by-strategy report rides ``ClusterMetrics.summary()``.
"""

from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.cluster import InstanceType, ServingCluster
from repro.configs import get_config
from repro.market import (AUTO, FALLBACKS, ON_DEMAND, DifferentMarketFallback,
                          DifferentTypeFallback, MarketAwareScaling,
                          MarketCatalog, OnDemandFallback, PurchaseOrder,
                          QueueWorkFallback, SavingsLedger, ScaleDownFallback,
                          SpotExchange, SpotMarket, make_fallback)
from repro.models import model_zoo as zoo

STD = InstanceType("std.1x", 1.0, cost_per_hour=1.0)
FAST = InstanceType("fast.2x", 2.0, cost_per_hour=1.6)
OD_ONLY = InstanceType("ondemand.1x", 1.0, spot=False, cost_per_hour=1.0)


def two_market_catalog(itypes=(STD,), *, spike=(120.0, 240.0, 5.0)):
    cat = MarketCatalog()
    cat.add_market(SpotMarket(
        "volatile", base_rate=0.25, volatility=0.06, spikes=(spike,),
        interruptions_per_hour=2.0, price_power=3.0, seed=1,
        horizon=600.0))
    cat.add_market(SpotMarket(
        "steady", base_rate=0.45, volatility=0.02,
        interruptions_per_hour=0.05, seed=2, horizon=600.0))
    for it in itypes:
        cat.list_instance(it, markets=("volatile", "steady"))
    return cat


# ------------------------------------------------------------ spot market
def test_price_path_is_seeded_and_floored():
    kw = dict(base_rate=0.3, volatility=0.5, reversion=0.1,
              floor_frac=0.25, horizon=1000.0, dt=5.0)
    a, b = SpotMarket("a", seed=4, **kw), SpotMarket("a", seed=4, **kw)
    ts = np.linspace(0.0, 1200.0, 97)      # incl. beyond the horizon
    assert [a.rate(t) for t in ts] == [b.rate(t) for t in ts]
    assert min(a.rate(t) for t in ts) >= 0.25 * 0.3 - 1e-12
    c = SpotMarket("a", seed=5, **kw)
    assert [a.rate(t) for t in ts] != [c.rate(t) for t in ts]


def test_spike_multiplies_rate_and_couples_intensity():
    m = SpotMarket("m", base_rate=0.2, volatility=0.0,
                   spikes=((100.0, 200.0, 4.0),),
                   interruptions_per_hour=1.5, price_power=2.0)
    assert m.rate(50.0) == pytest.approx(0.2)
    assert m.rate(150.0) == pytest.approx(0.8)
    # intensity scales as (rate/base)**power: 4x price -> 16x intensity
    assert m.intensity(50.0) == pytest.approx(1.5)
    assert m.intensity(150.0) == pytest.approx(1.5 * 16.0)


def test_dollars_matches_numerical_integral():
    m = SpotMarket("m", base_rate=0.3, volatility=0.2, seed=9,
                   spikes=((40.0, 90.0, 3.0),), horizon=400.0, dt=10.0)
    ts = np.linspace(7.0, 311.0, 40_001)
    numeric = np.trapezoid([m.rate(t) for t in ts], ts) / 3600.0
    assert m.dollars(7.0, 311.0) == pytest.approx(numeric, rel=1e-3)
    assert m.mean_rate(7.0, 304.0) \
        == pytest.approx(m.dollars(7.0, 311.0) * 3600.0 / 304.0)


def test_interruption_sampling_is_seeded_and_price_coupled():
    quiet = SpotMarket("q", base_rate=0.3, volatility=0.0,
                       interruptions_per_hour=0.5, horizon=3600.0)
    spiky = SpotMarket("s", base_rate=0.3, volatility=0.0,
                       spikes=((0.0, 3600.0, 5.0),), price_power=3.0,
                       interruptions_per_hour=0.5, horizon=3600.0)
    draws = lambda m, seed: m.sample_interruption(
        0.0, np.random.default_rng(seed))
    assert draws(quiet, 3) == draws(quiet, 3)          # seeded
    hits = lambda m: sum(draws(m, s) is not None for s in range(40))
    assert hits(spiky) > hits(quiet)                   # 125x intensity
    none_market = SpotMarket("z", base_rate=0.3,
                             interruptions_per_hour=0.0)
    assert draws(none_market, 0) is None
    # the `until` cap bounds the sampled window
    capped = spiky.sample_interruption(0.0, np.random.default_rng(1),
                                       until=10.0)
    assert capped is None or capped <= 10.0


# ---------------------------------------------------------------- catalog
def test_catalog_rejects_bad_registrations():
    cat = MarketCatalog()
    cat.add_market(SpotMarket("m", base_rate=0.3))
    with pytest.raises(ValueError, match="already registered"):
        cat.add_market(SpotMarket("m", base_rate=0.4))
    with pytest.raises(ValueError, match="reserved"):
        cat.add_market(SpotMarket(ON_DEMAND, base_rate=0.4))
    with pytest.raises(KeyError, match="unknown market"):
        cat.list_instance(STD, markets=("nope",))
    cat.list_instance(STD, markets=("m",))
    assert cat.on_demand_rate(STD) == STD.cost_per_hour
    assert cat.markets_for(STD) == ("m",)
    with pytest.raises(KeyError, match="not listed"):
        cat.listing(FAST)


# --------------------------------------------------------------- exchange
def test_adjusted_shopper_walks_away_from_the_spike():
    cat = two_market_catalog()
    naive = SpotExchange(cat, seed=0, mode="naive")
    adjusted = SpotExchange(cat, seed=0, mode="adjusted", lookahead_s=600.0)
    # right now volatile is cheapest; inside the lookahead the spike
    # raises both its mean rate and its interruption intensity
    assert naive.best_market(STD, 110.0) == "volatile"
    assert adjusted.best_market(STD, 110.0) == "steady"
    assert adjusted.effective_price(STD, "volatile", 110.0) \
        > adjusted.effective_price(STD, "steady", 110.0)
    assert adjusted.effective_price(STD, ON_DEMAND, 110.0) \
        == STD.cost_per_hour


def test_purchase_sequence_is_deterministic():
    def interruptions(seed):
        ex = SpotExchange(two_market_catalog(), seed=seed, mode="naive")
        out = []
        for rid in range(5):
            _, t_int = ex.purchase(rid, STD, t=5.0 * rid, market="volatile")
            out.append(t_int)
        return out

    assert interruptions(7) == interruptions(7)
    assert interruptions(7) != interruptions(8)


def test_non_spot_instance_always_buys_on_demand():
    cat = two_market_catalog((STD, OD_ONLY))
    ex = SpotExchange(cat, seed=0, mode="naive")
    rec, t_int = ex.purchase(0, OD_ONLY, t=0.0, market=AUTO)
    assert rec.market == ON_DEMAND and t_int is None
    rec, t_int = ex.purchase(1, STD, t=0.0, market=ON_DEMAND)
    assert rec.market == ON_DEMAND and t_int is None


def test_overhead_estimate_learns_from_drain_records():
    ex = SpotExchange(two_market_catalog(), default_overhead_s=60.0)
    assert ex.estimated_overhead_s() == 60.0
    ex.bind_metrics(SimpleNamespace(drains=[
        SimpleNamespace(checkpoint_s=2.0, restore_s=1.0),
        SimpleNamespace(checkpoint_s=4.0, restore_s=3.0)]))
    assert ex.estimated_overhead_s() == pytest.approx(65.0)
    assert ex.interruption_dollars(STD, overhead_s=3600.0) \
        == pytest.approx(STD.cost_per_hour)


# -------------------------------------------------------------- fallbacks
def _rep(itype=STD, market="volatile"):
    return SimpleNamespace(rid=0, itype=itype, model_id=itype.model_id,
                           purchase=SimpleNamespace(market=market))


def test_fallback_strategies():
    cat = two_market_catalog((STD, FAST))
    ex = SpotExchange(cat, seed=0, mode="adjusted")
    rep, view, now = _rep(), None, 110.0
    assert OnDemandFallback().replacement(view, rep, ex, now) \
        == PurchaseOrder(STD, ON_DEMAND)
    # different_market excludes the doomed market, keeps the hardware
    order = DifferentMarketFallback().replacement(view, rep, ex, now)
    assert order.itype == STD and order.market == "steady"
    # different_type reshops the hardware too
    order = DifferentTypeFallback().replacement(view, rep, ex, now)
    assert order.itype == FAST
    assert QueueWorkFallback().replacement(view, rep, ex, now) is None
    assert QueueWorkFallback().queue_until_free
    assert ScaleDownFallback().replacement(view, rep, ex, now) is None
    assert not ScaleDownFallback().queue_until_free


def test_make_fallback():
    assert make_fallback("queue_work").name == "queue_work"
    fb = OnDemandFallback()
    assert make_fallback(fb) is fb
    assert make_fallback(None) is None
    assert set(FALLBACKS) == {"on_demand", "different_market",
                              "different_type", "queue_work", "scale_down"}
    with pytest.raises(ValueError, match="unknown fallback"):
        make_fallback("nope")


# ----------------------------------------------------------------- ledger
def test_ledger_savings_and_breakdowns():
    cat = two_market_catalog()
    ledger = SavingsLedger(cat)
    ex = SpotExchange(cat, seed=0, mode="naive")
    # a cheap pre-spike spot holding vs the same period on demand
    rec, _ = ex.purchase(0, STD, t=0.0, market="volatile")
    ex.ledger.on_terminate(0, 100.0)
    rec2, _ = ex.purchase(1, STD, t=0.0, market=ON_DEMAND,
                          strategy="scale_up")
    spot_cost = cat.market("volatile").dollars(0.0, 100.0)
    od_cost = STD.cost_per_hour * 100.0 / 3600.0
    rep = ex.ledger.report(100.0)
    assert rep["market_dollar_cost"] \
        == pytest.approx(spot_cost + od_cost, abs=1e-6)
    assert rep["on_demand_dollar_cost"] == pytest.approx(2 * od_cost,
                                                         abs=1e-6)
    assert rep["savings_pct"] == pytest.approx(
        100.0 * (1.0 - (spot_cost + od_cost) / (2 * od_cost)), abs=1e-3)
    assert rep["market_volatile_purchases"] == 1
    assert rep["market_on_demand_purchases"] == 1
    assert rep["market_steady_purchases"] == 0     # zero-filled
    assert rep["strategy_initial_purchases"] == 1
    assert rep["strategy_scale_up_purchases"] == 1
    ex.ledger.on_interruption(0, 50.0, overhead_s=2.5)
    assert ex.ledger.report(100.0)["spot_interruptions"] == 1
    assert ex.ledger.report(100.0)["spot_interruption_overhead_s"] \
        == pytest.approx(2.5)


# ---------------------------------------------------------------- scaling
def test_market_aware_scaling_shops_effective_price():
    cat = two_market_catalog((STD, FAST))
    ex = SpotExchange(cat, seed=0, mode="adjusted")
    pol = MarketAwareScaling(ex)
    view = SimpleNamespace(log=lambda msg: None, now=110.0)
    # FAST: 2.0 speed at 1.6 od; on steady both cost ~the same market
    # rate, so speed/$ picks the faster hardware
    pick = pol.select_itype(view, STD.model_id, [])
    assert pick == FAST
    assert pol.replacement(view, _rep()) == FAST


# ----------------------------------------------------------- end to end
@pytest.fixture(scope="module")
def model():
    cfg = get_config("granite-8b").reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(0)).params
    return cfg, params


def _market_cluster(model, *, mode="adjusted",
                    fallback="different_market",
                    spike=(5.0, 300.0, 6.0)):
    cfg, params = model
    fleet = [STD, STD]
    cat = two_market_catalog(spike=spike)
    ex = SpotExchange(cat, seed=0, mode=mode, sample_until=400.0)
    cl = ServingCluster(cfg, params, fleet, dt=1.0, batch_size=2,
                        max_seq=32, rebalance_lead=4.0,
                        notice_deadline=3.0, market=ex, fallback=fallback,
                        autoscaler_kw=dict(replacement_latency=6.0,
                                           scale_down_idle=10_000.0))
    from repro.serving.workload import synthetic_requests
    for r in synthetic_requests(10, cfg.vocab_size, seed=0,
                                prompt_len=(3, 8)):
        cl.submit(r, at=0.0)
    return cl


def _market_run(model, **kw):
    cl = _market_cluster(model, **kw)
    return cl, cl.run(max_time=5000)


def test_cluster_market_run_reports_savings(model):
    cl, out = _market_run(model, mode="naive")
    assert out["dropped"] == 0
    assert 0.0 < out["market_dollar_cost"] < out["on_demand_dollar_cost"]
    assert out["savings_pct"] == pytest.approx(
        100.0 * (1.0 - out["market_dollar_cost"]
                 / out["on_demand_dollar_cost"]), abs=1e-2)
    for key in ("market_volatile_purchases", "market_steady_purchases",
                "strategy_initial_purchases", "spot_interruptions"):
        assert key in out, key
    # the naive shopper bought into the spiking market and got burned;
    # the fallback bought replacement capacity mid-run
    assert out["spot_interruptions"] > 0
    assert out["strategy_different_market_purchases"] > 0
    assert any("buy r" in msg for _, msg in cl.timeline)


def test_cluster_market_run_is_deterministic(model):
    (cl_a, out_a), (cl_b, out_b) = (_market_run(model, mode="naive")
                                    for _ in range(2))
    # staging overheads are REAL wall-clock store timings; everything
    # else (prices, interruption times, dollars) is bit-identical
    wall = ("interruption_overhead_s", "preempt_stage_s",
            "spot_interruption_overhead_s")
    assert {k: v for k, v in out_a.items() if k not in wall} \
        == {k: v for k, v in out_b.items() if k not in wall}
    assert cl_a.timeline == cl_b.timeline
    assert cl_a.faults.interruptions == cl_b.faults.interruptions


def test_interrupted_units_carry_their_hop_journal(model, monkeypatch):
    """A market-driven interruption drain stamps each displaced unit's
    journey (interruption -> land) onto its shared hop journal, visible
    end-to-end under a stable uid."""
    cl = _market_cluster(model, mode="naive")
    captured = []
    orig = cl.readmit
    monkeypatch.setattr(
        cl, "readmit",
        lambda units, now: (captured.extend(units), orig(units, now))[1])
    out = cl.run(max_time=5000)
    assert out["spot_interruptions"] > 0 and captured
    journeys = {u.uid: [h.reason for h in u.hops] for u in captured}
    assert any(j and j[0] == "interruption" and "land" in j
               for j in journeys.values()), journeys
    migrated = [tr for tr in cl.metrics.traces.values()
                if tr.migrations > 0]
    assert migrated, "no request was migrated by the interruption drain"


def test_queue_work_fallback_parks_until_capacity(model):
    """queue_work buys NO replacement: displaced units park until a
    surviving replica has a free slot.  An on-demand instance in the
    fleet guarantees a survivor, so nothing is dropped."""
    cfg, params = model
    cat = two_market_catalog((STD, OD_ONLY), spike=(5.0, 300.0, 6.0))
    ex = SpotExchange(cat, seed=0, mode="naive", sample_until=400.0)
    cl = ServingCluster(cfg, params, [STD, OD_ONLY], dt=1.0,
                        batch_size=2, max_seq=32, rebalance_lead=4.0,
                        notice_deadline=3.0, market=ex,
                        fallback="queue_work",
                        autoscaler_kw=dict(scale_down_idle=10_000.0))
    from repro.serving.workload import synthetic_requests
    for r in synthetic_requests(10, cfg.vocab_size, seed=0,
                                prompt_len=(3, 8)):
        cl.submit(r, at=0.0)
    out = cl.run(max_time=5000)
    assert out["dropped"] == 0 and out["spot_interruptions"] > 0
    # queue_work buys nothing: every purchase is an initial buy
    assert out["purchases"] == out["strategy_initial_purchases"] == 2


def test_market_requires_fallback_pairing(model):
    cfg, params = model
    with pytest.raises(ValueError, match="market"):
        ServingCluster(cfg, params, [STD], fallback="on_demand")
