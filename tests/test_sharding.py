"""Sharding-rule unit tests: divisibility fallbacks, axis dedup, ZeRO-1."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.launch.mesh import make_mesh
from repro.launch.sharding import ShardingRules
from repro.launch.specs import (_zero1_extend, batch_shardings,
                                params_shardings, state_shardings)
from repro.models import transformer as T
from repro.models.schema import Spec, is_spec


@pytest.fixture(scope="module")
def rules():
    # CPU-scale stand-in mesh with the production axis names
    return ShardingRules(make_mesh((1, 1), ("data", "model")))


def test_spec_dedup_never_reuses_axis(rules):
    # both dims prefer 'model'; only the first may take it
    spec = rules.spec(("experts", "expert_ff"), (16, 32))
    flat = [a for part in spec for a in
            ((part,) if isinstance(part, str) else (part or ()))]
    assert len(flat) == len(set(flat))


def test_divisibility_fallback():
    rules4 = ShardingRules(make_mesh((1, 1), ("data", "model")))
    # dim not divisible by axis size 1 never happens; emulate with logic:
    assert rules4.mesh_axes_for("heads", 24) in ("model", None)
    # non-divisible -> None (llama 24 heads on a 16-way axis)
    class FakeMesh:
        shape = {"data": 1, "model": 16}
        axis_names = ("data", "model")
    fr = ShardingRules.__new__(ShardingRules)
    fr.mesh = FakeMesh()
    fr.axes = {"data", "model"}
    assert fr.mesh_axes_for("heads", 24) is None
    assert fr.mesh_axes_for("heads", 32) == "model"
    assert fr.mesh_axes_for("experts", 60) is None
    assert fr.mesh_axes_for("experts", 128) == "model"


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_params_shardings_cover_schema(name, rules):
    sch = T.model_schema(ARCHS[name])
    psh = params_shardings(ARCHS[name], rules)
    specs = jax.tree.leaves(sch, is_leaf=is_spec)
    shardings = jax.tree.leaves(psh)
    assert len(specs) == len(shardings)
    for s, sh in zip(specs, shardings):
        assert len(sh.spec) <= len(s.shape)


def test_padded_vocab_always_divides_production_axis():
    for cfg in ARCHS.values():
        assert cfg.padded_vocab % 16 == 0
        assert cfg.padded_vocab >= cfg.vocab_size


def test_zero1_extends_first_free_dim():
    from jax.sharding import NamedSharding
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules(mesh)
    sh = NamedSharding(mesh, P(None, "model"))
    out = _zero1_extend(sh, (8, 16), rules)
    assert out.spec[0] == "data"


def test_batch_shardings_match_batch_spec(rules):
    from repro.configs import SHAPES
    cfg = ARCHS["internvl2-26b"]
    bsh = batch_shardings(cfg, SHAPES["train_4k"], rules)
    assert set(bsh) == {"tokens", "labels", "patch_embeds"}
