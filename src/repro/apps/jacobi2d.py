"""Jacobi2D — the paper's benchmark application on the overdecomposed
tile runtime (solves the Laplace equation; hot top edge).

Drives HostTileRuntime (measured, heterogeneity/latency-injectable) and is
used by benchmarks/bench_overdecomp.py (Fig 2) and bench_loadbalance.py
(Fig 3).  The TPU-production SPMD path is core/spmd_stencil.py.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.overdecomp import (CommModel, HostTileRuntime, TileGrid,
                                   choose_tiling)


@dataclasses.dataclass
class JacobiRun:
    time_per_iter: float
    accounted_time_per_iter: float   # jitter-free model time (see overdecomp)
    per_iter: List[Dict[str, float]]
    lb_events: List[dict]


def run_jacobi(*, grid_size: int = 512, n_pes: int = 4, odf: int = 4,
               iters: int = 20, kernel: str = "jacobi",
               comm_latency_s: float = 0.0, comm_bw_Bps: float = float("inf"),
               pe_rate_multipliers: Optional[Sequence[float]] = None,
               lb_strategy: Optional[str] = None, lb_every: int = 10,
               rate_aware: bool = True, warmup: int = 2) -> JacobiRun:
    n_tiles = n_pes * odf
    tr, tc = choose_tiling(n_tiles)
    # grid must divide tiles; round up
    H = ((grid_size + tr - 1) // tr) * tr
    W = ((grid_size + tc - 1) // tc) * tc
    rt = HostTileRuntime(
        TileGrid(H, W, tr, tc), n_pes, kernel=kernel, odf=odf,
        pe_rate_multipliers=pe_rate_multipliers,
        comm=CommModel(comm_latency_s, comm_bw_Bps))
    per_iter = []
    lb_events = []
    for it in range(iters):
        m = rt.step()
        if it >= warmup:
            per_iter.append(m)
        if lb_strategy and (it + 1) % lb_every == 0:
            res = rt.load_balance(lb_strategy, rate_aware=rate_aware)
            lb_events.append({"iter": it, "migrations": res.migrations,
                              "makespan": res.makespan,
                              "baseline": res.baseline_makespan})
    tpi = float(np.mean([m["time_per_iter"] for m in per_iter]))
    acc = float(np.mean([m["accounted_time_per_iter"] for m in per_iter]))
    return JacobiRun(tpi, acc, per_iter, lb_events)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=512)
    ap.add_argument("--pes", type=int, default=4)
    ap.add_argument("--odf", type=int, default=4)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--kernel", default="jacobi",
                    choices=["jacobi", "lulesh"])
    a = ap.parse_args()
    out = run_jacobi(grid_size=a.grid, n_pes=a.pes, odf=a.odf,
                     iters=a.iters, kernel=a.kernel)
    print(f"time/iter = {out.time_per_iter*1e3:.2f} ms")
