"""LULESH proxy — compute-bound unstructured-hydro stand-in (paper §III-B).

Same tile/halo structure as Jacobi2D but each step runs several rounds of
stencil + EOS-like transcendental work, so compute dominates communication
(the property that makes LULESH the paper's contrast case to Jacobi2D).
Driven through the same overdecomposed runtime; see apps/jacobi2d.py.
"""
from repro.apps.jacobi2d import JacobiRun, run_jacobi


def run_lulesh(**kw) -> JacobiRun:
    kw.setdefault("kernel", "lulesh")
    return run_jacobi(**kw)


if __name__ == "__main__":
    out = run_lulesh(grid_size=512, n_pes=4, odf=4, iters=12)
    print(f"time/iter = {out.time_per_iter*1e3:.2f} ms")
