"""Model / run configuration system.

Every assigned architecture is a ``ModelConfig`` instance in its own module
(``src/repro/configs/<id>.py``).  Configs are plain frozen dataclasses so they
are hashable (usable as jit static args) and trivially serializable.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# Families understood by the model zoo.
FAMILIES = ("dense", "moe", "enc_dec", "hybrid", "ssm", "vlm", "audio")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # one of FAMILIES

    # -- transformer backbone ------------------------------------------------
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    use_bias: bool = False
    tie_embeddings: bool = False

    # -- encoder/decoder (enc_dec family) -------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0

    # -- MoE (moe family) ------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0  # shared-expert FFN width = num_shared * d_ff
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # dispatch groups: routing/capacity is computed per group and the group
    # dim is sharded over 'data', so dispatch gathers are shard-local and
    # the expert reshard is a clean all-to-all (GShard capacity sharding)
    moe_groups: int = 16
    moe_impl: str = "auto"   # 'auto' (explicit-EP when possible) | 'grouped' | 'onehot'

    # -- SSM / Mamba2 (ssm + hybrid families) ----------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256

    # -- hybrid (zamba2 style): a shared-weight attention block applied every
    #    ``attn_every`` SSM layers ------------------------------------------------
    attn_every: int = 0

    # -- modality frontend stubs ----------------------------------------------
    # 'none' | 'vision' (precomputed patch embeddings) | 'audio' (frame embeds)
    frontend: str = "none"
    frontend_seq: int = 0  # number of prepended frontend positions

    # -- numerics ----------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # -- runtime knobs (the paper's technique) -----------------------------------
    num_microbatches: int = 4          # overdecomposition factor for grad accum
    grad_schedule: str = "fused"       # 'fused' | 'overlapped' (C1 analogue)
    grad_reduce_dtype: str = "float32" # 'bfloat16' halves DP all-reduce bytes
    remat: str = "full"                # 'none' | 'full'
    zero1: bool = False                # shard optimizer state over data axis
    flash_block_q: int = 512
    flash_block_kv: int = 512
    attn_impl: str = "auto"            # 'auto' | 'full' | 'blockwise'

    # ----------------------------------------------------------------------------
    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family == "enc_dec" and self.num_layers == 0:
            object.__setattr__(self, "num_layers", self.enc_layers + self.dec_layers)

    # Derived quantities ----------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a multiple of 256 so embedding tables always
        shard evenly over the model axis (MaxText-style). Padded logit slots
        are masked to -inf in lm_logits."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid families per the assignment)."""
        return self.family in ("ssm", "hybrid")

    @property
    def expert_capacity_den(self) -> int:
        return max(self.num_experts, 1)

    def reduced(self) -> "ModelConfig":
        """Small config of the same family for CPU smoke tests."""
        kw = dict(
            num_layers=min(self.num_layers, 2) or 2,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 2,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_microbatches=2,
        )
        if self.family == "enc_dec":
            kw.update(enc_layers=2, dec_layers=2, num_layers=0)
        if self.family == "moe":
            kw.update(num_experts=min(self.num_experts, 8) or 8,
                      top_k=min(self.top_k, 2) or 2,
                      num_shared_experts=min(self.num_shared_experts, 1),
                      d_ff=32)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.family == "hybrid":
            kw.update(num_layers=4, attn_every=2)
        if self.frontend != "none":
            kw.update(frontend_seq=8)
        return replace(self, **kw)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned (input-shape) cell: what gets lowered and at what size."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    def reduced(self) -> "ShapeConfig":
        return ShapeConfig(self.name, min(self.seq_len, 64),
                           min(self.global_batch, 4), self.kind)


# The four assigned LM shapes -------------------------------------------------
SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell runs, and why not if it doesn't."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (per assignment)"
    return True, ""
