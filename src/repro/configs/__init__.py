"""Architecture registry: ``--arch <id>`` resolves here."""
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable

from repro.configs.internvl2_26b import CONFIG as _internvl2
from repro.configs.command_r_35b import CONFIG as _command_r
from repro.configs.granite_3_2b import CONFIG as _granite3
from repro.configs.granite_8b import CONFIG as _granite8
from repro.configs.llama3_2_3b import CONFIG as _llama32
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2moe
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3moe
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.mamba2_780m import CONFIG as _mamba2

ARCHS = {c.name: c for c in [
    _internvl2, _command_r, _granite3, _granite8, _llama32,
    _qwen2moe, _qwen3moe, _seamless, _zamba2, _mamba2,
]}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "get_config",
           "shape_applicable"]
