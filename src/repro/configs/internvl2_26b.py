"""internvl2-26b [vlm]: InternViT + InternLM2 backbone (arXiv:2404.16821).

The ViT frontend is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings occupying the first ``frontend_seq`` positions.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92553, rope_theta=1_000_000.0,
    frontend="vision", frontend_seq=256,
)
