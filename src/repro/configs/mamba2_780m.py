"""mamba2-780m [ssm]: pure Mamba2, SSD / state-space duality (arXiv:2405.21060)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64,
)
