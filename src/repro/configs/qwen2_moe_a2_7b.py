"""qwen2-moe-a2.7b [moe]: 60 routed experts top-4 + 4 shared
(hf:Qwen/Qwen1.5-MoE-A2.7B). 60 % 16 != 0 -> EP fallback shards expert d_ff.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=151936,
    num_experts=60, top_k=4, num_shared_experts=4,
)
