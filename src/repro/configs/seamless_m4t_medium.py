"""seamless-m4t-medium [audio]: encoder-decoder (arXiv:2308.11596).

Audio frontend is a STUB: input_specs() supplies precomputed frame embeddings
as the encoder input sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="enc_dec",
    enc_layers=12, dec_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, frontend="audio",
)
