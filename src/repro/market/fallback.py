"""Fallback strategies: where capacity comes from after a spot notice.

When a market interruption fires its ``rebalance_recommendation``, the
autoscaler asks the control plane's :class:`FallbackStrategy` for a
:class:`PurchaseOrder` — which hardware to buy, in which market — and
pre-warms the replacement so it is ready before the doomed replica's
``terminate``.  The packed WorkUnits then land wherever the router's
readmission places them, replacement included.

The strategy set mirrors the ShieldOps taxonomy:

* ``on_demand``         — buy the same hardware at the guaranteed rate;
                          dearest, never interrupted again.
* ``different_market``  — same hardware in the best *other* market
                          (on-demand if the interrupted market was the
                          only listing).
* ``different_type``    — best (itype, market) offer across the whole
                          catalog for the replica's model.
* ``queue_work``        — no replacement; drained units wait for free
                          slots on surviving replicas.
* ``scale_down``        — no replacement; drained units spread across
                          survivors immediately (accept the squeeze).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Type, Union

from repro.cluster.replica import InstanceType
from repro.market.catalog import ON_DEMAND
from repro.market.exchange import SpotExchange


@dataclasses.dataclass(frozen=True)
class PurchaseOrder:
    """What the fallback wants bought."""
    itype: InstanceType
    market: str          # market name or ON_DEMAND


class FallbackStrategy:
    """Policy seam: spot notice -> optional replacement purchase."""

    name = "base"
    #: When True, drained units are only re-admitted onto replicas with
    #: free slots (they queue rather than pile onto busy engines).
    queue_until_free = False

    def replacement(self, view, rep, exchange: SpotExchange,
                    now: float) -> Optional[PurchaseOrder]:
        raise NotImplementedError

    def __repr__(self):
        return f"{type(self).__name__}()"


class OnDemandFallback(FallbackStrategy):
    name = "on_demand"

    def replacement(self, view, rep, exchange, now):
        return PurchaseOrder(rep.itype, ON_DEMAND)


class DifferentMarketFallback(FallbackStrategy):
    name = "different_market"

    def replacement(self, view, rep, exchange, now):
        bought = rep.purchase.market if rep.purchase is not None else None
        exclude = {bought} if bought else set()
        market = exchange.best_market(rep.itype, now, exclude=exclude)
        return PurchaseOrder(rep.itype, market or ON_DEMAND)


class DifferentTypeFallback(FallbackStrategy):
    name = "different_type"

    def replacement(self, view, rep, exchange, now):
        offer = exchange.best_offer(rep.model_id, now, exclude_itype=rep.itype)
        if offer is not None:
            return PurchaseOrder(*offer)
        # nothing else in the catalog serves this model: next-best market
        # for the same hardware, on-demand as the floor
        return DifferentMarketFallback().replacement(view, rep, exchange, now)


class QueueWorkFallback(FallbackStrategy):
    name = "queue_work"
    queue_until_free = True

    def replacement(self, view, rep, exchange, now):
        return None


class ScaleDownFallback(FallbackStrategy):
    name = "scale_down"

    def replacement(self, view, rep, exchange, now):
        return None


FALLBACKS: Dict[str, Type[FallbackStrategy]] = {
    cls.name: cls for cls in (
        OnDemandFallback, DifferentMarketFallback, DifferentTypeFallback,
        QueueWorkFallback, ScaleDownFallback)}


def make_fallback(spec: Union[str, FallbackStrategy, None]
                  ) -> Optional[FallbackStrategy]:
    if spec is None or isinstance(spec, FallbackStrategy):
        return spec
    try:
        return FALLBACKS[spec]()
    except KeyError:
        raise ValueError(f"unknown fallback {spec!r}; pick from "
                         f"{sorted(FALLBACKS)}") from None
