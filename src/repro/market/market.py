"""Priced spot markets: seeded rate processes + interruption intensity.

A :class:`SpotMarket` is one capacity pool with a stochastic hourly
rate.  The price path is a mean-reverting (Ornstein-Uhlenbeck style)
walk, precomputed on a fixed grid from one seed so every consumer of
the market — purchase pricing, the savings ledger's billing integral,
the interruption sampler — reads the *identical* path.  Scheduled
price-spike segments (capacity crunches) multiply the walk over
``[t0, t1)`` windows; they are part of the market definition, so a
lookahead shopper can see them coming the way a real spot-placement
advisor surfaces capacity trends.

Interruptions are priced in: the market's interruption intensity is a
function of its *current price relative to base*,

    intensity(t) = interruptions_per_hour * (rate(t)/base_rate)**price_power

so a spike both raises the bill and raises the chance of losing the
instance — the coupling that makes naive cheapest-now shopping lose to
interruption-adjusted shopping (paper follow-up: elastic job scheduling
across cloud offerings).

Interruption *times* are sampled per purchase via Poisson thinning
against the piecewise-constant intensity, from an RNG seeded by
``(exchange seed, purchase index)`` — the same purchase sequence under
the same seed reproduces the same interruption schedule bit-for-bit.
"""

from __future__ import annotations

import math
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np


class SpotMarket:
    """One spot capacity pool with a seeded hourly-rate process.

    Parameters
    ----------
    base_rate:
        Long-run mean of the price walk ($/hour).
    volatility:
        Per-step shock scale as a fraction of ``base_rate``.
    reversion:
        Mean-reversion strength per step (0 = random walk, 1 = snaps
        back to base every step).
    floor_frac:
        Price floor as a fraction of ``base_rate`` (spot never free).
    spikes:
        ``(t0, t1, mult)`` segments: the walk is multiplied by ``mult``
        for ``t0 <= t < t1`` (scheduled capacity crunches).
    interruptions_per_hour:
        Interruption intensity when the market trades at base price.
    price_power:
        Exponent coupling intensity to price: a market trading at twice
        base interrupts ``2**price_power`` times as often.
    seed / horizon / dt:
        The price path is precomputed over ``[0, horizon]`` on a ``dt``
        grid from ``seed``; beyond ``horizon`` the last price holds.
    """

    def __init__(self, name: str, *, base_rate: float,
                 volatility: float = 0.06, reversion: float = 0.2,
                 floor_frac: float = 0.25,
                 spikes: Sequence[Tuple[float, float, float]] = (),
                 interruptions_per_hour: float = 0.5,
                 price_power: float = 2.0, seed: int = 0,
                 horizon: float = 3600.0, dt: float = 10.0):
        if base_rate <= 0:
            raise ValueError(f"market {name!r}: base_rate must be > 0")
        self.name = name
        self.base_rate = float(base_rate)
        self.interruptions_per_hour = float(interruptions_per_hour)
        self.price_power = float(price_power)
        self.spikes = tuple((float(a), float(b), float(m))
                            for a, b, m in spikes)
        for a, b, _ in self.spikes:
            if b <= a:
                raise ValueError(f"market {name!r}: empty spike [{a}, {b})")
        self.horizon = float(horizon)
        self.dt = float(dt)
        self.seed = seed
        n = max(int(math.ceil(self.horizon / self.dt)), 1) + 1
        rng = np.random.default_rng(seed)
        path = np.empty(n)
        path[0] = self.base_rate
        floor = floor_frac * self.base_rate
        shocks = rng.normal(0.0, volatility * self.base_rate, n - 1)
        for i in range(1, n):
            drift = reversion * (self.base_rate - path[i - 1])
            path[i] = max(path[i - 1] + drift + shocks[i - 1], floor)
        self._path = path

    # ------------------------------------------------------------- price
    def _walk(self, t: float) -> float:
        idx = int(max(t, 0.0) / self.dt)
        return float(self._path[min(idx, len(self._path) - 1)])

    def _spike_mult(self, t: float) -> float:
        m = 1.0
        for a, b, mult in self.spikes:
            if a <= t < b:
                m *= mult
        return m

    def rate(self, t: float) -> float:
        """Instantaneous $/hour at virtual time ``t``."""
        return self._walk(t) * self._spike_mult(t)

    def intensity(self, t: float) -> float:
        """Instantaneous interruption intensity (events/hour) at ``t``."""
        rel = self.rate(t) / self.base_rate
        return self.interruptions_per_hour * rel ** self.price_power

    # ------------------------------------------------------- integration
    def _segments(self, t0: float, t1: float) -> Iterator[
            Tuple[float, float, float]]:
        """Piecewise-constant ``(a, b, rate)`` pieces covering [t0, t1)."""
        if t1 <= t0:
            return
        cuts = {t0, t1}
        k0 = int(math.floor(t0 / self.dt)) + 1
        k1 = int(math.ceil(t1 / self.dt))
        cuts.update(k * self.dt for k in range(k0, k1)
                    if t0 < k * self.dt < t1)
        for a, b, _ in self.spikes:
            for edge in (a, b):
                if t0 < edge < t1:
                    cuts.add(edge)
        pts = sorted(cuts)
        for a, b in zip(pts[:-1], pts[1:]):
            yield a, b, self.rate(0.5 * (a + b))

    def dollars(self, t0: float, t1: float) -> float:
        """Exact cost of holding one instance over ``[t0, t1]``."""
        return sum(r * (b - a) for a, b, r in self._segments(t0, t1)) / 3600.0

    def mean_rate(self, t0: float, window: float) -> float:
        """Average $/hour over ``[t0, t0+window]`` (lookahead pricing)."""
        if window <= 0:
            return self.rate(t0)
        return self.dollars(t0, t0 + window) * 3600.0 / window

    def mean_intensity(self, t0: float, window: float) -> float:
        """Average interruption intensity (events/hour) over the window."""
        if window <= 0:
            return self.intensity(t0)
        acc = 0.0
        for a, b, r in self._segments(t0, t0 + window):
            acc += self.interruptions_per_hour * (
                r / self.base_rate) ** self.price_power * (b - a)
        return acc / window

    # --------------------------------------------------------- sampling
    def sample_interruption(self, t0: float, rng: np.random.Generator,
                            until: Optional[float] = None) -> Optional[float]:
        """First interruption time after ``t0`` (None if none before
        ``until``), via Poisson thinning against ``intensity``.

        The candidate stream depends only on ``rng``, so one purchase =
        one generator = one reproducible interruption draw.
        """
        end = self.horizon if until is None else min(until, self.horizon)
        if end <= t0:
            return None
        lam_max = max((self.interruptions_per_hour
                       * (r / self.base_rate) ** self.price_power
                       for _, _, r in self._segments(t0, end)), default=0.0)
        if lam_max <= 0:
            return None
        t = t0
        for _ in range(100_000):
            t += float(rng.exponential(3600.0 / lam_max))
            if t >= end:
                return None
            if rng.uniform() * lam_max <= self.intensity(t):
                return t
        return None

    def __repr__(self):
        return (f"SpotMarket({self.name!r}, base=${self.base_rate:.2f}/h, "
                f"ir={self.interruptions_per_hour:.2f}/h, "
                f"spikes={len(self.spikes)})")
