"""Spot-market economics for the serving fleet (paper §IV follow-up).

The cloud as a priced economy: :class:`SpotMarket` rate processes
drive both the bill and the interruption schedule, a
:class:`MarketCatalog` lists the purchase options per instance type,
the :class:`SpotExchange` quotes naive vs interruption-adjusted
prices and executes buys, :class:`FallbackStrategy` decides where
capacity comes from after a spot notice, and the
:class:`SavingsLedger` reports savings vs all-on-demand through
``ClusterMetrics.summary()``.
"""

from repro.market.catalog import Listing, MarketCatalog, ON_DEMAND
from repro.market.exchange import AUTO, SpotExchange
from repro.market.fallback import (FALLBACKS, DifferentMarketFallback,
                                   DifferentTypeFallback, FallbackStrategy,
                                   OnDemandFallback, PurchaseOrder,
                                   QueueWorkFallback, ScaleDownFallback,
                                   make_fallback)
from repro.market.ledger import PurchaseRecord, SavingsLedger
from repro.market.market import SpotMarket
from repro.market.shopping import MarketAwareScaling

__all__ = [
    "AUTO", "ON_DEMAND", "FALLBACKS",
    "SpotMarket", "MarketCatalog", "Listing",
    "SpotExchange", "PurchaseRecord", "SavingsLedger",
    "FallbackStrategy", "PurchaseOrder", "make_fallback",
    "OnDemandFallback", "DifferentMarketFallback", "DifferentTypeFallback",
    "QueueWorkFallback", "ScaleDownFallback",
    "MarketAwareScaling",
]
