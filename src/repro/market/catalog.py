"""Instance-type listings: which markets sell which hardware, at what
on-demand rate.

A :class:`MarketCatalog` maps each :class:`InstanceType` to its
purchase options — zero or more spot markets (cheap, volatile, may be
interrupted) plus a guaranteed on-demand rate (expensive, never
interrupted).  ``InstanceType.cost_per_hour`` stays what it always was
(the static accounting rate used by ``ClusterMetrics``); the catalog's
``on_demand_rate`` is the *market* price of the no-risk option and
defaults to it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

from repro.cluster.replica import InstanceType
from repro.market.market import SpotMarket

#: Market name reserved for the never-interrupted option.
ON_DEMAND = "on_demand"


@dataclasses.dataclass(frozen=True)
class Listing:
    """Purchase options for one instance type."""
    itype: InstanceType
    on_demand_rate: float
    markets: Tuple[str, ...] = ()


class MarketCatalog:
    """Registry of spot markets + per-instance-type listings."""

    def __init__(self):
        self._markets: Dict[str, SpotMarket] = {}
        self._listings: Dict[str, Listing] = {}

    # ------------------------------------------------------------ build
    def add_market(self, market: SpotMarket) -> SpotMarket:
        if market.name == ON_DEMAND:
            raise ValueError(f"{ON_DEMAND!r} is reserved")
        if market.name in self._markets:
            raise ValueError(f"market {market.name!r} already registered")
        self._markets[market.name] = market
        return market

    def list_instance(self, itype: InstanceType, *,
                      on_demand_rate: Optional[float] = None,
                      markets: Tuple[str, ...] = ()) -> Listing:
        for m in markets:
            if m not in self._markets:
                raise KeyError(f"unknown market {m!r} (add_market first)")
        rate = (itype.cost_per_hour if on_demand_rate is None
                else float(on_demand_rate))
        listing = Listing(itype, rate, tuple(markets))
        self._listings[itype.name] = listing
        return listing

    # ---------------------------------------------------------- queries
    def market(self, name: str) -> SpotMarket:
        try:
            return self._markets[name]
        except KeyError:
            raise KeyError(f"unknown market {name!r}; have "
                           f"{sorted(self._markets)}") from None

    def markets(self) -> List[SpotMarket]:
        return list(self._markets.values())

    def listing(self, itype: Union[InstanceType, str]) -> Listing:
        name = itype if isinstance(itype, str) else itype.name
        try:
            return self._listings[name]
        except KeyError:
            raise KeyError(f"instance type {name!r} not listed; have "
                           f"{sorted(self._listings)}") from None

    def itypes(self, model_id: Optional[str] = None) -> List[InstanceType]:
        out = [l.itype for l in self._listings.values()]
        if model_id is not None:
            out = [it for it in out if it.model_id == model_id]
        return out

    def markets_for(self, itype: Union[InstanceType, str]) -> Tuple[str, ...]:
        return self.listing(itype).markets

    def on_demand_rate(self, itype: Union[InstanceType, str]) -> float:
        return self.listing(itype).on_demand_rate
