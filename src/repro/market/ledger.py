"""The savings ledger: what the fleet actually paid vs all-on-demand.

Every replica purchase is a :class:`PurchaseRecord` — which market it
was bought in (or on-demand), under which strategy (initial fleet,
scale-up, or a fallback on a spot notice), and when it started/ended.
The ledger bills spot purchases by integrating the market's actual
price path over the holding period and compares against the
counterfactual of holding the same instances on-demand for the same
durations — the savings % the paper's spot-instance extension exists
to harvest.  ``report()`` flattens totals plus by-market and
by-strategy breakdowns into the ``ClusterMetrics.summary()`` dict.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.market.catalog import MarketCatalog, ON_DEMAND


@dataclasses.dataclass
class PurchaseRecord:
    """One replica bought on the exchange."""
    rid: int
    itype: str
    model_id: str
    market: str               # market name, or ON_DEMAND
    strategy: str             # initial | scale_up | <fallback name> | ...
    t_buy: float
    on_demand_rate: float     # counterfactual $/hour for this hardware
    rate_at_buy: float        # price observed at purchase time
    t_end: Optional[float] = None         # retirement time (None = running)
    interrupted_t: Optional[float] = None

    @property
    def spot(self) -> bool:
        return self.market != ON_DEMAND


class SavingsLedger:
    """Actual vs all-on-demand dollars, by market and by strategy."""

    def __init__(self, catalog: MarketCatalog):
        self.catalog = catalog
        self.purchases: List[PurchaseRecord] = []
        self._open: Dict[int, PurchaseRecord] = {}
        self.interruptions = 0
        self.interruption_overhead_s = 0.0

    # ----------------------------------------------------------- events
    def on_purchase(self, rec: PurchaseRecord):
        self.purchases.append(rec)
        self._open[rec.rid] = rec

    def on_terminate(self, rid: int, t: float):
        rec = self._open.pop(rid, None)
        if rec is not None:
            rec.t_end = t

    def on_interruption(self, rid: int, t: float, overhead_s: float = 0.0):
        """A spot notice forced ``rid`` to drain (checkpoint+restore cost
        ``overhead_s`` engine-seconds of migration work)."""
        self.interruptions += 1
        self.interruption_overhead_s += overhead_s
        rec = self._open.get(rid)
        if rec is None:                     # already retired: find latest
            recs = [r for r in self.purchases if r.rid == rid]
            rec = recs[-1] if recs else None
        if rec is not None:
            rec.interrupted_t = t

    # ---------------------------------------------------------- billing
    def _span(self, rec: PurchaseRecord, horizon: float):
        end = rec.t_end if rec.t_end is not None else horizon
        return rec.t_buy, max(end, rec.t_buy)

    def purchase_dollars(self, rec: PurchaseRecord, horizon: float) -> float:
        t0, t1 = self._span(rec, horizon)
        if rec.spot:
            return self.catalog.market(rec.market).dollars(t0, t1)
        return rec.on_demand_rate * (t1 - t0) / 3600.0

    def actual_dollars(self, horizon: float) -> float:
        return sum(self.purchase_dollars(r, horizon) for r in self.purchases)

    def on_demand_dollars(self, horizon: float) -> float:
        """Counterfactual: same instances, same holding periods, all
        bought at their guaranteed on-demand rate."""
        return sum(r.on_demand_rate * (self._span(r, horizon)[1]
                                       - self._span(r, horizon)[0]) / 3600.0
                   for r in self.purchases)

    def savings_pct(self, horizon: float) -> float:
        od = self.on_demand_dollars(horizon)
        if od <= 0:
            return 0.0
        return 100.0 * (od - self.actual_dollars(horizon)) / od

    # ---------------------------------------------------------- reports
    def by_market(self, horizon: float) -> Dict[str, Dict[str, float]]:
        # every catalog market appears (zero-filled) so the report's key
        # set is stable across runs that never touched a market
        out: Dict[str, Dict[str, float]] = {
            m.name: {"purchases": 0, "dollars": 0.0, "interruptions": 0}
            for m in self.catalog.markets()}
        for rec in self.purchases:
            row = out.setdefault(rec.market, {
                "purchases": 0, "dollars": 0.0, "interruptions": 0})
            row["purchases"] += 1
            row["dollars"] += self.purchase_dollars(rec, horizon)
            row["interruptions"] += int(rec.interrupted_t is not None)
        return out

    def by_strategy(self) -> Dict[str, int]:
        out: Dict[str, int] = {"initial": 0}
        for rec in self.purchases:
            out[rec.strategy] = out.get(rec.strategy, 0) + 1
        return out

    def report(self, horizon: float) -> Dict[str, float]:
        """Flat dict merged into ``ClusterMetrics.summary()``."""
        out = {
            "market_dollar_cost": round(self.actual_dollars(horizon), 6),
            "on_demand_dollar_cost": round(
                self.on_demand_dollars(horizon), 6),
            "savings_pct": round(self.savings_pct(horizon), 3),
            "spot_interruptions": self.interruptions,
            "spot_interruption_overhead_s": round(
                self.interruption_overhead_s, 3),
            "purchases": len(self.purchases),
        }
        for market, row in sorted(self.by_market(horizon).items()):
            out[f"market_{market}_purchases"] = row["purchases"]
            out[f"market_{market}_dollars"] = round(row["dollars"], 6)
            out[f"market_{market}_interruptions"] = row["interruptions"]
        for strategy, n in sorted(self.by_strategy().items()):
            out[f"strategy_{strategy}_purchases"] = n
        return out
