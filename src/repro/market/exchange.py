"""The purchase window: price quotes, market shopping, and buys.

:class:`SpotExchange` is the single place replicas are bought.  It
quotes two kinds of price per (instance type, market):

* ``naive`` — the spot rate *right now*; the cheapest-now shopper.
* ``adjusted`` — the interruption-adjusted effective price over a
  lookahead window:

      mean_rate(t, W) + mean_intensity(t, W) * interruption_dollars

  where ``interruption_dollars`` prices one interruption as the
  on-demand rate times the estimated overhead (drain checkpoint +
  restore + re-prefill seconds, measured from ``ClusterMetrics`` drain
  records once any exist).  Because a market's scheduled price spikes
  raise both its mean rate and its intensity inside the window, the
  adjusted shopper walks away from a pool that is about to get
  expensive *and* flaky — the A/B the ``cluster_spot_market``
  benchmark measures.

Every ``purchase()`` draws the instance's interruption time from an
RNG seeded by ``(exchange seed, purchase index)``: the same purchase
sequence under the same seed yields a bit-identical interruption
schedule, which keeps whole-cluster runs deterministic.
"""

from __future__ import annotations

import itertools
from typing import Iterable, Optional, Tuple

import numpy as np

from repro.cluster.replica import InstanceType
from repro.market.catalog import MarketCatalog, ON_DEMAND
from repro.market.ledger import PurchaseRecord, SavingsLedger

#: ``purchase(market=AUTO)``: shop every listed market for the type.
AUTO = "auto"

MODES = ("naive", "adjusted")


class SpotExchange:
    def __init__(self, catalog: MarketCatalog, *, seed: int = 0,
                 mode: str = "adjusted", lookahead_s: float = 600.0,
                 default_overhead_s: float = 60.0,
                 sample_until: Optional[float] = None):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; pick from {MODES}")
        self.catalog = catalog
        self.seed = seed
        self.mode = mode
        self.lookahead_s = lookahead_s
        self.default_overhead_s = default_overhead_s
        self.sample_until = sample_until   # cap on interruption sampling
        self.ledger = SavingsLedger(catalog)
        self._idx = itertools.count()
        self._metrics = None               # ClusterMetrics, once attached

    # ----------------------------------------------------------- wiring
    def bind_metrics(self, metrics):
        """Let overhead estimates learn from observed drain records."""
        self._metrics = metrics

    def estimated_overhead_s(self) -> float:
        """Seconds of work one interruption costs: measured drain
        checkpoint+restore overhead when records exist (plus the
        re-prefill/migration prior), the prior alone otherwise."""
        measured = 0.0
        drains = getattr(self._metrics, "drains", None)
        if drains:
            measured = sum(d.checkpoint_s + d.restore_s
                           for d in drains) / len(drains)
        return self.default_overhead_s + measured

    # ---------------------------------------------------------- pricing
    def spot_rate(self, market: str, t: float) -> float:
        return self.catalog.market(market).rate(t)

    def interruption_dollars(self, itype: InstanceType,
                             overhead_s: Optional[float] = None) -> float:
        """Dollar cost of one interruption: the overhead seconds repriced
        at the hardware's no-risk (on-demand) rate."""
        oh = self.estimated_overhead_s() if overhead_s is None else overhead_s
        return self.catalog.on_demand_rate(itype) * oh / 3600.0

    def effective_price(self, itype: InstanceType, market: str, t: float,
                        *, overhead_s: Optional[float] = None) -> float:
        """$/hour used for shopping: mode-dependent (see module doc)."""
        if market == ON_DEMAND:
            return self.catalog.on_demand_rate(itype)
        m = self.catalog.market(market)
        if self.mode == "naive":
            return m.rate(t)
        return (m.mean_rate(t, self.lookahead_s)
                + m.mean_intensity(t, self.lookahead_s)
                * self.interruption_dollars(itype, overhead_s))

    # --------------------------------------------------------- shopping
    def best_market(self, itype: InstanceType, t: float, *,
                    exclude: Iterable[str] = (),
                    include_on_demand: bool = False) -> Optional[str]:
        """Cheapest market (by the mode's price) for ``itype`` at ``t``."""
        skip = set(exclude)
        names = [m for m in self.catalog.markets_for(itype) if m not in skip]
        if include_on_demand and ON_DEMAND not in skip:
            names.append(ON_DEMAND)
        if not names:
            return None
        return min(names, key=lambda m: (self.effective_price(itype, m, t),
                                         m))

    def best_offer(self, model_id: str, t: float, *,
                   exclude_itype: Optional[InstanceType] = None
                   ) -> Optional[Tuple[InstanceType, str]]:
        """Best (itype, market) across the catalog for ``model_id``:
        maximal speed per effective dollar, on-demand included as the
        no-risk candidate."""
        best, best_key = None, None
        for it in self.catalog.itypes(model_id):
            if exclude_itype is not None and it.name == exclude_itype.name:
                continue
            market = self.best_market(it, t, include_on_demand=True)
            if market is None:
                continue
            price = self.effective_price(it, market, t)
            key = (-it.speed / max(price, 1e-9), price, it.name)
            if best_key is None or key < best_key:
                best, best_key = (it, market), key
        return best

    # ------------------------------------------------------------- buys
    def purchase(self, rid: int, itype: InstanceType, *, t: float,
                 market: str = AUTO, strategy: str = "initial"
                 ) -> Tuple[PurchaseRecord, Optional[float]]:
        """Buy one ``itype`` for replica ``rid`` at time ``t``.

        Returns ``(record, interruption_t)``; ``interruption_t`` is
        ``None`` for on-demand buys and for spot buys whose sampled
        interruption falls beyond the market horizon.
        """
        if not itype.spot:
            market = ON_DEMAND     # hardware flagged non-spot never risks
        elif market == AUTO:
            market = self.best_market(itype, t) or ON_DEMAND
        idx = next(self._idx)
        t_int = None
        if market == ON_DEMAND:
            rate = self.catalog.on_demand_rate(itype)
        else:
            m = self.catalog.market(market)
            rate = m.rate(t)
            rng = np.random.default_rng((self.seed, idx))
            t_int = m.sample_interruption(t, rng, until=self.sample_until)
        rec = PurchaseRecord(
            rid=rid, itype=itype.name, model_id=itype.model_id,
            market=market, strategy=strategy, t_buy=float(t),
            on_demand_rate=self.catalog.on_demand_rate(itype),
            rate_at_buy=rate)
        self.ledger.on_purchase(rec)
        return rec, t_int
