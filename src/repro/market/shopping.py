"""Market-aware autoscaling: shop live prices, not static ones.

:class:`MarketAwareScaling` keeps ``CostAwareScaling``'s grow/shrink
triggers but reprices every launch decision through the exchange: the
winning (itype, market) maximizes speed per *effective* dollar, where
the effective price in ``adjusted`` mode folds in the market's
predicted interruption rate times the dollar cost of one interruption
(drain + re-prefill overhead, learned from ``ClusterMetrics`` drain
records, billed at the on-demand rate).  The actual market is chosen
again at ``ServingCluster.launch`` time via ``market="auto"`` — the
exchange is the single pricing authority, so policy and purchase can
never disagree.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.cluster.control import ClusterView, CostAwareScaling
from repro.cluster.replica import InstanceType, Replica
from repro.market.exchange import SpotExchange


class MarketAwareScaling(CostAwareScaling):
    name = "market"

    def __init__(self, exchange: SpotExchange, **kw):
        catalog = exchange.catalog.itypes()
        if not catalog:
            raise ValueError("MarketAwareScaling needs a listed catalog")
        super().__init__(catalog, **kw)
        self.exchange = exchange

    def select_itype(self, view: ClusterView, model_id: str,
                     serving: Sequence[Replica]) -> InstanceType:
        offer = self.exchange.best_offer(model_id, view.now)
        if offer is None:
            return super().select_itype(view, model_id, serving)
        itype, market = offer
        price = self.exchange.effective_price(itype, market, view.now)
        view.log(f"scale_up pool={model_id}: market pick {itype.name} @ "
                 f"{market} (eff ${price:.2f}/h, {self.exchange.mode})")
        return itype

    def replacement(self, view: ClusterView, rep: Replica) -> InstanceType:
        offer = self.exchange.best_offer(rep.model_id, view.now)
        return offer[0] if offer is not None else rep.itype
