"""Shared discrete-event runtime core (the paper's ARTS substrate).

One clock, one event heap, one fault schedule.  Every time-driven
subsystem in the reproduction — the ``CloudManager`` spot simulation,
the serving cluster's replicas, and the overdecomposed tile runtime —
registers named handlers on a shared :class:`EventLoop` instead of
owning a private heap, so training and serving experiments replay the
*identical* interruption schedule from a single :class:`FaultTrace`.

This is the message-driven core the paper argues for (§II): no global
lockstep tick; each actor schedules its own next event at its own
cadence.
"""

from repro.runtime.clock import VirtualClock
from repro.runtime.loop import Event, EventLoop
from repro.runtime.faults import (FaultTrace, SpotEventFeed, SpotNotice,
                                  CHAOS_KINDS, LIFECYCLE_KINDS)

__all__ = [
    "VirtualClock", "Event", "EventLoop",
    "FaultTrace", "SpotEventFeed", "SpotNotice", "CHAOS_KINDS",
    "LIFECYCLE_KINDS",
]
