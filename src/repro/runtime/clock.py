"""Deterministic virtual time shared by every event-driven subsystem."""

from __future__ import annotations


class VirtualClock:
    """Monotonic fake clock: the single time source of an ``EventLoop``.

    Runs are keyed off *virtual* seconds so simulations are deterministic
    and reproducible on any host; only explicitly measured stages (e.g.
    checkpoint-store timers) use real wall-clock.
    """

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float):
        assert dt > 0
        self._t += dt

    def advance_to(self, t: float):
        """Jump forward to ``t`` (no-op if ``t`` is in the past)."""
        if t > self._t:
            self._t = float(t)
