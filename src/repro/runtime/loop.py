"""The single ordered event heap + named handlers.

``EventLoop`` is the only ``heapq`` in the repository's simulation
stack.  Subsystems register a handler per event *kind* and schedule
events onto the shared heap; ties at equal virtual time resolve by
schedule order (a monotone sequence number), so identical inputs give
bit-identical dispatch order — the substrate of every determinism
guarantee downstream.

Built to survive million-event runs:

* the heap stores ``(t, seq, event)`` tuples so ordering compares in C
  (no per-event dataclass ``__lt__``), and ``pending`` is an O(1) live
  counter instead of an O(n) heap scan;
* cancelled events buried deep in the heap (recurring rebalance /
  heartbeat / closed-loop cancellations) are *compacted* away once they
  outnumber the live entries, not just dropped when they surface at the
  top — ``(t, seq)`` is a total order, so a filter + ``heapify``
  provably preserves dispatch order (asserted bit-identical in tests);
* journaling is optional (``EventLoop(journal=False)``) for
  million-event runs; a running CRC-32 ``journal_digest`` over every
  dispatched ``(t, seq, kind)`` is maintained in BOTH modes, so two
  runs can assert bit-identical event timelines without storing one
  tuple per event.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import struct
import zlib
from typing import Callable, Dict, List, Optional, Tuple

# compact the heap when buried cancelled entries both exceed this floor
# and outnumber the live entries (amortized O(1) per cancellation)
_COMPACT_MIN = 64


@dataclasses.dataclass(order=True)
class Event:
    t: float
    seq: int
    kind: str = dataclasses.field(compare=False)
    payload: dict = dataclasses.field(compare=False, default_factory=dict)
    cancelled: bool = dataclasses.field(compare=False, default=False)
    dispatched: bool = dataclasses.field(compare=False, default=False)


Handler = Callable[[Event, float], None]


class EventLoop:
    """Discrete-event scheduler over a shared :class:`VirtualClock`.

    * ``register(kind, fn)``    — name a handler (one per kind).
    * ``schedule(t, kind, **p)``— push an event; returns it (cancellable).
    * ``dispatch_next()``       — pop the earliest live event, advance the
                                  clock to its time, run its handler.
    * ``run(until=...)``        — dispatch until the heap drains or the
                                  next event lies beyond ``until``;
                                  raises ``RuntimeError`` if ``max_events``
                                  is exhausted with live work still due
                                  (a silently truncated sim would report
                                  partial metrics as if complete).

    The loop journals every dispatched ``(t, seq, kind)`` so tests can
    assert two runs produced bit-identical event timelines; pass
    ``journal=False`` to keep only the running ``journal_digest``
    (same bit-identity check, O(1) memory).
    """

    def __init__(self, clock=None, *, journal: bool = True):
        from repro.runtime.clock import VirtualClock
        self.clock = clock if clock is not None else VirtualClock()
        # heap of (t, seq, Event): the tuple prefix is the total order
        self._heap: List[Tuple[float, int, Event]] = []
        self._seq = itertools.count()
        self._handlers: Dict[str, Handler] = {}
        self.keep_journal = journal
        self.journal: List[Tuple[float, int, str]] = []
        self.journal_digest = 0      # crc32 over dispatched (t, seq, kind)
        self.dispatched = 0          # events dispatched (journal or not)
        self.compactions = 0         # cancelled-entry compaction passes
        self._live = 0               # scheduled, not cancelled/dispatched
        self._buried = 0             # cancelled entries still in the heap

    # ------------------------------------------------------------ wiring
    def register(self, kind: str, handler: Handler):
        if kind in self._handlers:
            raise ValueError(f"handler for {kind!r} already registered")
        self._handlers[kind] = handler

    def now(self) -> float:
        return self.clock.now()

    # ------------------------------------------------------------ heap
    def schedule(self, t: float, kind: str, **payload) -> Event:
        ev = Event(float(t), next(self._seq), kind, payload)
        heapq.heappush(self._heap, (ev.t, ev.seq, ev))
        self._live += 1
        return ev

    def cancel(self, ev: Optional[Event]):
        if ev is None or ev.cancelled or ev.dispatched:
            return
        ev.cancelled = True
        self._live -= 1
        self._buried += 1
        self._maybe_compact()

    def _maybe_compact(self):
        """Rebuild the heap without cancelled entries once they dominate.

        ``(t, seq)`` is a total order (``seq`` is unique), so dropping
        dead entries and re-heapifying cannot change the pop order of
        the survivors — dispatch order, and therefore the journal, is
        bit-identical (asserted in tests/test_loop_scale.py).
        """
        if self._buried < _COMPACT_MIN or self._buried * 2 < len(self._heap):
            return
        self._heap = [e for e in self._heap if not e[2].cancelled]
        heapq.heapify(self._heap)
        self._buried = 0
        self.compactions += 1

    def _drop_cancelled(self):
        while self._heap and self._heap[0][2].cancelled:
            heapq.heappop(self._heap)
            self._buried -= 1

    @property
    def pending(self) -> int:
        return self._live

    def peek_t(self) -> float:
        """Virtual time of the earliest live event (inf when empty)."""
        self._drop_cancelled()
        return self._heap[0][0] if self._heap else math.inf

    def peek(self) -> Optional[Event]:
        """The earliest live event without popping it (None when empty)."""
        self._drop_cancelled()
        return self._heap[0][2] if self._heap else None

    # ------------------------------------------------------------ dispatch
    def dispatch_next(self) -> Optional[Event]:
        self._drop_cancelled()
        if not self._heap:
            return None
        ev = heapq.heappop(self._heap)[2]
        ev.dispatched = True
        self._live -= 1
        self.clock.advance_to(ev.t)
        self.dispatched += 1
        self.journal_digest = zlib.crc32(
            struct.pack("<dq", ev.t, ev.seq) + ev.kind.encode(),
            self.journal_digest)
        if self.keep_journal:
            self.journal.append((ev.t, ev.seq, ev.kind))
        handler = self._handlers.get(ev.kind)
        if handler is None:
            raise ValueError(f"no handler registered for event {ev.kind!r}")
        handler(ev, ev.t)
        return ev

    def run(self, until: float = math.inf, max_events: int = 10_000_000) -> int:
        """Dispatch events with ``t <= until``; returns events dispatched.

        Raises ``RuntimeError`` when ``max_events`` is exhausted while a
        live event is still due at ``t <= until`` — a sim that silently
        stops mid-stream would report partial metrics as if complete.
        """
        n = 0
        while True:
            self._drop_cancelled()
            if not self._heap or self._heap[0][0] > until:
                break
            if n >= max_events:
                raise RuntimeError(
                    f"EventLoop.run exhausted max_events={max_events} with "
                    f"{self._live} live event(s) still due at "
                    f"t<={until} (next at t={self._heap[0][0]:g}); the "
                    f"simulation is truncated, not complete — raise "
                    f"max_events or check for a non-draining event chain")
            self.dispatch_next()
            n += 1
        return n
