"""The single ordered event heap + named handlers.

``EventLoop`` is the only ``heapq`` in the repository's simulation
stack.  Subsystems register a handler per event *kind* and schedule
events onto the shared heap; ties at equal virtual time resolve by
schedule order (a monotone sequence number), so identical inputs give
bit-identical dispatch order — the substrate of every determinism
guarantee downstream.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass(order=True)
class Event:
    t: float
    seq: int
    kind: str = dataclasses.field(compare=False)
    payload: dict = dataclasses.field(compare=False, default_factory=dict)
    cancelled: bool = dataclasses.field(compare=False, default=False)


Handler = Callable[[Event, float], None]


class EventLoop:
    """Discrete-event scheduler over a shared :class:`VirtualClock`.

    * ``register(kind, fn)``    — name a handler (one per kind).
    * ``schedule(t, kind, **p)``— push an event; returns it (cancellable).
    * ``dispatch_next()``       — pop the earliest live event, advance the
                                  clock to its time, run its handler.
    * ``run(until=...)``        — dispatch until the heap drains or the
                                  next event lies beyond ``until``.

    The loop journals every dispatched ``(t, seq, kind)`` so tests can
    assert two runs produced bit-identical event timelines.
    """

    def __init__(self, clock=None):
        from repro.runtime.clock import VirtualClock
        self.clock = clock if clock is not None else VirtualClock()
        self._heap: List[Event] = []
        self._seq = itertools.count()
        self._handlers: Dict[str, Handler] = {}
        self.journal: List[Tuple[float, int, str]] = []

    # ------------------------------------------------------------ wiring
    def register(self, kind: str, handler: Handler):
        if kind in self._handlers:
            raise ValueError(f"handler for {kind!r} already registered")
        self._handlers[kind] = handler

    def now(self) -> float:
        return self.clock.now()

    # ------------------------------------------------------------ heap
    def schedule(self, t: float, kind: str, **payload) -> Event:
        ev = Event(float(t), next(self._seq), kind, payload)
        heapq.heappush(self._heap, ev)
        return ev

    def cancel(self, ev: Optional[Event]):
        if ev is not None:
            ev.cancelled = True

    def _drop_cancelled(self):
        while self._heap and self._heap[0].cancelled:
            heapq.heappop(self._heap)

    @property
    def pending(self) -> int:
        return sum(not e.cancelled for e in self._heap)

    def peek_t(self) -> float:
        """Virtual time of the earliest live event (inf when empty)."""
        self._drop_cancelled()
        return self._heap[0].t if self._heap else math.inf

    def peek(self) -> Optional[Event]:
        """The earliest live event without popping it (None when empty)."""
        self._drop_cancelled()
        return self._heap[0] if self._heap else None

    # ------------------------------------------------------------ dispatch
    def dispatch_next(self) -> Optional[Event]:
        self._drop_cancelled()
        if not self._heap:
            return None
        ev = heapq.heappop(self._heap)
        self.clock.advance_to(ev.t)
        self.journal.append((ev.t, ev.seq, ev.kind))
        handler = self._handlers.get(ev.kind)
        if handler is None:
            raise ValueError(f"no handler registered for event {ev.kind!r}")
        handler(ev, ev.t)
        return ev

    def run(self, until: float = math.inf, max_events: int = 10_000_000) -> int:
        """Dispatch events with ``t <= until``; returns events dispatched."""
        n = 0
        while n < max_events:
            self._drop_cancelled()
            if not self._heap or self._heap[0].t > until:
                break
            self.dispatch_next()
            n += 1
        return n
