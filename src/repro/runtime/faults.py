"""One fault schedule for every subsystem (paper §IV, AWS FIS analogue).

A :class:`FaultTrace` materializes an interruption schedule — injected
explicitly, sampled from a seeded Poisson process, read from a trace
file, or driven per-purchase by the market layer (a ``SpotExchange``
buy samples the instance's interruption time from its market's
price-coupled intensity and injects it here, so interruptions are a
function of *which market each replica was bought in*) — into the full
§IV spot lifecycle per interruption:

    rebalance_recommendation  at  t
    interruption_notice       at  t + rebalance_lead
    terminate                 at  t + rebalance_lead + notice_deadline

Consumers attach in one of two ways:

* ``trace.bind(loop, kind)`` — every lifecycle event (past and future
  injections) is scheduled onto a shared :class:`EventLoop`; this is how
  ``CloudManager``, ``ServingCluster``, and the tile runtime all observe
  the *identical* timestamps from a single trace.
* ``trace.subscribe()`` / :class:`SpotEventFeed` — a poll-style cursor
  view for callers that drive their own time (legacy interface).

Beyond the graceful lifecycle, the trace also carries a *chaos* model
(``CHAOS_KINDS``): ``hard_kill`` (zero-notice termination),
``slowdown`` (speed degraded by a factor over a window),
``network_contention`` (staging/event-delivery latency inflated over a
window), and ``endpoint_failure`` (transient MigrationEndpoint
put/get errors).  Chaos faults ride the same injection, binding, and
file round-trip machinery — one seeded soup (``chaos_sampled``)
replays identically with recovery on or off.
"""

from __future__ import annotations

import bisect
import dataclasses
import itertools
import math
from typing import Iterable, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SpotNotice:
    """One fault event delivered to a subscriber.

    Spot-lifecycle kinds (``LIFECYCLE_KINDS``) only use the first four
    fields; the chaos kinds (``CHAOS_KINDS``) carry their parameters in
    the trailing defaulted fields — ``factor``/``duration`` for
    slowdown and network-contention windows, ``count`` for transient
    endpoint failures.
    """
    t: float
    kind: str       # LIFECYCLE_KINDS | CHAOS_KINDS
    target: int     # subscriber-defined id (instance / serving replica)
    lifecycle: int = -1   # interruption index in the trace: ties the three
                          # events of one lifecycle together even when the
                          # same target is interrupted repeatedly
    factor: float = 1.0   # slowdown / contention severity multiplier
    duration: float = 0.0  # window length (virtual seconds)
    count: int = 1        # transient endpoint-failure arm count


LIFECYCLE_KINDS = ("rebalance_recommendation", "interruption_notice",
                   "terminate")

# The chaos model beyond the graceful §IV lifecycle: faults that arrive
# with NO advance warning, so resilience depends on checkpoints and
# detection rather than a drain window.
CHAOS_KINDS = ("hard_kill", "slowdown", "network_contention",
               "endpoint_failure")


class FaultTrace:
    """Seeded-or-file-driven interruption schedule -> lifecycle events."""

    def __init__(self, *, rebalance_lead: float = 180.0,
                 notice_deadline: float = 120.0):
        self.rebalance_lead = rebalance_lead
        self.notice_deadline = notice_deadline
        self.interruptions: List[Tuple[float, int]] = []
        self.chaos: List[SpotNotice] = []   # injected chaos faults, in order
        # sorted by (t, seq): bisect keeps polls O(log n), no private heap
        self._events: List[Tuple[float, int, SpotNotice]] = []
        self._seq = itertools.count()
        self._sinks: List[Tuple[object, str]] = []

    # ------------------------------------------------------------ build
    @classmethod
    def sampled(cls, *, rate: float, horizon: float, targets: int,
                seed: int = 0, rebalance_lead: float = 180.0,
                notice_deadline: float = 120.0) -> "FaultTrace":
        """Poisson(``rate``/s) interruption arrivals over ``horizon`` s,
        cycling victims through ``targets`` ids — one seeded draw gives
        one schedule, replayable by any number of consumers."""
        trace = cls(rebalance_lead=rebalance_lead,
                    notice_deadline=notice_deadline)
        rng = np.random.default_rng(seed)
        t, k = 0.0, 0
        while True:
            t += float(rng.exponential(1.0 / rate))
            if t >= horizon:
                break
            trace.inject(t, k % targets)
            k += 1
        return trace

    @classmethod
    def chaos_sampled(cls, *, rate: float, horizon: float, targets: int,
                      seed: int = 0, kinds: Tuple[str, ...] = CHAOS_KINDS,
                      factor: float = 3.0, window: float = 45.0,
                      fail_count: int = 2, rebalance_lead: float = 180.0,
                      notice_deadline: float = 120.0) -> "FaultTrace":
        """Seeded mixed fault soup: Poisson(``rate``/s) chaos arrivals
        over ``horizon`` s, drawing each fault's kind from ``kinds`` and
        cycling victims through ``targets`` ids.  Slowdown/contention
        windows use (``factor``, ``window``); endpoint failures arm
        ``fail_count`` transient errors.  One seed, one soup — the
        recovery-on/off A/B replays the identical schedule."""
        trace = cls(rebalance_lead=rebalance_lead,
                    notice_deadline=notice_deadline)
        rng = np.random.default_rng(seed)
        t, k = 0.0, 0
        while True:
            t += float(rng.exponential(1.0 / rate))
            if t >= horizon:
                break
            kind = kinds[int(rng.integers(len(kinds)))]
            tgt = k % targets
            if kind == "hard_kill":
                trace.inject_hard_kill(t, tgt)
            elif kind == "slowdown":
                trace.inject_slowdown(t, tgt, factor=factor,
                                      duration=window)
            elif kind == "network_contention":
                trace.inject_contention(t, factor=factor, duration=window)
            elif kind == "endpoint_failure":
                trace.inject_endpoint_failure(t, tgt, count=fail_count)
            else:
                trace.inject(t, tgt)
            k += 1
        return trace

    @classmethod
    def from_file(cls, path: str, *, rebalance_lead: float = 180.0,
                  notice_deadline: float = 120.0) -> "FaultTrace":
        """Trace file: ``<t> <target>`` per line for spot interruptions
        (the original format), ``<t> <target> <kind> [key=val ...]`` for
        chaos kinds (# comments)."""
        trace = cls(rebalance_lead=rebalance_lead,
                    notice_deadline=notice_deadline)
        with open(path) as fh:
            for line in fh:
                line = line.split("#", 1)[0].strip()
                if not line:
                    continue
                parts = line.split()
                if len(parts) == 2:
                    t, target = parts
                    trace.inject(float(t), int(target))
                    continue
                t, target, kind = parts[:3]
                kw = dict(p.split("=", 1) for p in parts[3:])
                trace.inject_chaos(
                    float(t), int(target), kind,
                    factor=float(kw.get("factor", 1.0)),
                    duration=float(kw.get("duration", 0.0)),
                    count=int(kw.get("count", 1)))
        return trace

    def to_file(self, path: str):
        """Write the fault schedule; ``from_file`` round-trips it
        exactly (``repr`` floats) — spot lines keep the original
        two-field format, chaos lines append kind + parameters."""
        with open(path, "w") as fh:
            fh.write("# fault trace: <t> <target> [<kind> key=val ...] "
                     "per line\n")
            for t, target in self.interruptions:
                fh.write(f"{t!r} {target}\n")
            for n in self.chaos:
                fh.write(f"{n.t!r} {n.target} {n.kind} "
                         f"factor={n.factor!r} duration={n.duration!r} "
                         f"count={n.count}\n")

    def inject(self, t: float, target: int):
        """FIS analogue: schedule the full lifecycle for ``target``."""
        lc = len(self.interruptions)
        self.interruptions.append((t, target))
        t_notice = t + self.rebalance_lead
        for notice in (
                SpotNotice(t, "rebalance_recommendation", target, lc),
                SpotNotice(t_notice, "interruption_notice", target, lc),
                SpotNotice(t_notice + self.notice_deadline, "terminate",
                           target, lc)):
            self._push(notice)

    def inject_chaos(self, t: float, target: int, kind: str, *,
                     factor: float = 1.0, duration: float = 0.0,
                     count: int = 1) -> SpotNotice:
        """Schedule ONE zero-warning chaos fault (no lifecycle: the
        whole point is that nobody gets a drain window)."""
        if kind not in CHAOS_KINDS:
            raise ValueError(f"unknown chaos kind {kind!r}; "
                             f"choose from {CHAOS_KINDS}")
        notice = SpotNotice(t, kind, target, -1, factor, duration, count)
        self.chaos.append(notice)
        self._push(notice)
        return notice

    def inject_hard_kill(self, t: float, target: int) -> SpotNotice:
        """Terminate ``target`` at ``t`` with zero notice."""
        return self.inject_chaos(t, target, "hard_kill")

    def inject_slowdown(self, t: float, target: int, *,
                        factor: float = 3.0,
                        duration: float = 60.0) -> SpotNotice:
        """Degrade ``target``'s speed by ``factor`` for ``duration`` s
        (processor performance variability)."""
        return self.inject_chaos(t, target, "slowdown", factor=factor,
                                 duration=duration)

    def inject_contention(self, t: float, *, target: int = -1,
                          factor: float = 3.0,
                          duration: float = 60.0) -> SpotNotice:
        """Inflate migration-staging and event-delivery latency by
        ``factor`` for ``duration`` s (network contention; target -1 =
        the whole fabric)."""
        return self.inject_chaos(t, target, "network_contention",
                                 factor=factor, duration=duration)

    def inject_endpoint_failure(self, t: float, target: int, *,
                                count: int = 1) -> SpotNotice:
        """Arm ``target``'s MigrationEndpoint to fail its next ``count``
        staging operations transiently."""
        return self.inject_chaos(t, target, "endpoint_failure",
                                 count=count)

    def _push(self, notice: SpotNotice):
        seq = next(self._seq)
        bisect.insort(self._events, (notice.t, seq, notice))
        for loop, kind in self._sinks:
            loop.schedule(notice.t, kind, notice=notice)

    # ------------------------------------------------------------ consume
    def events(self) -> List[SpotNotice]:
        """Every materialized lifecycle event, time-ordered."""
        return [n for _, _, n in self._events]

    def bind(self, loop, kind: str = "spot"):
        """Deliver all lifecycle events (incl. future injections) as
        ``kind`` events on ``loop``; payload carries the ``notice``."""
        self._sinks.append((loop, kind))
        for t, _, notice in self._events:
            loop.schedule(t, kind, notice=notice)

    def subscribe(self) -> "FaultSubscription":
        return FaultSubscription(self)


class FaultSubscription:
    """Per-consumer delivery cursor over a trace.

    Tracks delivered events by identity (seq), not by a time watermark,
    so a lifecycle injected *behind* an already-polled timestamp is still
    delivered on the next poll — matching the old heap-based feed.
    Traces are small (3 events per interruption), so the linear scan per
    poll is irrelevant.
    """

    def __init__(self, trace: FaultTrace):
        self.trace = trace
        self._delivered: set = set()

    def poll(self, now: float) -> List[SpotNotice]:
        """Pop every undelivered event due at or before ``now``, in order."""
        events = self.trace._events
        hi = bisect.bisect_right(events, (now, math.inf))
        due = [(seq, n) for _, seq, n in events[:hi]
               if seq not in self._delivered]
        self._delivered.update(seq for seq, _ in due)
        return [n for _, n in due]

    @property
    def next_event_t(self) -> float:
        return next((t for t, seq, _ in self.trace._events
                     if seq not in self._delivered), math.inf)


class SpotEventFeed:
    """Back-compat view: the old poll-style feed, now a thin subscription
    over a shared :class:`FaultTrace` (pass ``trace=`` to share one
    schedule between subsystems)."""

    def __init__(self, *, rebalance_lead: float = 180.0,
                 notice_deadline: float = 120.0,
                 trace: Optional[FaultTrace] = None):
        self.trace = trace if trace is not None else FaultTrace(
            rebalance_lead=rebalance_lead, notice_deadline=notice_deadline)
        self.rebalance_lead = self.trace.rebalance_lead
        self.notice_deadline = self.trace.notice_deadline
        self._sub = self.trace.subscribe()

    def inject_interruption(self, t: float, target: int):
        self.trace.inject(t, target)

    def poll(self, now: float) -> List[SpotNotice]:
        return self._sub.poll(now)

    @property
    def next_event_t(self) -> float:
        return self._sub.next_event_t
