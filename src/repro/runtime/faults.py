"""One fault schedule for every subsystem (paper §IV, AWS FIS analogue).

A :class:`FaultTrace` materializes an interruption schedule — injected
explicitly, sampled from a seeded Poisson process, read from a trace
file, or driven per-purchase by the market layer (a ``SpotExchange``
buy samples the instance's interruption time from its market's
price-coupled intensity and injects it here, so interruptions are a
function of *which market each replica was bought in*) — into the full
§IV spot lifecycle per interruption:

    rebalance_recommendation  at  t
    interruption_notice       at  t + rebalance_lead
    terminate                 at  t + rebalance_lead + notice_deadline

Consumers attach in one of two ways:

* ``trace.bind(loop, kind)`` — every lifecycle event (past and future
  injections) is scheduled onto a shared :class:`EventLoop`; this is how
  ``CloudManager``, ``ServingCluster``, and the tile runtime all observe
  the *identical* timestamps from a single trace.
* ``trace.subscribe()`` / :class:`SpotEventFeed` — a poll-style cursor
  view for callers that drive their own time (legacy interface).
"""

from __future__ import annotations

import bisect
import dataclasses
import itertools
import math
from typing import Iterable, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SpotNotice:
    """One spot-lifecycle event delivered to a subscriber."""
    t: float
    kind: str       # rebalance_recommendation | interruption_notice | terminate
    target: int     # subscriber-defined id (instance / serving replica)
    lifecycle: int = -1   # interruption index in the trace: ties the three
                          # events of one lifecycle together even when the
                          # same target is interrupted repeatedly


LIFECYCLE_KINDS = ("rebalance_recommendation", "interruption_notice",
                   "terminate")


class FaultTrace:
    """Seeded-or-file-driven interruption schedule -> lifecycle events."""

    def __init__(self, *, rebalance_lead: float = 180.0,
                 notice_deadline: float = 120.0):
        self.rebalance_lead = rebalance_lead
        self.notice_deadline = notice_deadline
        self.interruptions: List[Tuple[float, int]] = []
        # sorted by (t, seq): bisect keeps polls O(log n), no private heap
        self._events: List[Tuple[float, int, SpotNotice]] = []
        self._seq = itertools.count()
        self._sinks: List[Tuple[object, str]] = []

    # ------------------------------------------------------------ build
    @classmethod
    def sampled(cls, *, rate: float, horizon: float, targets: int,
                seed: int = 0, rebalance_lead: float = 180.0,
                notice_deadline: float = 120.0) -> "FaultTrace":
        """Poisson(``rate``/s) interruption arrivals over ``horizon`` s,
        cycling victims through ``targets`` ids — one seeded draw gives
        one schedule, replayable by any number of consumers."""
        trace = cls(rebalance_lead=rebalance_lead,
                    notice_deadline=notice_deadline)
        rng = np.random.default_rng(seed)
        t, k = 0.0, 0
        while True:
            t += float(rng.exponential(1.0 / rate))
            if t >= horizon:
                break
            trace.inject(t, k % targets)
            k += 1
        return trace

    @classmethod
    def from_file(cls, path: str, *, rebalance_lead: float = 180.0,
                  notice_deadline: float = 120.0) -> "FaultTrace":
        """Trace file: one ``<t> <target>`` pair per line (# comments)."""
        trace = cls(rebalance_lead=rebalance_lead,
                    notice_deadline=notice_deadline)
        with open(path) as fh:
            for line in fh:
                line = line.split("#", 1)[0].strip()
                if not line:
                    continue
                t, target = line.split()
                trace.inject(float(t), int(target))
        return trace

    def to_file(self, path: str):
        """Write the interruption schedule as ``<t> <target>`` lines;
        ``from_file`` round-trips it exactly (``repr`` floats)."""
        with open(path, "w") as fh:
            fh.write("# fault trace: <t> <target> per line\n")
            for t, target in self.interruptions:
                fh.write(f"{t!r} {target}\n")

    def inject(self, t: float, target: int):
        """FIS analogue: schedule the full lifecycle for ``target``."""
        lc = len(self.interruptions)
        self.interruptions.append((t, target))
        t_notice = t + self.rebalance_lead
        for notice in (
                SpotNotice(t, "rebalance_recommendation", target, lc),
                SpotNotice(t_notice, "interruption_notice", target, lc),
                SpotNotice(t_notice + self.notice_deadline, "terminate",
                           target, lc)):
            seq = next(self._seq)
            bisect.insort(self._events, (notice.t, seq, notice))
            for loop, kind in self._sinks:
                loop.schedule(notice.t, kind, notice=notice)

    # ------------------------------------------------------------ consume
    def events(self) -> List[SpotNotice]:
        """Every materialized lifecycle event, time-ordered."""
        return [n for _, _, n in self._events]

    def bind(self, loop, kind: str = "spot"):
        """Deliver all lifecycle events (incl. future injections) as
        ``kind`` events on ``loop``; payload carries the ``notice``."""
        self._sinks.append((loop, kind))
        for t, _, notice in self._events:
            loop.schedule(t, kind, notice=notice)

    def subscribe(self) -> "FaultSubscription":
        return FaultSubscription(self)


class FaultSubscription:
    """Per-consumer delivery cursor over a trace.

    Tracks delivered events by identity (seq), not by a time watermark,
    so a lifecycle injected *behind* an already-polled timestamp is still
    delivered on the next poll — matching the old heap-based feed.
    Traces are small (3 events per interruption), so the linear scan per
    poll is irrelevant.
    """

    def __init__(self, trace: FaultTrace):
        self.trace = trace
        self._delivered: set = set()

    def poll(self, now: float) -> List[SpotNotice]:
        """Pop every undelivered event due at or before ``now``, in order."""
        events = self.trace._events
        hi = bisect.bisect_right(events, (now, math.inf))
        due = [(seq, n) for _, seq, n in events[:hi]
               if seq not in self._delivered]
        self._delivered.update(seq for seq, _ in due)
        return [n for _, n in due]

    @property
    def next_event_t(self) -> float:
        return next((t for t, seq, _ in self.trace._events
                     if seq not in self._delivered), math.inf)


class SpotEventFeed:
    """Back-compat view: the old poll-style feed, now a thin subscription
    over a shared :class:`FaultTrace` (pass ``trace=`` to share one
    schedule between subsystems)."""

    def __init__(self, *, rebalance_lead: float = 180.0,
                 notice_deadline: float = 120.0,
                 trace: Optional[FaultTrace] = None):
        self.trace = trace if trace is not None else FaultTrace(
            rebalance_lead=rebalance_lead, notice_deadline=notice_deadline)
        self.rebalance_lead = self.trace.rebalance_lead
        self.notice_deadline = self.trace.notice_deadline
        self._sub = self.trace.subscribe()

    def inject_interruption(self, t: float, target: int):
        self.trace.inject(t, target)

    def poll(self, now: float) -> List[SpotNotice]:
        return self._sub.poll(now)

    @property
    def next_event_t(self) -> float:
        return self._sub.next_event_t
