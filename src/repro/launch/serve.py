"""Serving launcher: continuous-batching engine over any --arch.

CPU-scale demo (reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --requests 8 --max-new 8

Cluster mode — replicated engines on a heterogeneous spot fleet, with
rate-aware routing and a drained interruption:
  PYTHONPATH=src python -m repro.launch.serve --cluster --fleet 2x2.0,2x0.7 \
      --router rate_aware --requests 24 --interrupt-at 4

Chaos drill — seeded fault soup (hard kills, slowdowns, contention,
endpoint failures) survived via checkpoints + heartbeat detection:
  PYTHONPATH=src python -m repro.launch.serve --cluster --fleet 2x1.0 \
      --requests 10 --chaos 3 --chaos-rate 0.05 --checkpoint-every 3
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCHS
from repro.models import model_zoo as zoo
from repro.serving.engine import ServingEngine


def _make_requests(args, cfg):
    from repro.serving.workload import classed_requests, synthetic_requests
    if getattr(args, "slo_mix", None) is not None:
        return classed_requests(args.requests, cfg.vocab_size,
                                interactive_frac=args.slo_mix,
                                seed=args.seed)
    return synthetic_requests(
        args.requests, cfg.vocab_size, seed=args.seed,
        prompt_len=(3, min(12, args.max_seq // 2)), max_new=args.max_new)


def _parse_fleet(spec: str):
    """'2x2.0,2x0.7@0.5' -> two speed-2.0 replicas at the default $1/h +
    two speed-0.7 replicas at $0.50/h (cost feeds the dollar metrics and
    cost-aware scaling)."""
    from repro.cluster import InstanceType
    fleet = []
    try:
        for part in spec.split(","):
            count, speed = part.split("x")
            speed, _, cost = speed.partition("@")
            for _ in range(int(count)):
                fleet.append(InstanceType(
                    f"spot.{speed}x", float(speed),
                    cost_per_hour=float(cost) if cost else 1.0))
    except ValueError:
        raise SystemExit(
            f"bad --fleet spec {spec!r}: expected "
            f"'<count>x<speed>[@<cost_per_hour>],...' like '2x2.0,2x0.7@0.5'")
    if not fleet:
        raise SystemExit("--fleet spec produced an empty fleet")
    return fleet


def run_single(args, cfg, params):
    engine = ServingEngine(cfg, params, batch_size=args.batch_size,
                           max_seq=args.max_seq,
                           temperature=args.temperature, seed=args.seed,
                           prefill_mode=args.prefill_mode,
                           decode_block=args.decode_block)
    reqs = _make_requests(args, cfg)
    for req in reqs:
        engine.submit(req)
    stats = engine.run_until_idle()
    done = sum(r.done for r in reqs)
    print(f"arch={cfg.name} served {done}/{len(reqs)} requests, "
          f"{stats['tokens']} tokens in {stats['seconds']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")


def _make_exchange(args, fleet):
    """Default two-market exchange over the fleet's instance types: a
    cheap-but-volatile market (scheduled price spike, spike-coupled
    interruption intensity) and a pricier steady one, both priced
    relative to the fleet's mean on-demand rate."""
    from repro.market import MarketCatalog, SpotExchange, SpotMarket
    itypes = sorted({it for it in fleet}, key=lambda it: it.name)
    od = sum(it.cost_per_hour for it in itypes) / len(itypes)
    cat = MarketCatalog()
    cat.add_market(SpotMarket(
        "volatile", base_rate=0.25 * od, volatility=0.08,
        spikes=((120.0, 360.0, 5.0),), interruptions_per_hour=2.0,
        price_power=3.0, seed=args.seed + 1))
    cat.add_market(SpotMarket(
        "steady", base_rate=0.45 * od, volatility=0.02,
        interruptions_per_hour=0.1, seed=args.seed + 2))
    for it in itypes:
        cat.list_instance(it, markets=("volatile", "steady"))
    return SpotExchange(cat, seed=args.seed, mode=args.market)


def run_cluster(args, cfg, params):
    from repro.cluster import (CheckpointPolicy, FailureDetector,
                               PREEMPTION_POLICIES, ROUTERS,
                               SCALING_POLICIES, ServingCluster,
                               StragglerPolicy)
    from repro.runtime import FaultTrace
    fleet = _parse_fleet(args.fleet)
    preemption = PREEMPTION_POLICIES[args.preemption]() \
        if args.preemption != "none" else None
    exchange = None
    if args.market != "off":
        exchange = _make_exchange(args, fleet)
    # --chaos SEED samples a mixed fault soup (hard kills, slowdowns,
    # network contention, endpoint failures) and arms recovery: periodic
    # checkpoints (--checkpoint-every), heartbeat failure detection, and
    # straggler quarantine
    trace = checkpoint = health = straggler = None
    if args.chaos is not None:
        trace = FaultTrace.chaos_sampled(
            rate=args.chaos_rate, horizon=200.0, targets=len(fleet),
            seed=args.chaos, rebalance_lead=args.rebalance_lead,
            notice_deadline=args.notice_deadline)
        health = FailureDetector()
        straggler = StragglerPolicy()
    if args.checkpoint_every is not None:
        checkpoint = CheckpointPolicy(interval=args.checkpoint_every)
    elif args.chaos is not None:
        checkpoint = CheckpointPolicy()
    # --vertical arms an in-place resize recommender; --qos layers the
    # Guaranteed/Burstable/BestEffort capacity contract on admission and
    # shrink-eviction order (either works alone, they compose when both
    # are set)
    qos = vertical = None
    if args.qos or args.vertical != "off":
        from repro.vertical import QoSPolicy, VERTICAL_POLICIES
        if args.qos:
            qos = QoSPolicy()
        if args.vertical != "off":
            vertical = VERTICAL_POLICIES[args.vertical](qos=qos)
    scaling = None
    if args.scaling == "cost_aware":
        if exchange is not None:
            # market mode: shop (instance type, market) pairs by speed
            # per interruption-adjusted effective dollar
            from repro.market import MarketAwareScaling
            scaling = MarketAwareScaling(exchange)
        else:
            # the catalog is the distinct instance types in the fleet
            catalog = sorted({it for it in fleet}, key=lambda it: it.name)
            scaling = SCALING_POLICIES["cost_aware"](catalog)
    cl = ServingCluster(cfg, params, fleet,
                        router=ROUTERS[args.router](),
                        batch_size=args.batch_size, max_seq=args.max_seq,
                        temperature=args.temperature,
                        prefill_mode=args.prefill_mode,
                        decode_block=args.decode_block,
                        dt=1.0, seed=args.seed,
                        rebalance_lead=args.rebalance_lead,
                        notice_deadline=args.notice_deadline,
                        admission=args.admission,
                        rebalance_interval=args.migrate_every,
                        preemption=preemption, scaling=scaling,
                        market=exchange,
                        fallback=args.fallback if exchange else None,
                        trace=trace, checkpoint=checkpoint,
                        health=health, straggler=straggler,
                        vertical=vertical, qos=qos)
    from repro.serving.workload import make_arrivals
    reqs = _make_requests(args, cfg)
    cl.attach_arrivals(make_arrivals(args.arrival, reqs, seed=args.seed))
    if args.interrupt_at is not None:
        cl.inject_interruption(t=args.interrupt_at, replica_rid=0)
    t0 = time.perf_counter()
    out = cl.run()
    wall = time.perf_counter() - t0
    print(f"arch={cfg.name} router={args.router} fleet={args.fleet}")
    print(f"  completed {out['completed']}/{out['submitted']} "
          f"(dropped {out['dropped']}), {out['total_tokens']} tokens")
    print(f"  virtual: makespan={out['virtual_seconds']:.0f}s "
          f"p50={out['p50_latency']:.1f}s p99={out['p99_latency']:.1f}s "
          f"agg={out['tok_per_s']:.2f} tok/s  (wall {wall:.1f}s)")
    if out["drains"]:
        print(f"  drains={out['drains']} migrated_slots="
              f"{out['migrated_slots']} ckpt+restore="
              f"{out['interruption_overhead_s']*1e3:.1f}ms")
    if out["rebalance_migrations"]:
        print(f"  rebalance_migrations={out['rebalance_migrations']}")
    if out["preemptions"]:
        print(f"  preemptions={out['preemptions']} "
              f"resumes={out['resumes']}")
    if out["vertical_grows"] or out["vertical_shrinks"]:
        print(f"  vertical: grows={out['vertical_grows']} "
              f"shrinks={out['vertical_shrinks']} "
              f"evictions={out['vertical_evictions']} "
              f"stage={out['resize_stage_s']*1e3:.1f}ms")
    if args.qos:
        print(f"  qos slot-s: guaranteed="
              f"{out['qos_guaranteed_slot_s']:.1f} "
              f"burstable={out['qos_burstable_slot_s']:.1f} "
              f"best_effort={out['qos_best_effort_slot_s']:.1f}")
    if out["hard_kills"] or out["checkpoints"]:
        print(f"  chaos: hard_kills={out['hard_kills']} "
              f"lost={out['requests_lost']} "
              f"recovered={out['requests_recovered']} "
              f"replayed_tokens={out['replayed_tokens']} "
              f"checkpoints={out['checkpoints']} "
              f"quarantines={out['quarantines']}")
    if out["slowdowns"] or out["contention_windows"] \
            or out["endpoint_faults"]:
        print(f"  degraded: slowdowns={out['slowdowns']} "
              f"contention_windows={out['contention_windows']} "
              f"(+{out['contention_delay_s']:.1f}s staging) "
              f"endpoint_faults={out['endpoint_faults']} "
              f"retries={out['endpoint_retries']}")
    print(f"  fleet_dollar_cost=${out['fleet_dollar_cost']:.4f}")
    if exchange is not None:
        print(f"  market[{args.market}]: "
              f"cost=${out['market_dollar_cost']:.4f} "
              f"vs on-demand ${out['on_demand_dollar_cost']:.4f} "
              f"-> savings {out['savings_pct']:.1f}% "
              f"({out['spot_interruptions']} interruptions, "
              f"fallback={args.fallback})")
        for m in exchange.catalog.markets():
            n = out.get(f"market_{m.name}_purchases", 0)
            if n:
                print(f"    {m.name}: {n} buys "
                      f"${out[f'market_{m.name}_dollars']:.4f} "
                      f"{out[f'market_{m.name}_interruptions']} "
                      f"interruptions")
    for k in sorted(out):
        if k.startswith("attainment_"):
            slo = k[len("attainment_"):]
            print(f"  slo[{slo}]: attainment={out[k]:.3f} "
                  f"p99={out.get(f'p99_latency_{slo}', 0.0):.1f}s "
                  f"misses={out.get(f'misses_{slo}', 0)}")
    for rs in cl.metrics.per_replica():
        print(f"  replica r{rs['rid']} {rs['itype']}: {rs['tokens']} tok "
              f"@ {rs['tok_per_s']:.2f} tok/s (measured)")
    for t, msg in cl.timeline:
        print(f"  [{t:7.1f}s] {msg}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-mode", default="chunked",
                    choices=("chunked", "streamed"),
                    help="chunked bulk prefill (bucketed make_prefill) or "
                         "the streamed per-token baseline")
    ap.add_argument("--decode-block", type=int, default=8,
                    help="fused decode steps per dispatch (sync-free "
                         "window size)")
    # cluster mode
    ap.add_argument("--cluster", action="store_true",
                    help="serve over a replicated heterogeneous fleet")
    ap.add_argument("--fleet", default="2x2.0,2x0.7",
                    help="fleet spec: '<count>x<speed>,...'")
    ap.add_argument("--router", default="rate_aware",
                    choices=("rate_aware", "round_robin", "slo_aware"))
    ap.add_argument("--admission", default="fifo",
                    choices=("fifo", "priority"),
                    help="priority holds batch-class arrivals until the "
                         "fleet has backlog headroom")
    ap.add_argument("--preemption", default="none",
                    choices=("none", "slo"),
                    help="slo pauses batch-class slots (WorkUnit "
                         "preempt/resume) when waiting interactive work "
                         "would miss its deadline")
    ap.add_argument("--scaling", default="backlog",
                    choices=("backlog", "cost_aware"),
                    help="cost_aware shops the fleet's instance types by "
                         "speed per dollar on every scale-up/replacement")
    ap.add_argument("--vertical", default="off",
                    choices=("off", "fixed", "window"),
                    help="in-place replica resize: fixed reacts to "
                         "instantaneous backlog per lane, window to a "
                         "sliding-window mean (no drain; evicted slots "
                         "park and resume)")
    ap.add_argument("--qos", action="store_true",
                    help="QoS-classed capacity: interactive=Guaranteed "
                         "(reserved), standard=Burstable, batch="
                         "BestEffort (bursts into idle capacity, "
                         "evicted first on shrink)")
    ap.add_argument("--slo-mix", type=float, default=None,
                    help="serve an interactive/batch SLO mix with this "
                         "interactive fraction (default: class-less)")
    ap.add_argument("--migrate-every", type=float, default=None,
                    help="mid-stream migration pass interval in virtual "
                         "seconds (default: off)")
    ap.add_argument("--market", default="off",
                    choices=("off", "naive", "adjusted"),
                    help="buy replicas on priced spot markets; naive "
                         "shops the cheapest rate right now, adjusted "
                         "the interruption-adjusted effective price")
    ap.add_argument("--fallback", default="on_demand",
                    choices=("on_demand", "different_market",
                             "different_type", "queue_work",
                             "scale_down"),
                    help="replacement strategy on a spot rebalance "
                         "recommendation (market mode only)")
    ap.add_argument("--interrupt-at", type=float, default=None,
                    help="inject a spot interruption on replica 0 at this "
                         "virtual time")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="sample a seeded chaos soup (hard kills, "
                         "slowdowns, network contention, endpoint "
                         "failures) and arm heartbeat failure detection "
                         "+ straggler quarantine + checkpoints")
    ap.add_argument("--chaos-rate", type=float, default=0.02,
                    help="chaos fault arrivals per virtual second "
                         "(with --chaos)")
    ap.add_argument("--checkpoint-every", type=float, default=None,
                    metavar="S",
                    help="periodic WorkUnit recovery checkpoints every S "
                         "virtual seconds (default: on with --chaos at "
                         "the policy's interval, else off)")
    ap.add_argument("--rebalance-lead", type=float, default=6.0)
    ap.add_argument("--notice-deadline", type=float, default=4.0)
    ap.add_argument("--arrival", default="batch",
                    help="offered load: batch | poisson:<rate> | "
                         "trace:<file>")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(args.seed)).params
    if args.cluster:
        run_cluster(args, cfg, params)
    else:
        run_single(args, cfg, params)


if __name__ == "__main__":
    main()
