"""Serving launcher: continuous-batching engine over any --arch.

CPU-scale demo (reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --requests 8 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import model_zoo as zoo
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    params = zoo.init_state(cfg, jax.random.PRNGKey(args.seed)).params
    engine = ServingEngine(cfg, params, batch_size=args.batch_size,
                           max_seq=args.max_seq,
                           temperature=args.temperature, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = []
    for rid in range(args.requests):
        plen = int(rng.integers(3, min(12, args.max_seq // 2)))
        req = Request(rid=rid,
                      prompt=rng.integers(0, cfg.vocab_size, plen,
                                          dtype=np.int32),
                      max_new_tokens=args.max_new)
        reqs.append(req)
        engine.submit(req)
    stats = engine.run_until_idle()
    done = sum(r.done for r in reqs)
    print(f"arch={cfg.name} served {done}/{len(reqs)} requests, "
          f"{stats['tokens']} tokens in {stats['seconds']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()
