"""Training launcher: data pipeline + model zoo + elastic adaptive runtime.

``ElasticTrainer`` is the production driver: it owns the mesh, shardings,
jitted train step, prefetching data pipeline, periodic checkpoints, and the
shrink/expand protocol (via core.elastic.ElasticRuntime).  Spot events from a
CloudManager (or an explicit schedule, as in the examples) trigger real
rescales whose stage timings are recorded.

CLI (CPU-scale):
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
      --steps 20 --n-devices 1
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs import ARCHS, SHAPES
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.checkpointing import InMemoryStore, make_store
from repro.core.elastic import ElasticRuntime, RescaleEvent
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.sharding import ShardingRules, use_rules
from repro.launch.specs import (batch_shardings, metrics_shardings,
                                state_shardings)
from repro.models import model_zoo as zoo
from repro.optim import adamw


def _mesh_for(n_devices: int, model_par: int = 1):
    assert n_devices % model_par == 0
    return make_mesh((n_devices // model_par, model_par), ("data", "model"))


class ElasticTrainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *,
                 n_devices: Optional[int] = None, model_par: int = 1,
                 seed: int = 0, store_kind: str = "memory",
                 hp: Optional[adamw.HParams] = None):
        self.cfg = cfg
        self.shape = shape
        self.data = SyntheticLM(cfg, shape, seed=seed)
        self.step_idx = 0
        self.metrics_log: List[Dict[str, float]] = []
        n_devices = n_devices or len(jax.devices())
        self.model_par = model_par
        init = zoo.init_state(cfg, jax.random.PRNGKey(seed))

        def mesh_factory(n):
            return _mesh_for(n, self.model_par)

        def shardings_factory(mesh):
            return state_shardings(cfg, ShardingRules(mesh))

        def step_factory(mesh):
            rules = ShardingRules(mesh)
            ssh = state_shardings(cfg, rules)
            bsh = batch_shardings(cfg, shape, rules)
            fn = zoo.make_train_step(cfg, hp=hp)
            jitted = jax.jit(fn, in_shardings=(ssh, bsh),
                             out_shardings=(ssh, metrics_shardings(rules)),
                             donate_argnums=(0,))
            # eager AOT compile: this is the paper's 'restart' stage --
            # application startup dominates rescale cost (Fig 5/6)
            with mesh, use_rules(rules):
                jitted.lower(zoo.abstract_state(cfg),
                             zoo.batch_spec(cfg, shape)).compile()

            def wrapped(state, batch):
                with mesh, use_rules(rules):
                    return jitted(state, batch)
            return wrapped

        self.runtime = ElasticRuntime(
            mesh_factory=mesh_factory,
            shardings_factory=shardings_factory,
            step_factory=step_factory,
            init_state=init,
            n_devices=n_devices,
            store=make_store(store_kind),
        )

    # ------------------------------------------------------------- training
    def train(self, n_steps: int, log_every: int = 10) -> Dict[str, float]:
        t0 = time.perf_counter()
        mesh = self.runtime.mesh
        rules = ShardingRules(mesh)
        bsh = batch_shardings(self.cfg, self.shape, rules)
        for _ in range(n_steps):
            host = self.data.batch_at(self.step_idx)
            batch = jax.tree.map(jax.device_put, host, bsh)
            metrics = self.runtime.step(batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step"] = self.step_idx
            self.metrics_log.append(metrics)
            if log_every and self.step_idx % log_every == 0:
                print(f"step {self.step_idx:5d} loss {metrics['loss']:.4f} "
                      f"gnorm {metrics['grad_norm']:.3f}", flush=True)
            self.step_idx += 1
        return {"seconds": time.perf_counter() - t0,
                "final_loss": self.metrics_log[-1]["loss"]}

    # ------------------------------------------------------------- elastic
    def rescale(self, n_devices: int) -> RescaleEvent:
        ev = self.runtime.rescale_to(n_devices)
        print(f"[elastic] {ev.kind} {ev.from_devices}->{ev.to_devices} "
              + " ".join(f"{k}={v*1e3:.1f}ms" for k, v in ev.stages.items()),
              flush=True)
        return ev

    @property
    def state(self):
        return self.runtime.state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=sorted(ARCHS))
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES))
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--n-devices", type=int, default=None)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    shape = SHAPES[args.shape]
    if args.reduced:
        cfg, shape = cfg.reduced(), shape.reduced()
    trainer = ElasticTrainer(cfg, shape, n_devices=args.n_devices,
                             model_par=args.model_par, seed=args.seed)
    out = trainer.train(args.steps)
    print(f"done: {out}")


if __name__ == "__main__":
    main()
