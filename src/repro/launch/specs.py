"""input_specs(): ShapeDtypeStruct stand-ins + shardings per (arch x shape).

Shardings for jit *inputs* must divide evenly; ShardingRules guarantees that
(launch/sharding.py).  No device allocation happens here.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.sharding import (ShardingRules, param_shardings,
                                   zero1_extend, zero1_shardings)
from repro.models import model_zoo as zoo
from repro.models import transformer as T
from repro.models.schema import Spec, is_spec
from repro.optim.adamw import AdamWState


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig,
                    rules: ShardingRules):
    spec = zoo.batch_spec(cfg, shape)
    return {
        k: rules.sharding(("batch",) + (None,) * (len(v.shape) - 1), v.shape)
        for k, v in spec.items()
    }


def params_shardings(cfg: ModelConfig, rules: ShardingRules):
    return param_shardings(rules, T.model_schema(cfg))


_zero1_extend = zero1_extend  # re-export (tests import from here)


def state_shardings(cfg: ModelConfig, rules: ShardingRules):
    psh = params_shardings(cfg, rules)
    sch = T.model_schema(cfg)
    if cfg.zero1:
        opt_one = zero1_shardings(rules, sch)
    else:
        opt_one = psh
    return zoo.TrainState(
        step=NamedSharding(rules.mesh, P()),
        params=psh,
        opt=AdamWState(m=opt_one, v=opt_one),
    )


def decode_state_shardings(cfg: ModelConfig, shape: ShapeConfig,
                           rules: ShardingRules):
    ab = zoo.abstract_decode_state(cfg, shape)
    ax = zoo.decode_state_logical_axes(cfg)
    cache_sh = jax.tree.map(
        lambda s, a: rules.sharding(a, s.shape), ab.cache, ax.cache,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return zoo.DecodeState(cache_sh,
                           rules.sharding(ax.cache_len,
                                          (shape.global_batch,)))


def metrics_shardings(rules: ShardingRules):
    rep = NamedSharding(rules.mesh, P())
    return {k: rep for k in ("loss", "nll", "aux", "grad_norm")}


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                rules: ShardingRules) -> Dict[str, Any]:
    """Everything dryrun/train/serve needs to lower a cell.

    Returns dict with: kind, abstract args, in_shardings, out_shardings.
    """
    if shape.kind == "train":
        args = (zoo.abstract_state(cfg), zoo.batch_spec(cfg, shape))
        in_sh = (state_shardings(cfg, rules), batch_shardings(cfg, shape,
                                                              rules))
        out_sh = (state_shardings(cfg, rules), metrics_shardings(rules))
        return dict(kind="train", args=args, in_shardings=in_sh,
                    out_shardings=out_sh)
    if shape.kind == "prefill":
        params = T.model_schema(cfg)
        from repro.models.schema import abstract_params
        args = (abstract_params(params, cfg.param_dtype),
                zoo.batch_spec(cfg, shape))
        rep = NamedSharding(rules.mesh, P())
        in_sh = (params_shardings(cfg, rules),
                 batch_shardings(cfg, shape, rules))
        out_sh = (rep, decode_state_shardings(cfg, shape, rules))
        return dict(kind="prefill", args=args, in_shardings=in_sh,
                    out_shardings=out_sh)
    if shape.kind == "decode":
        from repro.models.schema import abstract_params
        params = abstract_params(T.model_schema(cfg), cfg.param_dtype)
        args = (params, zoo.abstract_decode_state(cfg, shape),
                zoo.batch_spec(cfg, shape))
        rep = NamedSharding(rules.mesh, P())
        dsh = decode_state_shardings(cfg, shape, rules)
        in_sh = (params_shardings(cfg, rules), dsh,
                 batch_shardings(cfg, shape, rules))
        out_sh = (rep, dsh)
        return dict(kind="decode", args=args, in_shardings=in_sh,
                    out_shardings=out_sh)
    raise ValueError(shape.kind)


def cell_fn(cfg: ModelConfig, shape: ShapeConfig, *, unroll=False):
    """The function lowered for a cell."""
    if shape.kind == "train":
        return zoo.make_train_step(cfg, unroll=unroll)
    if shape.kind == "prefill":
        return zoo.make_prefill(cfg, shape, unroll=unroll)
    if shape.kind == "decode":
        return zoo.make_serve_step(cfg, shape, unroll=unroll)
    raise ValueError(shape.kind)
