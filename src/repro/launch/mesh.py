"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    # jax.sharding.AxisType landed after 0.4.x; older jax uses Auto
    # semantics implicitly and make_mesh has no axis_types parameter.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (smoke tests, elastic reconfigurations)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Mesh over however many (possibly forced-host) devices exist."""
    n = len(jax.devices())
    assert n_data * n_model <= n, (n_data, n_model, n)
    return make_mesh((n_data, n_model), ("data", "model"))
