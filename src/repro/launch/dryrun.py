import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (jax locks the device
# count on first init).  Everything below is ordinary code.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCHS, SHAPES, shape_applicable  # noqa: E402
from repro.launch import hlo_analysis as H                 # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.launch.sharding import ShardingRules, use_rules  # noqa: E402
from repro.launch.specs import cell_fn, input_specs        # noqa: E402

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


# --------------------------------------------------------------- compilation
def compile_cell(cfg, shape, mesh, *, unroll=False, with_out_shardings=True,
                 donate=False):
    rules = ShardingRules(mesh)
    spec = input_specs(cfg, shape, rules)
    fn = cell_fn(cfg, shape, unroll=unroll)
    kw = dict(in_shardings=spec["in_shardings"])
    if with_out_shardings:
        kw["out_shardings"] = spec["out_shardings"]
        if donate and shape.kind == "train":
            kw["donate_argnums"] = (0,)     # state in -> state out
        elif donate and shape.kind == "decode":
            kw["donate_argnums"] = (1,)     # KV cache / SSM state
    with mesh, use_rules(rules):
        t0 = time.time()
        lowered = jax.jit(fn, **kw).lower(*spec["args"])
        compiled = lowered.compile()
        dt = time.time() - t0
    return compiled, dt


def production_record(cfg, shape, mesh, donate=False):
    compiled, dt = compile_cell(cfg, shape, mesh, donate=donate)
    rec = {
        "compile_s": round(dt, 2),
        "memory": H.memory_stats(compiled),
        # body-once caveat: qualitative collective schedule only
        "raw_terms_body_once": H.extract_terms(compiled),
        "n_devices": mesh.devices.size,
    }
    del compiled
    return rec


def _analysis_cfg(cfg, n_units, n_micro):
    """Shrink the stack to ``n_units`` layer-units for an unrolled build."""
    kw = dict(attn_impl="full", num_microbatches=n_micro)
    if cfg.family == "enc_dec":
        kw.update(enc_layers=n_units, dec_layers=n_units, num_layers=0)
    elif cfg.family == "hybrid":
        kw.update(num_layers=cfg.attn_every * n_units)
    else:
        kw.update(num_layers=n_units)
    return cfg.with_(**kw)


def production_units(cfg) -> int:
    if cfg.family == "enc_dec":
        return cfg.enc_layers
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    return cfg.num_layers


def analysis_points(cfg, shape, mesh):
    """Unrolled small builds for linear cost extrapolation.

    train: cost(L, M) = a + M*b + M*L*d  -> 3 points
    other: cost(L)    = a + L*d          -> 2 points
    """
    pts = []
    if shape.kind == "train":
        per_micro = shape.global_batch // max(cfg.num_microbatches, 1)
        combos = [(1, 1), (2, 1), (1, 2)]
        for (L_, M_) in combos:
            cfg_a = _analysis_cfg(cfg, L_, M_)
            shape_a = shape.__class__(shape.name, shape.seq_len,
                                      per_micro * M_, shape.kind)
            compiled, dt = compile_cell(cfg_a, shape_a, mesh,
                                        unroll=True,
                                        with_out_shardings=False)
            terms = H.extract_terms(compiled)
            terms.update(L=L_, M=M_, compile_s=round(dt, 2))
            pts.append(terms)
            del compiled
    else:
        for L_ in (1, 2):
            cfg_a = _analysis_cfg(cfg, L_, cfg.num_microbatches)
            compiled, dt = compile_cell(cfg_a, shape, mesh, unroll=True,
                                        with_out_shardings=False)
            terms = H.extract_terms(compiled)
            terms.update(L=L_, M=1, compile_s=round(dt, 2))
            pts.append(terms)
            del compiled
    return pts


# --------------------------------------------------------------- driver
def run_cell(arch: str, shape_name: str, *, meshes=("single", "multi"),
             analysis=True, out_dir: Path = ARTIFACT_DIR,
             force=False, opts=()) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}__{shape_name}.json"
    if path.exists() and not force:
        return json.loads(path.read_text())

    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    donate = "donate" in opts
    if "zero1" in opts:
        cfg = cfg.with_(zero1=True)
    if "overlapped" in opts:
        cfg = cfg.with_(grad_schedule="overlapped")
    if "bf16params" in opts:
        cfg = cfg.with_(param_dtype="bfloat16")
    for o in opts:
        if o.startswith("micro="):
            cfg = cfg.with_(num_microbatches=int(o.split("=")[1]))
        if o.startswith("moe="):
            cfg = cfg.with_(moe_impl=o.split("=")[1])
    if "gradbf16" in opts:
        cfg = cfg.with_(grad_reduce_dtype="bfloat16")
    rec = {"arch": arch, "shape": shape_name, "kind": shape.kind}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec["skipped"] = why
        path.write_text(json.dumps(rec, indent=1))
        return rec

    try:
        for mesh_kind in meshes:
            mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
            rec[f"production_{mesh_kind}"] = production_record(
                cfg, shape, mesh, donate=donate)
        if analysis:
            mesh = make_production_mesh(multi_pod=False)
            rec["analysis_points"] = analysis_points(cfg, shape, mesh)
            rec["production_L_units"] = production_units(cfg)
            rec["production_M"] = (cfg.num_microbatches
                                   if shape.kind == "train" else 1)
        rec["ok"] = True
    except Exception as e:  # a dry-run failure is a bug in our system
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--no-analysis", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACT_DIR))
    ap.add_argument("--opt", default="",
                    help="comma list: zero1,overlapped,donate,bf16params,"
                         "micro=N")
    args = ap.parse_args()

    meshes = {"both": ("single", "multi"), "single": ("single",),
              "multi": ("multi",)}[args.mesh]
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            t0 = time.time()
            rec = run_cell(arch, shape_name, meshes=meshes,
                           analysis=not args.no_analysis,
                           out_dir=Path(args.out), force=args.force,
                           opts=tuple(o for o in args.opt.split(",") if o))
            status = ("SKIP " + rec["skipped"] if "skipped" in rec
                      else "OK" if rec.get("ok") else
                      "FAIL " + rec.get("error", "?"))
            print(f"[{time.time()-t0:7.1f}s] {arch:22s} {shape_name:12s} "
                  f"{status}", flush=True)
            if not rec.get("ok") and "skipped" not in rec:
                n_fail += 1
    print(f"done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
