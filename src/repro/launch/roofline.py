"""Roofline table from dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json, extrapolates the unrolled analysis points to
the production (layers, microbatches), and emits per-cell roofline terms:

  t_compute    = flops_per_device / 197e12
  t_memory     = hbm_bytes_per_device / 819e9
  t_collective = wire_bytes_per_device / 50e9

plus MODEL_FLOPS (6*N*D train / 2*N*D inference, active-params for MoE), the
useful-compute ratio, the dominant term, and per-device memory from the
full-L scanned production compile.

Cost model (exact for homogeneous stacks):
  train:  c(L, M) = a + M*b + M*L*d   (3 analysis points)
  other:  c(L)    = a + L*d           (2 analysis points)
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import ARCHS, SHAPES
from repro.launch.dryrun import ARTIFACT_DIR, production_units
from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.models import model_zoo as zoo

CHIPS_SINGLE_POD = 256


def _metric(pt: dict, key: str) -> float:
    if key == "wire_bytes":
        return float(pt.get("wire_bytes", 0.0))
    return float(pt.get(key, 0.0))


def extrapolate(points: List[dict], key: str, kind: str, L: int,
                M: int) -> float:
    """Linear cost-model fit -> value at production (L, M)."""
    if kind == "train":
        by = {(p["L"], p["M"]): _metric(p, key) for p in points}
        (l1, m1), (l2, _), (_, m2) = (1, 1), (2, 1), (1, 2)
        c11, c21, c12 = by[(1, 1)], by[(2, 1)], by[(1, 2)]
        d = c21 - c11                 # per-layer per-microbatch
        b = c12 - 2 * c11 + d        # c12 = a + 2b + 2d; c11 = a + b + d
        a = c11 - b - d
        return max(a + M * b + M * L * d, 0.0)
    by = {p["L"]: _metric(p, key) for p in points}
    d = by[2] - by[1]
    a = by[1] - d
    return max(a + L * d, 0.0)


def model_flops(arch: str, shape_name: str) -> float:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    n = zoo.active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n * tokens


def cell_roofline(rec: dict) -> Optional[dict]:
    if "analysis_points" not in rec:
        return None
    kind = rec["kind"]
    L = rec["production_L_units"]
    M = rec.get("production_M", 1)
    pts = rec["analysis_points"]
    flops = extrapolate(pts, "flops", kind, L, M)
    hbm = extrapolate(pts, "bytes_accessed", kind, L, M)
    wire = extrapolate(pts, "wire_bytes", kind, L, M)
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_w = wire / ICI_BW
    dominant = max(
        (("compute", t_c), ("memory", t_m), ("collective", t_w)),
        key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / (flops * CHIPS_SINGLE_POD) if flops else 0.0
    mem = rec.get("production_single", {}).get("memory", {})
    bound = max(t_c, t_m, t_w)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": kind,
        "flops_per_device": flops, "hbm_bytes_per_device": hbm,
        "wire_bytes_per_device": wire,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_w,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": (t_c / bound) if bound else 0.0,
        "peak_hbm_gib": mem.get("peak_hbm_estimate", 0) / 2**30,
    }


def load_table(art_dir: Path = ARTIFACT_DIR) -> List[dict]:
    rows = []
    for f in sorted(Path(art_dir).glob("*.json")):
        rec = json.loads(f.read_text())
        if "skipped" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skipped": rec["skipped"]})
            continue
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "error": rec.get("error")})
            continue
        r = cell_roofline(rec)
        if r:
            rows.append(r)
    return rows


def fmt_seconds(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def markdown_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | t_compute | t_memory | t_collective | dominant "
           "| useful | roofline-frac | HBM GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"SKIP | — | — | — |\n")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_seconds(r['t_compute_s'])}"
            f" | {fmt_seconds(r['t_memory_s'])} "
            f"| {fmt_seconds(r['t_collective_s'])} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['peak_hbm_gib']:.1f} |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default=str(ARTIFACT_DIR))
    ap.add_argument("--json", default=None, help="dump rows as json")
    args = ap.parse_args()
    rows = load_table(Path(args.art))
    print(markdown_table(rows))
    if args.json:
        Path(args.json).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
