"""Logical-axis sharding rules (MaxText-style) with divisibility fallbacks.

Models annotate tensors with *logical* axis names; this module maps them to
mesh axes. jit *input* shardings must divide evenly (verified on jax 0.8.2),
so ``logical_to_spec`` checks divisibility and falls back:

  batch        -> ("pod", "data")          (always divides for assigned shapes)
  embed        -> None (activations) / "model" for embedding tables' d_model
  vocab        -> "model", fallback: replicate (vocab stays whole, the
                  d_model dim of the table is sharded instead via 'embed_tp')
  heads        -> "model" if divisible else replicate   (llama3.2 24H)
  kv_heads     -> "model" if divisible else replicate
  ff / expert_ff -> "model"
  experts      -> "model" if divisible else replicate (qwen2's 60 experts;
                  its expert_ff fallback still gives the layer a TP dim)
  cache_seq    -> "model"   (decode KV caches: 32768 / 524288 divide 16)
  d_inner / conv_dim / ssm_heads -> "model" if divisible

Inside jit, ``constrain`` applies with_sharding_constraint with the active
rules; with no active mesh it is a no-op so the same model runs on CPU.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _axis_size(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


class ShardingRules:
    """Maps logical axis names -> mesh axis names with divisibility checks."""

    # logical name -> preferred mesh axes (tuple entries = multi-axis)
    PREFERRED = {
        "batch": ("pod", "data"),
        "vocab": ("model",),
        "embed_tp": ("model",),      # embedding-table d_model fallback dim
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": None,   # contraction-dim TP measured 30x worse than
        # replicated attention for llama 24H (EXPERIMENTS.md §Perf h4): the
        # per-layer activation all-reduces dwarf the saved compute
        "ff": ("model",),
        "expert_ff": ("model",),
        "experts": ("model",),
        "cache_seq": ("model",),
        "cache_batch": ("pod", "data"),
        "d_inner": ("model",),
        "conv_dim": ("model",),
        "ssm_heads": ("model",),
        "ssm_state": None,
        "embed": None,               # activation d_model: replicated
        "seq": None,
        "layers": None,
        "periods": None,
        "stack": None,
        None: None,
    }

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self.axes = set(mesh.axis_names)

    def mesh_axes_for(self, logical: Optional[str], dim_size: int):
        pref = self.PREFERRED.get(logical, None)
        if pref is None:
            return None
        present = tuple(a for a in pref if a in self.axes)
        if not present:
            return None
        if dim_size % _axis_size(self.mesh, present) != 0:
            return None  # fallback: replicate this dim
        return present if len(present) > 1 else present[0]

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Sequence[int]) -> P:
        # earlier dims take priority; a mesh axis is used at most once
        used = set()
        parts = []
        for ax, d in zip(logical_axes, shape):
            m = self.mesh_axes_for(ax, d)
            names = (m,) if isinstance(m, str) else (m or ())
            if m is None or any(n in used for n in names):
                parts.append(None)
            else:
                used.update(names)
                parts.append(m)
        return P(*parts)

    def sharding(self, logical_axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


@contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def active_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


def constrain(x, *logical_axes):
    """with_sharding_constraint by logical names; no-op without active rules."""
    rules = active_rules()
    if rules is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    spec = rules.spec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def param_shardings(rules: ShardingRules, schema):
    """Pytree of NamedShardings for a param schema (models/schema.py)."""
    from repro.models.schema import Spec, is_spec
    return jax.tree.map(lambda s: rules.sharding(s.axes, s.shape),
                        schema, is_leaf=is_spec)


def zero1_extend(sharding: NamedSharding, shape, rules: ShardingRules):
    """Additionally shard one dim over 'data' (ZeRO-1 optimizer state /
    reduce-scattered gradient accumulation)."""
    if "data" not in rules.axes:
        return sharding
    dsize = rules.mesh.shape["data"]
    parts = list(sharding.spec) + [None] * (len(shape) - len(sharding.spec))
    for i, (p, d) in enumerate(zip(parts, shape)):
        if p is None and d % dsize == 0:
            parts[i] = "data"
            return NamedSharding(rules.mesh, P(*parts))
        if p is not None:
            cur = (p,) if isinstance(p, str) else tuple(p)
            if "data" not in cur and "pod" not in cur:
                total = dsize
                for a in cur:
                    total *= rules.mesh.shape[a]
                if d % total == 0:
                    parts[i] = cur + ("data",)
                    return NamedSharding(rules.mesh, P(*parts))
    return sharding


def zero1_shardings(rules: ShardingRules, schema):
    """Param shardings additionally scattered over 'data' (ZeRO-1)."""
    from repro.models.schema import Spec, is_spec
    psh = param_shardings(rules, schema)
    return jax.tree.map(
        lambda s, spec: zero1_extend(s, spec.shape, rules),
        psh, schema, is_leaf=lambda x: isinstance(x, NamedSharding))
