"""Roofline-term extraction from compiled XLA artifacts.

``cost_analysis()`` supplies HLO FLOPs / bytes; collective traffic is parsed
from the compiled HLO text (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute result sizes + replica-group sizes).

CAVEAT (measured, see DESIGN.md §6): XLA counts a while-loop body ONCE.  The
dry-run therefore lowers *unrolled* analysis builds at two (layers,
microbatch) points and extrapolates linearly; this module only extracts raw
terms from one artifact.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

# TPU v5e-class constants (per assignment)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Collective:
    kind: str
    result_bytes: int
    group_size: int


def parse_collectives(hlo_text: str) -> List[Collective]:
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pair: count the -start only
        rb = shape_bytes(m.group(1))
        g = 1
        mi = _GROUPS_IOTA_RE.search(line)
        if mi:
            g = int(mi.group(2))
        else:
            ml = _GROUPS_LIST_RE.search(line)
            if ml:
                g = len([x for x in ml.group(1).split(",") if x.strip()])
        out.append(Collective(m.group(2), rb, max(g, 1)))
    return out


def wire_bytes_per_device(c: Collective) -> float:
    """Ring-algorithm bytes each device puts on ICI links.

    ``result_bytes`` is the full (global logical) result size as printed in
    the *partitioned* HLO, i.e. already the per-device tensor for most ops.
    """
    g = c.group_size
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if c.kind == "all-gather":
        # per-device output is g x input; each device sends input*(g-1)
        return c.result_bytes * frac
    if c.kind == "reduce-scatter":
        return c.result_bytes * (g - 1)
    if c.kind == "all-reduce":
        return 2.0 * c.result_bytes * frac
    if c.kind == "all-to-all":
        return c.result_bytes * frac
    if c.kind == "collective-permute":
        return float(c.result_bytes)
    return 0.0


def collective_summary(hlo_text: str) -> Dict[str, Dict[str, float]]:
    colls = parse_collectives(hlo_text)
    summary: Dict[str, Dict[str, float]] = {}
    for c in colls:
        s = summary.setdefault(c.kind, {"count": 0, "result_bytes": 0,
                                        "wire_bytes": 0.0})
        s["count"] += 1
        s["result_bytes"] += c.result_bytes
        s["wire_bytes"] += wire_bytes_per_device(c)
    return summary


def total_wire_bytes(summary: Dict[str, Dict[str, float]]) -> float:
    return sum(s["wire_bytes"] for s in summary.values())


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_device / ICI_BW

    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant(),
        }


def extract_terms(compiled) -> Dict[str, float]:
    """Raw per-artifact terms (body-once caveat applies to loops)."""
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    summ = collective_summary(txt)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collectives": summ,
        "wire_bytes": total_wire_bytes(summ),
    }


def memory_stats(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_hbm_estimate": (ma.argument_size_in_bytes
                              + ma.output_size_in_bytes
                              + ma.temp_size_in_bytes
                              - ma.alias_size_in_bytes),
    }
