"""Vertical resize recommenders: when to grow/shrink a replica in place.

Two shapes, mirroring the Kube-DRM evaluation scenarios:

* ``FixedThresholdVertical`` — the "extreme" reactive shape: compare
  each replica's *instantaneous* backlog per lane against fixed
  grow/shrink thresholds and step the lane count immediately (bounded
  by a per-replica cooldown so the pool doesn't flap).
* ``SlidingWindowVertical`` — the smoothed ("guaranteed"-leaning)
  shape: the same thresholds over a sliding-window *mean* of the
  pressure signal, so one bursty tick neither grows nor shrinks the
  replica; sustained pressure does.

Both compose with ``QoSPolicy``: a shrink never goes below the lanes
currently occupied by Guaranteed-class work, and the cluster passes the
QoS ``evict_key`` to ``resize`` so any evicted slots are BestEffort
first.  Decisions are ``ResizeOrder``s; the cluster executes them and
parks evicted units for resume — a shrink moves work, never loses it.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.cluster.control import (ClusterView, ResizeOrder,
                                   VerticalScalingPolicy)

from repro.vertical.qos import qos_for


class FixedThresholdVertical(VerticalScalingPolicy):
    """Grow when backlog per lane exceeds ``grow_backlog`` token-units,
    shrink when it falls under ``shrink_backlog``, in ``step``-lane
    moves bounded by ``[min_batch, max_batch]`` and a per-replica
    ``cooldown`` (virtual seconds between resizes of the same replica).
    """

    name = "fixed"

    def __init__(self, *, min_batch: int = 1, max_batch: int = 8,
                 step: int = 2, grow_backlog: float = 24.0,
                 shrink_backlog: float = 4.0, cooldown: float = 6.0,
                 qos=None):
        if min_batch < 1 or max_batch < min_batch:
            raise ValueError(
                f"need 1 <= min_batch <= max_batch, got "
                f"[{min_batch}, {max_batch}]")
        if shrink_backlog >= grow_backlog:
            raise ValueError("shrink_backlog must be < grow_backlog "
                             "(hysteresis band)")
        self.min_batch = int(min_batch)
        self.max_batch = int(max_batch)
        self.step = max(int(step), 1)
        self.grow_backlog = grow_backlog
        self.shrink_backlog = shrink_backlog
        self.cooldown = cooldown
        self.qos = qos
        self._last_resize: Dict[int, float] = {}

    # ------------------------------------------------------------ signal
    def _pressure(self, rep, share: float, now: float) -> Optional[float]:
        """Backlog token-units per lane on ``rep``, including its share
        of the pool's routed-but-unplaced queue.  Subclasses may smooth;
        None means 'no decision this tick'."""
        return ((rep.engine.backlog_tokens() + share)
                / max(rep.engine.batch, 1))

    def _guaranteed_floor(self, rep) -> int:
        """Lanes a shrink must keep: live Guaranteed-class slots."""
        if self.qos is None:
            return 0
        return sum(1 for _, r in rep.engine.slot_requests()
                   if qos_for(r.slo).reserved)

    # ---------------------------------------------------------- decision
    def decide(self, view: ClusterView, now: float) -> List[ResizeOrder]:
        orders: List[ResizeOrder] = []
        for model_id in view.pools():
            pool = view.pool(model_id, "serving")
            if not pool:
                continue
            share = view.queued_cost(model_id) / len(pool)
            for rep in pool:
                pressure = self._pressure(rep, share, now)
                if pressure is None:
                    continue
                if now - self._last_resize.get(rep.rid,
                                               float("-inf")) < self.cooldown:
                    continue
                b = rep.engine.batch
                if pressure > self.grow_backlog and b < self.max_batch:
                    nb = min(b + self.step, self.max_batch)
                    orders.append(ResizeOrder(
                        rid=rep.rid, batch_size=nb,
                        reason=f"backlog/lane={pressure:.0f}"))
                    self._last_resize[rep.rid] = now
                elif pressure < self.shrink_backlog and b > self.min_batch:
                    nb = max(b - self.step, self.min_batch,
                             self._guaranteed_floor(rep))
                    if nb < b:
                        orders.append(ResizeOrder(
                            rid=rep.rid, batch_size=nb,
                            reason=f"quiet (backlog/lane="
                                   f"{pressure:.1f})"))
                        self._last_resize[rep.rid] = now
        return orders


class SlidingWindowVertical(FixedThresholdVertical):
    """Same thresholds, applied to a ``window``-second sliding mean of
    the pressure signal.  No decision until the window has at least
    ``min_samples`` ticks of history, so startup transients and single
    bursty ticks never resize anything."""

    name = "window"

    def __init__(self, *, window: float = 12.0, min_samples: int = 3,
                 **kw):
        super().__init__(**kw)
        self.window = window
        self.min_samples = max(int(min_samples), 1)
        self._samples: Dict[int, List[Tuple[float, float]]] = {}

    def _pressure(self, rep, share: float, now: float) -> Optional[float]:
        raw = super()._pressure(rep, share, now)
        hist = self._samples.setdefault(rep.rid, [])
        hist.append((now, raw))
        while hist and hist[0][0] < now - self.window:
            hist.pop(0)
        if len(hist) < self.min_samples:
            return None
        return sum(s for _, s in hist) / len(hist)


VERTICAL_POLICIES = {
    "fixed": FixedThresholdVertical,
    "window": SlidingWindowVertical,
}
