"""QoS-classed capacity: SLO classes mapped onto Kube-DRM-style tiers.

Kubernetes (and the Kube-DRM in-place-resize work built on it) grades
pods by their request/limit shape into **Guaranteed** (requests ==
limits: capacity reserved, evicted last), **Burstable** (requests <
limits: may use spare capacity, evicted before Guaranteed) and
**BestEffort** (no requests: runs purely on idle capacity, evicted
first).  This module mirrors that contract onto the serving fleet's
``SLOClass``es:

* ``interactive`` (priority 0) -> **Guaranteed**: slots reserved, never
  held at the door, last to be evicted by a shrink.
* ``standard`` (priority 1)    -> **Burstable**: normal admission,
  evicted before Guaranteed under a shrink.
* ``batch`` / any lazily-admitted class -> **BestEffort**: bursts into
  idle capacity only (held at the door while the pool has none beyond
  the Guaranteed reservation), first evicted by a shrink.

``QoSPolicy`` is the enforcement object the cluster composes with its
``PreemptionPolicy``: its ``hold``/``admit_held`` gate runs *after* the
preemption policy's headroom gate (either may hold), and its
``evict_key`` orders ``ServingEngine.resize`` evictions so a shrink
takes BestEffort work first.  Deadline urgency within a tier is still
``SLOPreemption``'s job — QoS decides *who owns capacity*, preemption
decides *who yields it right now*.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class QoSClass:
    name: str
    eviction_rank: int        # higher = evicted earlier under a shrink
    reserved: bool = False    # capacity is reserved for this tier
    burst_only: bool = False  # admits only into idle (unreserved) capacity


GUARANTEED = QoSClass("guaranteed", 0, reserved=True)
BURSTABLE = QoSClass("burstable", 1)
BEST_EFFORT = QoSClass("best_effort", 2, burst_only=True)

QOS_CLASSES: Tuple[QoSClass, ...] = (GUARANTEED, BURSTABLE, BEST_EFFORT)


def qos_for(slo) -> QoSClass:
    """Map an ``SLOClass`` (or None) onto its QoS tier.

    Lazily-admitted classes are BestEffort regardless of priority (they
    already consented to waiting at the door); priority 0 is Guaranteed;
    everything else — including class-less requests — is Burstable.
    """
    if slo is None:
        return BURSTABLE
    if slo.admit_lazily or slo.priority >= 2:
        return BEST_EFFORT
    if slo.priority == 0:
        return GUARANTEED
    return BURSTABLE


class QoSPolicy:
    """Admission + eviction enforcement over the QoS tiers.

    ``reserve_frac`` of each replica's lanes is the Guaranteed
    reservation: BestEffort arrivals are held at the door unless some
    admitting replica in their pool has a genuinely idle lane beyond
    that reservation and beyond already-placed waiting work (the
    "bursts into idle capacity" contract).  Guaranteed and Burstable
    admission is untouched — their gates stay with the preemption
    policy's headroom logic.
    """

    def __init__(self, reserve_frac: float = 0.25):
        if not 0.0 <= reserve_frac < 1.0:
            raise ValueError(f"reserve_frac must be in [0, 1), "
                             f"got {reserve_frac}")
        self.reserve_frac = reserve_frac

    # ------------------------------------------------------------ tiers
    @staticmethod
    def qos_for(slo) -> QoSClass:
        return qos_for(slo)

    def reserved_slots(self, rep) -> int:
        """Lanes held back for Guaranteed work on one replica."""
        return int(rep.engine.batch * self.reserve_frac)

    # -------------------------------------------------------- admission
    def _pool_has_idle(self, model_id: str, view) -> bool:
        for rep in view.pool(model_id):
            spare = (rep.engine.free_slots - len(view.waiting(rep))
                     - self.reserved_slots(rep))
            if spare > 0:
                return True
        return False

    def hold(self, req, view) -> bool:
        """Door gate: BestEffort waits while its pool has no idle lane
        beyond the Guaranteed reservation."""
        if not qos_for(req.slo).burst_only:
            return False
        return not self._pool_has_idle(req.model_id, view)

    def admit_held(self, held: Sequence, view) -> Tuple[List, List]:
        """Split held arrivals into (admit now, keep holding)."""
        admit, still = [], []
        for req in held:
            (still if self.hold(req, view) else admit).append(req)
        return admit, still

    # --------------------------------------------------------- eviction
    @staticmethod
    def evict_key(u) -> Tuple:
        """Keep-preference for ``resize``: Guaranteed kept first,
        BestEffort evicted first; within a tier the stream with the
        most progress survives (least wasted sunk work), uid tiebreak."""
        return (qos_for(u.slo).eviction_rank, -u.snapshot.fed, u.uid)
