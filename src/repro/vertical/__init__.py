"""Vertical elasticity: in-place replica resize + QoS-classed capacity.

Three parts (see ISSUE/README "Vertical elasticity & QoS"):

* the resize *mechanism* lives on the engines
  (``ServingEngine.resize`` / ``SimEngine.resize`` /
  ``Replica.resize``) — repack through the canonical ``SlotSnapshot``
  path, no drain, surviving streams bit-identical;
* the QoS *contract* (``qos.py``): ``SLOClass`` -> Guaranteed /
  Burstable / BestEffort with door-gating and eviction order;
* the resize *policy* (``policy.py``): fixed-threshold vs
  sliding-window recommenders behind the ``ControlPlane.vertical``
  seam (``repro.cluster.control.VerticalScalingPolicy``).
"""

from repro.cluster.control import ResizeOrder, VerticalScalingPolicy

from repro.vertical.policy import (VERTICAL_POLICIES,
                                   FixedThresholdVertical,
                                   SlidingWindowVertical)
from repro.vertical.qos import (BEST_EFFORT, BURSTABLE, GUARANTEED,
                                QOS_CLASSES, QoSClass, QoSPolicy, qos_for)

__all__ = [
    "ResizeOrder", "VerticalScalingPolicy",
    "FixedThresholdVertical", "SlidingWindowVertical",
    "VERTICAL_POLICIES",
    "QoSClass", "QoSPolicy", "qos_for",
    "GUARANTEED", "BURSTABLE", "BEST_EFFORT", "QOS_CLASSES",
]
