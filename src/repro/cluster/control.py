"""ControlPlane: pluggable policies over a read-only cluster view.

The paper's thesis is a *separation of mechanism and policy*: migratable
objects with one pack/unpack interface are the mechanism; load
balancing, spot handling and elastic scaling are policies layered on
top.  This module is the policy layer for the serving cluster.  Each
policy consumes a read-only ``ClusterView`` and returns *decisions*
(orders / plans); the ``ServingCluster`` executes them through the
WorkUnit verbs, and its event handlers reduce to thin dispatch.

Three policy seams:

* ``PlacementPolicy``  — where queued requests go and which in-flight
  units migrate for load.  The existing routers (round-robin,
  rate-aware GreedyRefine, deadline-aware) ARE placement policies
  (``repro.cluster.router``); the base class also owns the recurring
  mid-stream ``rebalance`` decision (ETA-ratio gated, one move per pool,
  strict worst-ETA improvement).
* ``PreemptionPolicy`` — who waits at the door (lazy-admission headroom
  gate) and who gets *paused*.  ``SLOPreemption`` preempts batch-class
  slots when waiting interactive work would otherwise miss its deadline
  — freeing capacity through the same pack/unpack mechanism as a drain,
  and resuming the paused units (bit-identically) once the pressure
  clears.
* ``ScalingPolicy``    — when each model pool grows or shrinks and
  WHICH instance type to buy.  ``BacklogScaling`` reproduces the
  backlog/SLO-pressure thresholds; ``CostAwareScaling`` additionally
  selects instance types by measured price-performance over
  ``InstanceType.cost_per_hour`` (the elastic-scheduler follow-up of
  Bhosale & Kale: cost-aware instance selection on the same migratable
  abstraction).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.engine import Request, request_cost
from repro.serving.workunit import WorkUnit

from repro.cluster.replica import InstanceType, Replica, ReplicaState


# ---------------------------------------------------------------- view
class ClusterView:
    """Read-only window onto cluster state for control-plane policies.

    Policies decide; the cluster executes.  Everything here is either a
    measured signal (rates, backlogs, overdue counts) or bookkeeping
    state (queues, pools, paused units).  ``log`` is the one write — a
    timeline annotation, so policy decisions stay observable.
    """

    def __init__(self, cluster):
        self._cl = cluster

    # ------------------------------------------------------------ time
    @property
    def now(self) -> float:
        return self._cl.clock.now()

    def log(self, msg: str):
        self._cl.log(self.now, msg)

    # ----------------------------------------------------------- fleet
    @property
    def replicas(self) -> Tuple[Replica, ...]:
        return tuple(self._cl.replicas)

    def rates(self) -> Dict[int, float]:
        """Measured, normalized rates keyed by replica id."""
        return self._cl.rates()

    def pools(self) -> List[str]:
        return sorted({r.model_id for r in self._cl.replicas})

    def pool(self, model_id: str,
             state: str = "admitting") -> List[Replica]:
        """Pool members by coarse state: admitting | serving | launching."""
        if state == "admitting":
            keep = lambda r: r.admitting            # noqa: E731
        elif state == "serving":
            keep = lambda r: r.serving              # noqa: E731
        elif state == "launching":
            keep = lambda r: r.state == ReplicaState.LAUNCHING  # noqa: E731
        else:
            raise ValueError(f"unknown pool state filter {state!r}")
        return [r for r in self._cl.replicas
                if keep(r) and r.model_id == model_id]

    # ------------------------------------------------------------ work
    def queued(self, model_id: Optional[str] = None) -> List[Request]:
        """Router-level queue (not yet placed on any replica)."""
        return [q for q in self._cl.router.queue
                if model_id is None or q.model_id == model_id]

    def waiting(self, rep: Replica) -> Tuple[Request, ...]:
        """Placed-but-unadmitted requests on one replica's engine."""
        return rep.engine.queued_requests()

    def held(self, model_id: Optional[str] = None) -> List[Request]:
        """Lazily-admitted arrivals still held at the door."""
        return [q for q in self._cl._held
                if model_id is None or q.model_id == model_id]

    def paused(self, model_id: Optional[str] = None) -> List[WorkUnit]:
        """Preempted units parked by the cluster, oldest first."""
        return [u for u in self._cl._paused
                if model_id is None or u.request.model_id == model_id]

    def overdue(self, model_id: Optional[str] = None) -> Dict[str, int]:
        """Per-class live requests already past their deadline."""
        return self._cl.metrics.overdue(self.now, model_id=model_id)

    @property
    def prefill_discount(self) -> float:
        return getattr(self._cl.router, "prefill_discount", 1.0)

    def queued_tokens(self, model_id: str) -> float:
        """Token-units in the router queue for a pool — O(1) via the
        router's incremental aggregate (falls back to a scan for
        routers that don't maintain one)."""
        fn = getattr(self._cl.router, "queued_tokens", None)
        if fn is not None:
            return fn(model_id)
        return sum(q.total_tokens for q in self.queued(model_id))

    def queued_cost(self, model_id: str) -> float:
        """Discounted router load queued for a pool — O(1), as above."""
        fn = getattr(self._cl.router, "queued_cost", None)
        if fn is not None:
            return fn(model_id)
        return sum(request_cost(q, self.prefill_discount)
                   for q in self.queued(model_id))

    def pool_backlog(self, model_id: str) -> float:
        """Pending token-units across the pool: in-engine + routed +
        held + paused (paused work is still owed service)."""
        backlog = sum(r.backlog_tokens()
                      for r in self.pool(model_id, "serving"))
        backlog += self.queued_tokens(model_id)
        backlog += sum(q.total_tokens for q in self.held(model_id))
        backlog += sum(u.remaining_tokens for u in self.paused(model_id))
        return backlog


# ----------------------------------------------------------- decisions
@dataclasses.dataclass
class MigrationPlan:
    """One mid-stream move: pack ``slot`` on ``src``, unpack on ``dst``."""
    src: int                 # source replica rid
    slot: int                # engine slot to pack
    dst: int                 # destination replica rid


@dataclasses.dataclass
class PreemptOrder:
    """Pause ``slots`` on replica ``rid`` (units parked by the cluster)."""
    rid: int
    slots: List[int]


@dataclasses.dataclass
class ResumeOrder:
    """Re-admit parked ``units`` on replica ``rid``."""
    rid: int
    units: List[WorkUnit]


@dataclasses.dataclass
class ScaleDecision:
    """Grow/shrink one pool: launch an instance and/or retire a replica."""
    launch: Optional[InstanceType] = None
    retire: Optional[int] = None     # replica rid to drain + terminate
    reason: str = ""


@dataclasses.dataclass
class ResizeOrder:
    """Vertically resize replica ``rid`` in place (no drain).

    ``None`` fields keep the replica's current value; the cluster
    executes the order through ``Replica.resize`` and parks any evicted
    units for resume, so a shrink never loses work.
    """
    rid: int
    batch_size: Optional[int] = None
    decode_block: Optional[int] = None
    kv_pool_blocks: Optional[int] = None
    reason: str = ""


# ---------------------------------------------------------- placement
class PlacementPolicy:
    """Routing + mid-stream migration decisions.

    ``place`` routes queued requests (the admission queue lives on the
    policy — the existing ``Router`` subclasses adapt by implementing it
    over ``view.replicas`` / ``view.rates()``).  ``rebalance`` returns
    ``MigrationPlan``s; the cluster executes them via pack/unpack.
    """

    name = "base"

    def place(self, view: ClusterView, now: float) -> List[Replica]:
        """Place queued requests; returns replicas that received work."""
        raise NotImplementedError

    def rebalance(self, view: ClusterView, now: float,
                  ratio: float = 1.75) -> List[MigrationPlan]:
        """Proactive mid-stream migration (one move per model pool per
        pass): when the slowest-draining replica's ETA exceeds the
        fastest's by ``ratio``, its costliest in-flight slot moves to
        the least-loaded replica with a free slot — measured rates and
        prefill-discounted backlog only, and only when the move strictly
        improves the pool's worst ETA."""
        rates = view.rates()

        def eta(r: Replica) -> float:
            return (r.engine.backlog_tokens()
                    / max(rates.get(r.rid, 1e-9), 1e-9))

        plans: List[MigrationPlan] = []
        for model_id in view.pools():
            pool = view.pool(model_id)
            if len(pool) < 2:
                continue
            src = max(pool, key=eta)
            dsts = [r for r in pool
                    if r is not src and r.engine.free_slots > 0]
            if not dsts:
                continue
            dst = min(dsts, key=eta)
            if eta(src) <= ratio * eta(dst) + 1e-9:
                continue
            costs = src.engine.slot_costs()
            if not costs:
                continue          # backlog is queue-only: router's job
            slot, cost = max(costs, key=lambda sc: sc[1])
            r_src = max(rates.get(src.rid, 1e-9), 1e-9)
            r_dst = max(rates.get(dst.rid, 1e-9), 1e-9)
            new_worst = max(
                (src.engine.backlog_tokens() - cost) / r_src,
                (dst.engine.backlog_tokens() + cost) / r_dst)
            if new_worst >= eta(src):
                continue          # move would not improve the worst ETA
            plans.append(MigrationPlan(src=src.rid, slot=slot,
                                       dst=dst.rid))
        return plans


# ---------------------------------------------------------- preemption
class PreemptionPolicy:
    """Admission-hold + pause/resume decisions.

    The base policy never preempts: it only implements the lazy-admission
    headroom gate (hold batch-class arrivals while the pool's discounted
    backlog per admitting replica exceeds ``batch_admit_headroom``) and a
    liveness fallback for ``resume`` — any parked unit re-admits as soon
    as its pool has a free slot, so no policy can strand paused work.
    """

    name = "none"

    def __init__(self, batch_admit_headroom: float = 64.0):
        self.batch_admit_headroom = batch_admit_headroom

    # -------------------------------------------------- admission gate
    def headroom(self, view: ClusterView, model_id: str) -> bool:
        """True when the pool's discounted backlog per admitting replica
        is under ``batch_admit_headroom`` token-units."""
        pool = view.pool(model_id)
        if not pool:
            return False
        backlog = sum(r.engine.backlog_tokens() for r in pool)
        backlog += view.queued_cost(model_id)
        return backlog / len(pool) < self.batch_admit_headroom

    def hold(self, req: Request, view: ClusterView) -> bool:
        """Arrival-time gate for lazily-admitted classes."""
        return not self.headroom(view, req.model_id)

    def admit_held(self, held: Sequence[Request], view: ClusterView
                   ) -> Tuple[List[Request], List[Request]]:
        """Split held arrivals into (admit now, keep holding)."""
        admit, still = [], []
        for req in held:
            (admit if self.headroom(view, req.model_id)
             else still).append(req)
        return admit, still

    # --------------------------------------------------- pause/resume
    def preempt(self, view: ClusterView, now: float) -> List[PreemptOrder]:
        return []

    def resume(self, view: ClusterView, now: float) -> List[ResumeOrder]:
        """Liveness fallback: park nothing forever — each pool's paused
        units re-admit (oldest first) onto the least-loaded admitting
        replica as soon as slots free up."""
        orders: List[ResumeOrder] = []
        rates = view.rates()
        for model_id in view.pools():
            paused = view.paused(model_id)
            if not paused or not self._pool_quiet(view, model_id, now,
                                                  rates):
                continue
            # capacity already claimed by placed-but-unadmitted requests
            # is NOT free: unpacked units enter the restore queue, which
            # admits ahead of fresh work, so resuming into a claimed
            # slot would steal it back from the request the preemption
            # freed it for
            pool = sorted(
                [r for r in view.pool(model_id)
                 if self._spare_slots(view, r) > 0],
                key=lambda r: r.engine.backlog_tokens()
                / max(rates.get(r.rid, 1e-9), 1e-9))
            i = 0
            for r in pool:          # spread units over the spare capacity
                if i >= len(paused):
                    break
                take = self._spare_slots(view, r)
                orders.append(ResumeOrder(rid=r.rid,
                                          units=paused[i:i + take]))
                i += take
        return orders

    @staticmethod
    def _spare_slots(view: ClusterView, rep: Replica) -> int:
        """Free slots not already claimed by waiting (placed) requests."""
        return max(rep.engine.free_slots - len(view.waiting(rep)), 0)

    def _pool_quiet(self, view: ClusterView, model_id: str, now: float,
                    rates: Dict[int, float]) -> bool:
        """Hook: is it safe to re-admit paused work into this pool?
        The base policy always says yes (pure liveness)."""
        return True


class SLOPreemption(PreemptionPolicy):
    """SLO-aware preemption: pause batch-class slots when waiting
    interactive work would miss its deadline.

    On every pass, each saturated replica (no free slots) is checked for
    *urgent* waiting requests — placed-but-unadmitted work with a finite
    deadline that the replica's measured rate predicts it will miss
    (service can only start once a slot frees; the wait is the smallest
    remaining slot cost).  For each such request, the costliest
    lower-priority preemptible (``admit_lazily``) slot is paused: the
    slot frees immediately through the same pack mechanism as a drain,
    the unit parks at the cluster, and nothing is lost — the paused
    stream resumes bit-identically once the pool is quiet again.
    """

    name = "slo"

    def __init__(self, batch_admit_headroom: float = 64.0,
                 slack: float = 0.0, max_preempts_per_pass: int = 4):
        super().__init__(batch_admit_headroom)
        self.slack = slack
        self.max_preempts_per_pass = max(int(max_preempts_per_pass), 1)

    # ------------------------------------------------------- urgency
    def _urgent_waiting(self, rep: Replica, view: ClusterView,
                        now: float,
                        rates: Dict[int, float]) -> List[Request]:
        """Waiting requests on ``rep`` predicted to miss their deadline
        if slots only free naturally.

        Queue depth matters: the k-th waiting request can start only
        when k slots have freed, so slot-free times are simulated (a
        tiny EDF pass over remaining slot costs at the measured rate) —
        otherwise everyone behind the first freed slot looks fine until
        it is too late to preempt for them.
        """
        rate = max(rates.get(rep.rid, 1e-9), 1e-9)
        # when each slot can next start new work (0 = free now)
        free_at = [0.0] * rep.engine.free_slots
        free_at += [c / rate for _, c in rep.engine.slot_costs()]
        free_at.sort()
        urgent = []
        for q in sorted(view.waiting(rep),
                        key=lambda q: (q.slo.priority if q.slo else 1,
                                       q.deadline_t(), q.rid)):
            if not free_at:
                break
            start = heapq.heappop(free_at)
            service = request_cost(q, view.prefill_discount) / rate
            heapq.heappush(free_at, start + service)
            dl = q.deadline_t()
            if dl == float("inf"):
                continue
            if q.slo is not None and q.slo.admit_lazily:
                continue          # lazy classes never trigger preemption
            if now + start + service > dl - self.slack:
                urgent.append(q)
        return urgent

    def preempt(self, view: ClusterView, now: float) -> List[PreemptOrder]:
        """Pool-level decision: free as many slots as the pool's urgent
        demand exceeds its free capacity, pausing the costliest
        lower-priority batch slots anywhere in the pool.  Freeing across
        the pool (not just under the replica where the urgent work
        happens to be queued) matters: the router re-places every
        dispatch, so freed capacity on ANY replica is reachable, and a
        surge concentrated by one placement pass still fans out."""
        orders: List[PreemptOrder] = []
        budget = self.max_preempts_per_pass
        rates = view.rates()         # one snapshot per pass, not per replica
        for model_id in view.pools():
            if budget <= 0:
                break
            pool = view.pool(model_id)
            urgent = [q for rep in pool
                      for q in self._urgent_waiting(rep, view, now, rates)]
            if not urgent:
                continue
            spare = sum(r.engine.free_slots for r in pool)
            need = len(urgent) - spare
            if need <= 0:
                continue
            top = min(q.slo.priority for q in urgent if q.slo is not None)
            victims = []              # (remaining cost, rid, slot)
            for rep in pool:
                cost_by_slot = dict(rep.engine.slot_costs())
                victims.extend(
                    (cost_by_slot.get(slot, 0.0), rep.rid, slot)
                    for slot, req in rep.engine.slot_requests()
                    if req.slo is not None and req.slo.admit_lazily
                    and req.slo.priority > top)
            victims.sort(reverse=True)      # costliest first
            take = min(need, len(victims), budget)
            budget -= take
            by_rid: Dict[int, List[int]] = {}
            for _cost, rid, slot in victims[:take]:
                by_rid.setdefault(rid, []).append(slot)
            orders.extend(PreemptOrder(rid=rid, slots=slots)
                          for rid, slots in sorted(by_rid.items()))
        return orders

    def _pool_quiet(self, view: ClusterView, model_id: str, now: float,
                    rates: Dict[int, float]) -> bool:
        """Resume only once no admitting replica in the pool has urgent
        waiting work — otherwise the resumed unit would immediately be
        preempted again (churn)."""
        return not any(self._urgent_waiting(rep, view, now, rates)
                       for rep in view.pool(model_id))


PREEMPTION_POLICIES = {"none": PreemptionPolicy, "slo": SLOPreemption}


# ------------------------------------------------------------- scaling
class ScalingPolicy:
    """Per-pool grow/shrink decisions (the elastic-scheduler layer).

    Scale-up triggers on sustained backlog per replica OR decided
    deadline misses (overdue live requests); scale-down retires the
    slowest replica after a sustained idle window.  Hysteresis timers
    live on the policy, so swapping policies swaps the *whole* decision,
    not just thresholds.  ``select_itype``/``replacement`` are the
    instance-type choice seams ``CostAwareScaling`` overrides.
    """

    name = "backlog"

    def __init__(self, *, scale_up_backlog: float = 128.0,
                 scale_up_patience: float = 30.0,
                 scale_down_idle: float = 120.0,
                 min_replicas: int = 1, max_replicas: int = 8,
                 slo_scale_up: bool = True,
                 default_itype: Optional[InstanceType] = None):
        self.scale_up_backlog = scale_up_backlog
        self.scale_up_patience = scale_up_patience
        self.scale_down_idle = scale_down_idle
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.slo_scale_up = slo_scale_up
        self.default_itype = default_itype
        # per-model-pool hysteresis timers
        self._over_since: Dict[str, float] = {}
        self._idle_since: Dict[str, float] = {}

    # -------------------------------------------- instance selection
    def select_itype(self, view: ClusterView, model_id: str,
                     serving: Sequence[Replica]) -> InstanceType:
        """Which instance type to launch into ``model_id``.

        A ``default_itype`` serving a different pool is never silently
        substituted: the fallback to the pool's own type is logged on
        the cluster timeline (construction already rejected defaults
        that serve NO pool — see ``ServingCluster``)."""
        itype = self.default_itype or serving[0].itype
        if itype.model_id != model_id:
            fallback = serving[0].itype
            view.log(f"scale_up pool={model_id}: default_itype "
                     f"{itype.name} serves pool {itype.model_id!r}; "
                     f"using {fallback.name} instead")
            itype = fallback
        return itype

    def replacement(self, view: ClusterView,
                    rep: Replica) -> InstanceType:
        """Instance type to pre-warm when ``rep`` got a rebalance
        recommendation (spot Mode C).  Like-for-like by default."""
        return rep.itype

    # ------------------------------------------------------ decision
    def decide(self, view: ClusterView, model_id: str,
               now: float) -> Optional[ScaleDecision]:
        serving = view.pool(model_id, "serving")
        launching = view.pool(model_id, "launching")
        if not serving:
            return None
        backlog = view.pool_backlog(model_id)
        per_replica = backlog / max(len(serving) + len(launching), 1)
        # SLO pressure: live requests already past their deadline are
        # decided misses — the pool is under-provisioned for that class
        overdue = (sum(view.overdue(model_id).values())
                   if self.slo_scale_up else 0)

        # scale up on sustained backlog or sustained deadline pressure
        if per_replica > self.scale_up_backlog or overdue > 0:
            self._idle_since.pop(model_id, None)
            if model_id not in self._over_since:
                self._over_since[model_id] = now
            elif (now - self._over_since[model_id] >= self.scale_up_patience
                    and len(serving) + len(launching) < self.max_replicas):
                del self._over_since[model_id]
                itype = self.select_itype(view, model_id, serving)
                why = (f"overdue={overdue}" if overdue
                       else f"backlog/replica={per_replica:.0f}")
                return ScaleDecision(launch=itype, reason=why)
            return None
        self._over_since.pop(model_id, None)

        # scale down a surplus replica after a sustained idle window
        if backlog == 0 and not launching \
                and len(serving) > self.min_replicas:
            if model_id not in self._idle_since:
                self._idle_since[model_id] = now
            elif now - self._idle_since[model_id] >= self.scale_down_idle:
                del self._idle_since[model_id]
                rates = view.rates()
                victim = min(serving,
                             key=lambda r: rates.get(r.rid, 1.0))
                return ScaleDecision(retire=victim.rid,
                                     reason="sustained idle")
        else:
            self._idle_since.pop(model_id, None)
        return None


class BacklogScaling(ScalingPolicy):
    """The PR-1/PR-4 behaviour, named: thresholds only, like-for-like
    instance types."""

    name = "backlog"


class CostAwareScaling(ScalingPolicy):
    """Cost-aware per-pool instance selection over a catalog.

    Same grow/shrink triggers as ``BacklogScaling``, but every launch
    (scale-up AND spot replacement) shops a catalog of instance types:
    the pool-compatible type with the best price-performance
    (``speed / cost_per_hour``) wins, cheapest first on ties.  This is
    the Bhosale & Kale elastic-scheduler move — instance-type selection
    as a policy over the same migratable-unit mechanism.
    """

    name = "cost_aware"

    def __init__(self, catalog: Sequence[InstanceType], **kw):
        super().__init__(**kw)
        if not catalog:
            raise ValueError("CostAwareScaling needs a non-empty catalog")
        self.catalog = tuple(catalog)

    def _best(self, model_id: str) -> Optional[InstanceType]:
        fits = [it for it in self.catalog if it.model_id == model_id]
        if not fits:
            return None
        return max(fits, key=lambda it: (
            it.speed / max(it.cost_per_hour, 1e-9), -it.cost_per_hour))

    def select_itype(self, view: ClusterView, model_id: str,
                     serving: Sequence[Replica]) -> InstanceType:
        best = self._best(model_id)
        if best is None:
            return super().select_itype(view, model_id, serving)
        view.log(f"scale_up pool={model_id}: cost-aware pick "
                 f"{best.name} (speed/$={best.speed / best.cost_per_hour:.2f})")
        return best

    def replacement(self, view: ClusterView,
                    rep: Replica) -> InstanceType:
        return self._best(rep.model_id) or rep.itype


SCALING_POLICIES = {"backlog": BacklogScaling, "cost_aware": CostAwareScaling}


# ---------------------------------------------------- vertical scaling
class VerticalScalingPolicy:
    """Per-replica in-place resize decisions (the Kube-DRM layer).

    Horizontal scaling buys whole instances — full launch latency, full
    ``cost_per_hour``; vertical scaling resizes a live replica's slot
    count in place (the K8s in-place pod-resize move), so a surge can be
    absorbed on hardware already paid for.  The base policy recommends
    nothing; the concrete recommenders live in
    ``repro.vertical.policy`` (fixed-threshold vs sliding-window —
    the Kube-DRM "extreme" vs smoothed shapes) and are registered in
    ``repro.vertical.VERTICAL_POLICIES``.

    Contract: ``decide`` consumes the read-only view and returns
    ``ResizeOrder``s; the cluster executes them, parks evicted units,
    and meters grows/shrinks/evictions in ``ClusterMetrics``.
    """

    name = "vertical_base"

    def decide(self, view: ClusterView, now: float) -> List[ResizeOrder]:
        return []


# -------------------------------------------------------- control plane
@dataclasses.dataclass
class ControlPlane:
    """The cluster's policy seams, swappable independently.

    ``fallback`` is the market-mode fourth seam (a
    ``repro.market.FallbackStrategy``): where replacement capacity
    comes from when a spot notice fires.  None outside market runs.
    ``straggler`` is the chaos-mode fifth seam (a
    ``repro.cluster.health.StragglerPolicy``): quarantine/release
    decisions over measured rates, evaluated on the control tick.
    None disables straggler mitigation.
    ``vertical`` is the elasticity sixth seam (a
    ``VerticalScalingPolicy``): in-place replica resize decisions,
    evaluated on the control tick.  None disables vertical scaling.
    """
    placement: PlacementPolicy
    preemption: PreemptionPolicy
    scaling: ScalingPolicy
    fallback: Optional[object] = None
    straggler: Optional[object] = None
    vertical: Optional[VerticalScalingPolicy] = None
