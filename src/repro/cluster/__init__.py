"""Cloud-native serving cluster (paper §III/§IV applied to serving).

Replicated ``ServingEngine``s behind a pluggable ``ControlPlane``:
in-flight requests are migratable ``WorkUnit``s (one pack/unpack
lifecycle), and placement, SLO-aware preemption and cost-aware elastic
scaling are swappable policies over a read-only ``ClusterView``; a
``VerticalScalingPolicy`` seam adds in-place replica resize on top
(``repro.vertical`` supplies the recommenders and QoS classes).
Chaos faults (hard kills, stragglers, contention, endpoint failures)
are survived through periodic ``CheckpointPolicy`` snapshots, a
heartbeat ``FailureDetector``, and ``StragglerPolicy`` quarantine.
"""

from repro.serving.workunit import WorkUnit

from repro.cluster.autoscaler import Autoscaler
from repro.cluster.checkpoint import CheckpointPolicy, CheckpointRecord
from repro.cluster.cluster import ServingCluster
from repro.cluster.control import (BacklogScaling, ClusterView,
                                   ControlPlane, CostAwareScaling,
                                   MigrationPlan, PlacementPolicy,
                                   PreemptOrder, PreemptionPolicy,
                                   PREEMPTION_POLICIES, ResizeOrder,
                                   ResumeOrder, ScaleDecision,
                                   ScalingPolicy, SCALING_POLICIES,
                                   SLOPreemption, VerticalScalingPolicy)
from repro.cluster.endpoint import (DeviceEndpoint, EndpointUnavailable,
                                    ENDPOINTS, HostEndpoint,
                                    MigrationEndpoint, make_endpoint)
from repro.cluster.health import (FailureDetector, QuarantineOrder,
                                  ReleaseOrder, StragglerPolicy)
from repro.cluster.metrics import ClusterMetrics, VirtualClock
from repro.cluster.replica import InstanceType, Replica, ReplicaState
from repro.cluster.router import (DeadlineAwareRouter, RateAwareRouter,
                                  RoundRobinRouter, Router, ROUTERS)
