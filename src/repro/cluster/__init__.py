"""Cloud-native serving cluster (paper §III/§IV applied to serving).

Replicated ``ServingEngine``s behind a rate-aware (optionally
SLO/deadline-aware) router, with per-model pools, priority admission,
mid-stream slot migration, elastic autoscaling and proactive
spot-interruption drain.
"""

from repro.cluster.autoscaler import Autoscaler
from repro.cluster.cluster import ServingCluster
from repro.cluster.metrics import ClusterMetrics, VirtualClock
from repro.cluster.replica import InstanceType, Replica, ReplicaState
from repro.cluster.router import (DeadlineAwareRouter, RateAwareRouter,
                                  RoundRobinRouter, Router, ROUTERS)
