"""Fleet health: heartbeat failure detection + straggler mitigation.

Two policies the chaos model is pointed at:

* :class:`FailureDetector` — a hard-killed replica announces NOTHING;
  the only signal is silence.  Replicas emit periodic ``heartbeat``
  events while alive; a recurring ``health_check`` scans beat ages
  through the suspect -> confirm -> recover ladder.  Tuning matters:
  ``network_contention`` inflates heartbeat delivery, so a too-tight
  ``suspect_after`` yields false suspicions (cleared when the late beat
  lands), while a too-loose ``confirm_after`` stretches recovery
  latency (measured in ``ClusterMetrics``).

* :class:`StragglerPolicy` — the paper's rate-aware load balancing
  pointed at processor variability instead of heterogeneity: replicas
  whose *measured* rate falls below a fleet-median fraction are
  quarantined (they finish in-flight work but take nothing new) and
  their urgent slots (finite deadlines) proactively migrate away.
  Release is by measured recovery, or by an idle probe so an empty
  quarantined replica gets another chance rather than rotting on a
  stale rate sample.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np


class FailureDetector:
    """Suspect -> confirm dead replicas from heartbeat silence, and
    cross-check liveness against request progress.

    The detector never reads replica state — only what the cluster's
    ``heartbeat`` handler records: beat timestamps, plus (optionally)
    the replica's cumulative processed-token counter and whether it was
    busy at beat time — so detection latency is an honest function of
    the heartbeat/check cadence and timeouts.

    Heartbeats alone miss a *wedged* replica: one that is alive enough
    to beat but no longer decodes (a hung device dispatch, a livelocked
    loop).  When ``progress_stale_after`` is set, a replica whose
    progress counter has not advanced for that long *while it was busy*
    is suspected too — and cleared the moment a beat shows the counter
    moving (or the replica going idle, which is healthy, not wedged).
    Wedge staleness only suspects; confirmation stays heartbeat-based
    (a wedged-but-beating replica is a candidate for operator action or
    straggler quarantine, not for declaring dead and re-running its
    work while it might still complete).
    """

    def __init__(self, *, heartbeat_interval: float = 3.0,
                 check_interval: float = 3.0,
                 suspect_after: float = 7.0,
                 confirm_after: float = 14.0,
                 progress_stale_after: Optional[float] = None):
        if not (suspect_after < confirm_after):
            raise ValueError("suspect_after must precede confirm_after")
        self.heartbeat_interval = float(heartbeat_interval)
        self.check_interval = float(check_interval)
        self.suspect_after = float(suspect_after)
        self.confirm_after = float(confirm_after)
        self.progress_stale_after = (
            None if progress_stale_after is None
            else float(progress_stale_after))
        self._last_beat: Dict[int, float] = {}
        # rid -> (progress counter value, time it last ADVANCED): the
        # timestamp freezes while the counter does, which is exactly the
        # wedge age the scan measures
        self._progress: Dict[int, Tuple[int, float]] = {}
        self._suspected: Set[int] = set()

    def beat(self, rid: int, now: float,
             progress: Optional[int] = None, busy: bool = False):
        """Record a heartbeat.  ``progress`` is the replica's cumulative
        processed-token counter at beat time and ``busy`` whether it
        held active slots; beats without them (birth beats, minimal
        transports) leave the progress record untouched."""
        self._last_beat[rid] = now
        if progress is None:
            return
        if not busy:
            # idle is healthy: drop the record so a later busy phase
            # starts its staleness clock fresh
            self._progress.pop(rid, None)
            return
        prev = self._progress.get(rid)
        if prev is None or progress != prev[0]:
            self._progress[rid] = (progress, now)

    def forget(self, rid: int):
        """Stop monitoring (graceful terminate / confirmed dead)."""
        self._last_beat.pop(rid, None)
        self._progress.pop(rid, None)
        self._suspected.discard(rid)

    def _wedge_age(self, rid: int, now: float) -> float:
        """Seconds the replica has been busy without progress (0 when
        not tracked or the cross-check is disabled)."""
        if self.progress_stale_after is None:
            return 0.0
        rec = self._progress.get(rid)
        return 0.0 if rec is None else now - rec[1]

    def scan(self, replicas, now: float
             ) -> Tuple[List[int], List[int], List[object]]:
        """One health-check pass over monitored replicas.

        Returns (newly suspected rids, cleared rids, confirmed-dead
        replicas).  A replica with no beat recorded yet is not
        monitored (its heartbeat chain hasn't started)."""
        suspects: List[int] = []
        cleared: List[int] = []
        confirmed: List[object] = []
        for rep in replicas:
            last = self._last_beat.get(rep.rid)
            if last is None:
                continue
            age = now - last
            wedged = (self.progress_stale_after is not None
                      and self._wedge_age(rep.rid, now)
                      >= self.progress_stale_after)
            if age >= self.confirm_after:
                confirmed.append(rep)
                self.forget(rep.rid)
            elif age >= self.suspect_after or wedged:
                if rep.rid not in self._suspected:
                    self._suspected.add(rep.rid)
                    suspects.append(rep.rid)
            elif rep.rid in self._suspected:
                self._suspected.discard(rep.rid)
                cleared.append(rep.rid)
        return suspects, cleared, confirmed


@dataclasses.dataclass
class QuarantineOrder:
    rid: int
    slots: Tuple[int, ...] = ()   # urgent slots to migrate away


@dataclasses.dataclass
class ReleaseOrder:
    rid: int


@dataclasses.dataclass
class StragglerPolicy:
    """Quarantine replicas whose measured rate drops below a
    fleet-median fraction; migrate their urgent work proactively.

    ``threshold`` — quarantine below this fraction of the pool-median
    measured rate; ``min_fleet`` — pools smaller than this have no
    meaningful median; ``probe_after`` — release an *idle* quarantined
    replica after this long, so a drained straggler (whose rate sample
    can no longer refresh) gets probed with new work instead of being
    benched forever.
    """

    threshold: float = 0.5
    min_fleet: int = 2
    probe_after: float = 30.0

    def orders(self, view, now: float) -> List[object]:
        rates = view.rates()
        out: List[object] = []
        pools = {r.model_id for r in view.replicas if r.serving}
        for pool in sorted(pools):
            members = [r for r in view.replicas
                       if r.serving and r.model_id == pool]
            if len(members) < self.min_fleet:
                continue
            med = float(np.median([rates.get(r.rid, 0.0)
                                   for r in members]))
            if med <= 0.0:
                continue
            floor = self.threshold * med
            for rep in members:
                rate = rates.get(rep.rid, 0.0)
                if rep.quarantined:
                    idle = rep.engine.n_active == 0
                    if rate >= floor or (
                            idle and now - rep.quarantined_t
                            >= self.probe_after):
                        out.append(ReleaseOrder(rep.rid))
                elif rate < floor:
                    urgent = tuple(
                        slot for slot, req in rep.engine.slot_requests()
                        if np.isfinite(req.deadline_t()))
                    out.append(QuarantineOrder(rep.rid, urgent))
        return out
