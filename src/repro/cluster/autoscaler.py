"""Elastic autoscaling + proactive spot-drain for the serving cluster.

Subscribes to two signal sources:

* the cluster's bound ``FaultTrace`` (repro.runtime) — the §IV spot
  lifecycle, delivered as ``spot`` events on the shared loop.  On a
  *rebalance recommendation* the autoscaler pre-warms a replacement
  replica (the paper's Mode C: replacements are requested at the
  recommendation, long before the 2-minute notice).  On the
  *interruption notice* it drains the doomed replica: every in-flight
  slot is checkpointed (via ``InMemoryStore``) and re-admitted onto the
  healthiest surviving replicas; queued requests go back to the router.
  Zero requests are dropped and no decoded token is recomputed.
* Load + SLOs — thresholds grow and shrink the fleet **per model pool**
  (the elastic-job-scheduler behaviour of Bhosale & Kale, applied to
  serving): sustained backlog OR decided deadline misses (overdue live
  requests of any SLO class) launches a replica into that pool; a
  sustained-idle surplus replica is drained (losslessly) and retired.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.core.cloud import SpotNotice

from repro.cluster.metrics import DrainRecord
from repro.cluster.replica import InstanceType, Replica, ReplicaState


class Autoscaler:
    def __init__(self, cluster, *, replacement_latency: float = 90.0,
                 scale_up_backlog: float = 128.0,
                 scale_up_patience: float = 30.0,
                 scale_down_idle: float = 120.0,
                 min_replicas: int = 1,
                 max_replicas: int = 8,
                 slo_scale_up: bool = True,
                 default_itype: Optional[InstanceType] = None):
        self.cluster = cluster
        self.replacement_latency = replacement_latency
        self.scale_up_backlog = scale_up_backlog
        self.scale_up_patience = scale_up_patience
        self.scale_down_idle = scale_down_idle
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.slo_scale_up = slo_scale_up
        self.default_itype = default_itype
        # per-model-pool hysteresis timers
        self._over_since: Dict[str, float] = {}
        self._idle_since: Dict[str, float] = {}

    # ------------------------------------------------------------- events
    def handle_spot(self, ev: SpotNotice, now: float):
        rep = self.cluster.replica_by_rid(ev.target)
        if rep is None or rep.state == ReplicaState.TERMINATED:
            return
        if ev.kind == "rebalance_recommendation":
            if rep.serving:
                rep.state = ReplicaState.AT_RISK
                # Mode C: request the replacement NOW, rescale later
                new = self.cluster.launch(
                    rep.itype, ready_at=now + self.replacement_latency)
                self.cluster.log(now, f"rebalance_recommendation r{rep.rid} "
                                      f"prewarm r{new.rid}")
        elif ev.kind == "interruption_notice":
            self.cluster.log(now, f"interruption_notice r{rep.rid}")
            self.drain(rep, now)
        elif ev.kind == "terminate":
            rep.terminate()
            self.cluster.log(now, f"terminated r{rep.rid}")

    def drain(self, rep: Replica, now: float):
        """Checkpoint the doomed replica's slots; re-admit them elsewhere."""
        self.cluster.loop.cancel(rep.step_event)   # no step after the drain
        rep.step_event = None
        snaps, queued, (ckpt_s, restore_s) = rep.drain()
        # the drain's snapshot poll may discover just-finished slots: they
        # complete here, not migrate (the replica never steps again)
        self.cluster._harvest(rep, now)
        metrics = self.cluster.metrics
        metrics.drains.append(DrainRecord(
            t=now, replica=rep.rid, slots_migrated=len(snaps),
            queued_requeued=len(queued), checkpoint_s=ckpt_s,
            restore_s=restore_s))
        for s in snaps:
            metrics.on_migration(s.request.rid)
        if queued:
            self.cluster.router.requeue(queued)
        # least-loaded-first (rate-scaled) re-admission; parked if nobody
        # is serving yet (re-admitted once a replacement comes up)
        self.cluster.readmit(snaps, now)

    # ------------------------------------------------------------- load
    def tick(self, now: float):
        """Evaluate every model pool independently: replicas, backlog,
        and SLO pressure never leak across pools."""
        cl = self.cluster
        for model_id in sorted({r.model_id for r in cl.replicas}):
            self._tick_pool(model_id, now)

    def _tick_pool(self, model_id: str, now: float):
        cl = self.cluster
        serving = [r for r in cl.replicas
                   if r.serving and r.model_id == model_id]
        launching = [r for r in cl.replicas
                     if r.state == ReplicaState.LAUNCHING
                     and r.model_id == model_id]
        if not serving:
            return
        backlog = sum(r.backlog_tokens() for r in serving) \
            + sum(q.total_tokens for q in cl.router.queue
                  if q.model_id == model_id) \
            + sum(q.total_tokens for q in cl._held
                  if q.model_id == model_id)
        per_replica = backlog / max(len(serving) + len(launching), 1)
        # SLO pressure: live requests already past their deadline are
        # decided misses — the pool is under-provisioned for that class
        overdue = (sum(cl.metrics.overdue(now, model_id=model_id).values())
                   if self.slo_scale_up else 0)

        # scale up on sustained backlog or sustained deadline pressure
        if per_replica > self.scale_up_backlog or overdue > 0:
            if model_id not in self._over_since:
                self._over_since[model_id] = now
            elif (now - self._over_since[model_id] >= self.scale_up_patience
                    and len(serving) + len(launching) < self.max_replicas):
                itype = self.default_itype or serving[0].itype
                if itype.model_id != model_id:
                    itype = serving[0].itype
                new = cl.launch(itype,
                                ready_at=now + self.replacement_latency)
                why = (f"overdue={overdue}" if overdue
                       else f"backlog/replica={per_replica:.0f}")
                cl.log(now, f"scale_up r{new.rid} ({itype.name}) "
                            f"pool={model_id} {why}")
                del self._over_since[model_id]
        else:
            self._over_since.pop(model_id, None)

        # scale down a surplus replica after a sustained idle window
        if backlog == 0 and not launching and len(serving) > self.min_replicas:
            if model_id not in self._idle_since:
                self._idle_since[model_id] = now
            elif now - self._idle_since[model_id] >= self.scale_down_idle:
                victim = min(serving,
                             key=lambda r: cl.rates().get(r.rid, 1.0))
                self.drain(victim, now)
                victim.terminate()
                cl.log(now, f"scale_down r{victim.rid} pool={model_id}")
                del self._idle_since[model_id]
        else:
            self._idle_since.pop(model_id, None)
