"""Spot-lifecycle handling + elastic scaling *mechanism*.

The decisions live in a pluggable ``ScalingPolicy``
(``repro.cluster.control``): when a pool grows or shrinks, and which
``InstanceType`` to buy (``BacklogScaling`` = thresholds,
``CostAwareScaling`` = price-performance over a catalog).  This class
only executes:

* spot events from the cluster's bound ``FaultTrace`` — on a *rebalance
  recommendation* it pre-warms the policy-chosen replacement (the
  paper's Mode C: replacements are requested at the recommendation,
  long before the 2-minute notice); on the *interruption notice* it
  drains the doomed replica: every in-flight slot is packed into
  ``WorkUnit``s (staged through the replica's ``MigrationEndpoint``)
  and re-admitted onto the healthiest survivors; queued requests go
  back to the router.  Zero requests are dropped and no decoded token
  is recomputed.
* ``ScaleDecision``s from ``policy.decide`` — launches are billed from
  the decision time; retirements drain losslessly, then terminate.

A ``default_itype`` that serves NO pool of the fleet is a configuration
error and is rejected at construction; a default that serves a
*different* pool than the one scaling up is substituted by the pool's
own type — and the substitution is logged on the cluster timeline, never
silent (``ScalingPolicy.select_itype``).
"""

from __future__ import annotations

from typing import Optional

from repro.core.cloud import SpotNotice

from repro.cluster.control import BacklogScaling, ScalingPolicy
from repro.cluster.metrics import DrainRecord
from repro.cluster.replica import Replica, ReplicaState


class Autoscaler:
    def __init__(self, cluster, *, replacement_latency: float = 90.0,
                 scaling: Optional[ScalingPolicy] = None, **policy_kw):
        self.cluster = cluster
        self.replacement_latency = replacement_latency
        if scaling is not None and policy_kw:
            raise ValueError(
                f"an explicit scaling policy carries its own thresholds; "
                f"drop the conflicting autoscaler kwargs "
                f"{sorted(policy_kw)} or configure the policy instead")
        self.policy = scaling if scaling is not None \
            else BacklogScaling(**policy_kw)
        default = self.policy.default_itype
        if default is not None:
            pools = ({it.model_id for it in
                      (r.itype for r in cluster.replicas)}
                     | set(cluster.models))
            if default.model_id not in pools:
                raise ValueError(
                    f"default_itype {default.name!r} serves model pool "
                    f"{default.model_id!r}, which no fleet instance or "
                    f"configured model provides (pools: {sorted(pools)})")

    # ------------------------------------------------------------- events
    def handle_spot(self, ev: SpotNotice, now: float):
        rep = self.cluster.replica_by_rid(ev.target)
        if rep is None or rep.state in (ReplicaState.TERMINATED,
                                        ReplicaState.DEAD):
            return   # gone (or silently dead: a notice can't revive it)
        if ev.kind == "rebalance_recommendation":
            if rep.serving:
                rep.state = ReplicaState.AT_RISK
                fb = self.cluster.fallback
                if fb is not None:
                    # market mode: the fallback strategy decides where
                    # replacement capacity comes from — which hardware,
                    # which market, or none at all (queue_work /
                    # scale_down ride out the loss on survivors)
                    order = fb.replacement(self.cluster.view, rep,
                                           self.cluster.exchange, now)
                    if order is None:
                        self.cluster.log(
                            now, f"rebalance_recommendation r{rep.rid} "
                                 f"fallback={fb.name}: no replacement")
                    else:
                        new = self.cluster.launch(
                            order.itype,
                            ready_at=now + self.replacement_latency,
                            at=now, market=order.market, strategy=fb.name)
                        self.cluster.log(
                            now, f"rebalance_recommendation r{rep.rid} "
                                 f"fallback={fb.name} prewarm r{new.rid} "
                                 f"({order.itype.name} @ {order.market})")
                else:
                    # Mode C: request the replacement NOW, rescale later
                    # — the scaling policy chooses the instance type
                    # (cost-aware policies may shop the catalog instead
                    # of replacing like-for-like)
                    itype = self.policy.replacement(self.cluster.view, rep)
                    new = self.cluster.launch(
                        itype, ready_at=now + self.replacement_latency,
                        at=now)
                    self.cluster.log(now,
                                     f"rebalance_recommendation r{rep.rid} "
                                     f"prewarm r{new.rid} ({itype.name})")
        elif ev.kind == "interruption_notice":
            self.cluster.log(now, f"interruption_notice r{rep.rid}")
            self.drain(rep, now, reason="interruption")
        elif ev.kind == "terminate":
            self.cluster.retire(rep, now)
            self.cluster.log(now, f"terminated r{rep.rid}")

    def drain(self, rep: Replica, now: float,
              reason: str = "interruption"):
        """Pack the doomed replica's slots; re-admit them elsewhere.

        ``reason`` stamps unit provenance and the savings ledger:
        "interruption" = spot notice, "scale_down" = policy retirement.
        """
        self.cluster.loop.cancel(rep.step_event)   # no step after the drain
        rep.step_event = None
        units, queued, (ckpt_s, restore_s) = rep.drain_units()
        # the drain's pack poll may discover just-finished slots: they
        # complete here, not migrate (the replica never steps again)
        self.cluster._harvest(rep, now)
        metrics = self.cluster.metrics
        metrics.drains.append(DrainRecord(
            t=now, replica=rep.rid, slots_migrated=len(units),
            queued_requeued=len(queued), checkpoint_s=ckpt_s,
            restore_s=restore_s, endpoint=rep.endpoint.kind))
        if reason == "interruption" and metrics.ledger is not None:
            metrics.ledger.on_interruption(rep.rid, now,
                                           overhead_s=ckpt_s + restore_s)
        for u in units:
            u.packed_t = now
            u.record_hop(rep.rid, now, reason)
            metrics.on_migration(u.rid)
        if queued:
            self.cluster.router.requeue(queued)
        # least-loaded-first (rate-scaled) re-admission; parked if nobody
        # is serving yet (re-admitted once a replacement comes up)
        self.cluster.readmit(units, now)

    # ------------------------------------------------------------- load
    def tick(self, now: float):
        """Evaluate every model pool independently (replicas, backlog,
        and SLO pressure never leak across pools) and execute the
        policy's decisions."""
        cl = self.cluster
        for model_id in cl.view.pools():
            decision = self.policy.decide(cl.view, model_id, now)
            if decision is None:
                continue
            if decision.launch is not None:
                new = cl.launch(decision.launch,
                                ready_at=now + self.replacement_latency,
                                at=now, strategy="scale_up")
                cl.log(now, f"scale_up r{new.rid} ({decision.launch.name}) "
                            f"pool={model_id} {decision.reason}")
            if decision.retire is not None:
                victim = cl.replica_by_rid(decision.retire)
                if victim is not None and victim.serving:
                    self.drain(victim, now, reason="scale_down")
                    cl.retire(victim, now)
                    cl.log(now, f"scale_down r{victim.rid} "
                                f"pool={model_id} ({decision.reason})")
