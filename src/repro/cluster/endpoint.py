"""MigrationEndpoint: store-backed staging for WorkUnit payloads.

Every migration (drain, rebalance, preempt) round-trips the packed
unit's cache columns through a checkpoint store, so the §IV
checkpoint/restore stages are actually exercised and *timed* — not
assumed.  The endpoint abstracts WHICH store:

* ``HostEndpoint``   — ``InMemoryStore`` (the Linux-shared-memory
                       substrate of §II-B): payloads stage through host
                       RAM.  The default for plain instances.
* ``DeviceEndpoint`` — ``DeviceStore`` (the GPU daemon-process analogue
                       of §IV-A): payloads stage through a second
                       device-resident buffer, so an accelerator host's
                       drain pays an HBM-to-HBM round trip instead of
                       crossing the host link.

Replicas pick their endpoint from ``InstanceType.accelerator`` (or an
explicit override); the measured per-stage seconds flow into
``DrainRecord``/cluster metrics either way, so the host-vs-device cost
asymmetry the paper measures (Fig 5 vs 6) shows up in serving drains
too.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.checkpointing import DeviceStore, InMemoryStore
from repro.serving.workunit import (RESIDENCY_DEVICE, RESIDENCY_HOST,
                                    WorkUnit)


class MigrationEndpoint:
    """Round-trips packed payloads through a checkpoint store.

    ``roundtrip`` saves every unit's cache columns, restores them, and
    writes the restored arrays back into the units — proving the store
    path is lossless and measuring its real (wall-clock) cost.  Each
    unit's ``residency`` is stamped with the store class it staged
    through.
    """

    kind = RESIDENCY_HOST

    def __init__(self, store=None):
        self.store = store if store is not None else self._default_store()

    def _default_store(self):
        return InMemoryStore()

    def roundtrip(self, units: List[WorkUnit],
                  name: str) -> Tuple[float, float]:
        """Stage ``units`` through the store; returns real
        (checkpoint_s, restore_s) stage seconds."""
        if not units:
            return 0.0, 0.0
        ck0 = self.store.timer.stages.get("checkpoint", 0.0)
        rs0 = self.store.timer.stages.get("restore", 0.0)
        self.store.save(name, [u.snapshot.cache for u in units])
        caches = self.store.restore(name)
        ckpt_s = self.store.timer.stages["checkpoint"] - ck0
        restore_s = self.store.timer.stages["restore"] - rs0
        for u, c in zip(units, caches):
            u.snapshot.cache = {k: np.asarray(v) for k, v in c.items()}
            u.residency = self.kind
        self.store.drop(name)
        return ckpt_s, restore_s


class HostEndpoint(MigrationEndpoint):
    """Host-RAM staging (``InMemoryStore``, the shm analogue)."""

    kind = RESIDENCY_HOST


class DeviceEndpoint(MigrationEndpoint):
    """Device-resident staging (``DeviceStore``, the daemon analogue)."""

    kind = RESIDENCY_DEVICE

    def _default_store(self):
        return DeviceStore()


ENDPOINTS = {"host": HostEndpoint, "device": DeviceEndpoint}


def make_endpoint(kind: str, store=None) -> MigrationEndpoint:
    if kind not in ENDPOINTS:
        raise ValueError(f"unknown migration endpoint {kind!r}; "
                         f"choose from {sorted(ENDPOINTS)}")
    return ENDPOINTS[kind](store)
