"""MigrationEndpoint: store-backed staging for WorkUnit payloads.

Every migration (drain, rebalance, preempt) round-trips the packed
unit's cache columns through a checkpoint store, so the §IV
checkpoint/restore stages are actually exercised and *timed* — not
assumed.  The endpoint abstracts WHICH store:

* ``HostEndpoint``   — ``InMemoryStore`` (the Linux-shared-memory
                       substrate of §II-B): payloads stage through host
                       RAM.  The default for plain instances.
* ``DeviceEndpoint`` — ``DeviceStore`` (the GPU daemon-process analogue
                       of §IV-A): payloads stage through a second
                       device-resident buffer, so an accelerator host's
                       drain pays an HBM-to-HBM round trip instead of
                       crossing the host link.

Replicas pick their endpoint from ``InstanceType.accelerator`` (or an
explicit override); the measured per-stage seconds flow into
``DrainRecord``/cluster metrics either way, so the host-vs-device cost
asymmetry the paper measures (Fig 5 vs 6) shows up in serving drains
too.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.checkpointing import DeviceStore, InMemoryStore
from repro.serving.workunit import (RESIDENCY_DEVICE, RESIDENCY_HOST,
                                    WorkUnit)


class EndpointUnavailable(RuntimeError):
    """Transient staging-store failure (armed by an ``endpoint_failure``
    chaos fault); staging ops retry with exponential backoff."""


class MigrationEndpoint:
    """Round-trips packed payloads through a checkpoint store.

    ``roundtrip`` saves every unit's cache columns, restores them, and
    writes the restored arrays back into the units — proving the store
    path is lossless and measuring its real (wall-clock) cost.  Each
    unit's ``residency`` is stamped with the store class it staged
    through.  ``put``/``fetch`` are the persistent variants used by
    recovery checkpoints: the payload stays in the store under its key
    until ``discard``.

    Fault injection: ``arm_failures(k)`` makes the next ``k`` staging
    operations raise :class:`EndpointUnavailable`; every op runs under
    retry-with-backoff (``retries`` / ``backoff_s`` account the cost),
    so transient store outages never lose a unit — only slow it down.
    """

    kind = RESIDENCY_HOST

    def __init__(self, store=None, *, max_retries: int = 6,
                 backoff_base: float = 0.05):
        self.store = store if store is not None else self._default_store()
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self._fail_next = 0
        self.retries = 0          # staging ops that needed a retry
        self.backoff_s = 0.0      # accounted backoff (virtual seconds)

    def _default_store(self):
        return InMemoryStore()

    # ------------------------------------------------- fault injection
    def arm_failures(self, count: int):
        """The next ``count`` staging ops fail transiently."""
        self._fail_next += int(count)

    def _with_retry(self, op):
        delay = self.backoff_base
        for attempt in range(self.max_retries + 1):
            try:
                if self._fail_next > 0:
                    self._fail_next -= 1
                    raise EndpointUnavailable(
                        "staging store unavailable (injected fault)")
                return op()
            except EndpointUnavailable:
                if attempt == self.max_retries:
                    raise
                self.retries += 1
                self.backoff_s += delay
                delay *= 2.0

    # ------------------------------------------------------- staging
    def roundtrip(self, units: List[WorkUnit],
                  name: str) -> Tuple[float, float]:
        """Stage ``units`` through the store; returns real
        (checkpoint_s, restore_s) stage seconds."""
        if not units:
            return 0.0, 0.0

        def op():
            ck0 = self.store.timer.stages.get("checkpoint", 0.0)
            rs0 = self.store.timer.stages.get("restore", 0.0)
            self.store.save(name, [u.snapshot.cache for u in units])
            caches = self.store.restore(name)
            ckpt_s = self.store.timer.stages["checkpoint"] - ck0
            restore_s = self.store.timer.stages["restore"] - rs0
            for u, c in zip(units, caches):
                u.snapshot.cache = {k: np.asarray(v) for k, v in c.items()}
                u.residency = self.kind
            self.store.drop(name)
            return ckpt_s, restore_s
        return self._with_retry(op)

    # ---------------------------------------------------- checkpoints
    def put(self, units: List[WorkUnit], name: str) -> float:
        """Persist the units' cache columns under ``name`` (recovery
        checkpoint); returns real checkpoint stage seconds."""
        if not units:
            return 0.0

        def op():
            ck0 = self.store.timer.stages.get("checkpoint", 0.0)
            self.store.save(name, [u.snapshot.cache for u in units])
            return self.store.timer.stages["checkpoint"] - ck0
        return self._with_retry(op)

    def fetch(self, units: List[WorkUnit], name: str) -> float:
        """Restore ``name``'s payloads back into ``units`` (recovery
        landing); returns real restore stage seconds."""
        if not units or not self.store.exists(name):
            return 0.0

        def op():
            rs0 = self.store.timer.stages.get("restore", 0.0)
            caches = self.store.restore(name)
            restore_s = self.store.timer.stages["restore"] - rs0
            for u, c in zip(units, caches):
                u.snapshot.cache = {k: np.asarray(v) for k, v in c.items()}
                u.residency = self.kind
            return restore_s
        return self._with_retry(op)

    def discard(self, name: str):
        self.store.drop(name)


class HostEndpoint(MigrationEndpoint):
    """Host-RAM staging (``InMemoryStore``, the shm analogue)."""

    kind = RESIDENCY_HOST


class DeviceEndpoint(MigrationEndpoint):
    """Device-resident staging (``DeviceStore``, the daemon analogue)."""

    kind = RESIDENCY_DEVICE

    def _default_store(self):
        return DeviceStore()


ENDPOINTS = {"host": HostEndpoint, "device": DeviceEndpoint}


def make_endpoint(kind: str, store=None) -> MigrationEndpoint:
    if kind not in ENDPOINTS:
        raise ValueError(f"unknown migration endpoint {kind!r}; "
                         f"choose from {sorted(ENDPOINTS)}")
    return ENDPOINTS[kind](store)
