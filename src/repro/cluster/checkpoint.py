"""Periodic WorkUnit checkpoints: the recovery substrate for hard kills.

``CheckpointPolicy`` rides a recurring ``checkpoint`` event on the
cluster's EventLoop (off the hot decode path): each pass asks every
serving replica with live slots for a NON-destructive
``checkpoint_units()`` — the engine keeps decoding — and persists the
payloads in that replica's ``MigrationEndpoint`` store under a stable
per-replica key (Kub-style checkpoint-based recovery, arXiv:2410.10655,
mapped onto the PR 5 WorkUnit verbs).

The catalog keeps only the LATEST checkpoint per replica.  When the
``FailureDetector`` confirms a replica dead, ``recover()`` pulls the
payloads back out of the store (real, timed restore) and hands the
units to the cluster, which rewinds each original request to its
checkpoint progress and re-admits the unit — the lost tail re-decodes
deterministically, so final streams are bit-identical to a fault-free
run.  Requests that were never checkpointed readmit from the prompt.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.serving.workunit import WorkUnit


@dataclasses.dataclass
class CheckpointRecord:
    t: float                     # virtual time the checkpoint was taken
    units: List[WorkUnit]
    name: str                    # store key in the replica's endpoint


class CheckpointPolicy:
    """Cadence + catalog for periodic recovery checkpoints.

    ``interval`` is the checkpoint period in virtual seconds: shorter
    means less replayed work after a hard kill, at more (measured)
    checkpoint staging overhead — the knob the ``cluster_chaos``
    benchmark turns.
    """

    def __init__(self, interval: float = 15.0):
        self.interval = float(interval)
        self._catalog: Dict[int, CheckpointRecord] = {}

    def take(self, rep, now: float) -> Tuple[int, float]:
        """Checkpoint ``rep``'s live slots into its endpoint store;
        returns (units checkpointed, real checkpoint seconds).  May
        raise ``EndpointUnavailable`` past the retry budget — the
        caller skips the pass and tries again next interval."""
        units, ckpt_s = rep.checkpoint_units()
        if units:
            self._catalog[rep.rid] = CheckpointRecord(
                now, units, f"ckpt_r{rep.rid}")
        return len(units), ckpt_s

    def recover(self, rep) -> Tuple[List[WorkUnit], float]:
        """Pull ``rep``'s last checkpoint back out of its endpoint
        store; returns (units, real restore seconds).  The caller
        filters against the lost-work manifest (a unit whose request
        completed or migrated after the checkpoint must not revive)."""
        rec = self._catalog.pop(rep.rid, None)
        if rec is None:
            return [], 0.0
        restore_s = rep.endpoint.fetch(rec.units, rec.name)
        rep.endpoint.discard(rec.name)
        return rec.units, restore_s

    def drop(self, rid: int):
        """Forget a replica's checkpoint (graceful retirement)."""
        self._catalog.pop(rid, None)

    def latest_t(self, rid: int) -> float:
        rec = self._catalog.get(rid)
        return rec.t if rec is not None else float("-inf")
