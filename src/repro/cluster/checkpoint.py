"""Periodic WorkUnit checkpoints: the recovery substrate for hard kills.

``CheckpointPolicy`` rides a recurring ``checkpoint`` event on the
cluster's EventLoop (off the hot decode path): each pass asks every
serving replica with live slots for a NON-destructive
``checkpoint_units()`` — the engine keeps decoding — and persists the
payloads in that replica's ``MigrationEndpoint`` store under a stable
per-replica key (Kub-style checkpoint-based recovery, arXiv:2410.10655,
mapped onto the PR 5 WorkUnit verbs).

The catalog keeps only the LATEST checkpoint per replica.  When the
``FailureDetector`` confirms a replica dead, ``recover()`` pulls the
payloads back out of the store (real, timed restore) and hands the
units to the cluster, which rewinds each original request to its
checkpoint progress and re-admits the unit — the lost tail re-decodes
deterministically, so final streams are bit-identical to a fault-free
run.  Requests that were never checkpointed readmit from the prompt.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.serving.workunit import WorkUnit


@dataclasses.dataclass
class CheckpointRecord:
    t: float                     # virtual time the checkpoint was taken
    units: List[WorkUnit]
    name: str                    # store key in the replica's endpoint


class CheckpointPolicy:
    """Cadence + catalog for periodic recovery checkpoints.

    ``interval`` is the checkpoint period in virtual seconds: shorter
    means less replayed work after a hard kill, at more (measured)
    checkpoint staging overhead — the knob the ``cluster_chaos``
    benchmark turns.

    With ``adaptive=True`` the cadence self-tunes to what is at risk:
    the cluster reports every chaos event through ``note_fault`` and
    ``next_interval`` measures the in-flight token count, and the
    period scales by ``1 / (1 + pressure)`` where pressure sums recent
    faults (per ``fault_ref``) and in-flight tokens (per
    ``tokens_ref``) — more chaos or more live work means checkpoints
    land sooner, so less re-decode after a kill.  A fully quiet window
    (no recent faults, nothing in flight worth protecting) relaxes the
    period by ``quiet_relax`` instead.  Both directions are clamped to
    ``[min_interval, max_interval]``.
    """

    def __init__(self, interval: float = 15.0, *, adaptive: bool = False,
                 min_interval: Optional[float] = None,
                 max_interval: Optional[float] = None,
                 fault_window: float = 60.0, fault_ref: float = 2.0,
                 tokens_ref: float = 256.0, quiet_relax: float = 2.0):
        self.interval = float(interval)
        self.adaptive = bool(adaptive)
        self.min_interval = (self.interval / 4.0 if min_interval is None
                             else float(min_interval))
        self.max_interval = (self.interval * 4.0 if max_interval is None
                             else float(max_interval))
        if not self.min_interval <= self.interval <= self.max_interval:
            raise ValueError(
                f"need min <= interval <= max, got "
                f"[{self.min_interval}, {self.interval}, "
                f"{self.max_interval}]")
        self.fault_window = float(fault_window)
        self.fault_ref = max(float(fault_ref), 1e-9)
        self.tokens_ref = max(float(tokens_ref), 1e-9)
        self.quiet_relax = max(float(quiet_relax), 1.0)
        self._fault_times: List[float] = []
        self._catalog: Dict[int, CheckpointRecord] = {}

    # ------------------------------------------------- adaptive cadence
    def note_fault(self, t: float):
        """Record one chaos event (any kind) for the intensity signal."""
        self._fault_times.append(t)

    def _recent_faults(self, now: float) -> int:
        cutoff = now - self.fault_window
        self._fault_times = [t for t in self._fault_times if t >= cutoff]
        return len(self._fault_times)

    def next_interval(self, replicas, now: float) -> float:
        """Seconds until the next checkpoint pass.

        Non-adaptive policies return the fixed ``interval`` (the
        pre-existing behaviour); adaptive ones scale it by measured
        risk: recent chaos intensity and the token count currently in
        flight across serving replicas (what a kill would force to
        re-decode).
        """
        if not self.adaptive:
            return self.interval
        in_flight = sum(rep.engine.fed_tokens(slot)
                        for rep in replicas if rep.serving
                        for slot, _req in rep.engine.slot_requests())
        pressure = (self._recent_faults(now) / self.fault_ref
                    + in_flight / self.tokens_ref)
        if pressure <= 0.0:
            nxt = self.interval * self.quiet_relax
        else:
            nxt = self.interval / (1.0 + pressure)
        return min(max(nxt, self.min_interval), self.max_interval)

    def take(self, rep, now: float) -> Tuple[int, float]:
        """Checkpoint ``rep``'s live slots into its endpoint store;
        returns (units checkpointed, real checkpoint seconds).  May
        raise ``EndpointUnavailable`` past the retry budget — the
        caller skips the pass and tries again next interval."""
        units, ckpt_s = rep.checkpoint_units()
        if units:
            self._catalog[rep.rid] = CheckpointRecord(
                now, units, f"ckpt_r{rep.rid}")
        return len(units), ckpt_s

    def recover(self, rep) -> Tuple[List[WorkUnit], float]:
        """Pull ``rep``'s last checkpoint back out of its endpoint
        store; returns (units, real restore seconds).  The caller
        filters against the lost-work manifest (a unit whose request
        completed or migrated after the checkpoint must not revive)."""
        rec = self._catalog.pop(rep.rid, None)
        if rec is None:
            return [], 0.0
        restore_s = rep.endpoint.fetch(rec.units, rec.name)
        rep.endpoint.discard(rec.name)
        return rec.units, restore_s

    def drop(self, rid: int):
        """Forget a replica's checkpoint (graceful retirement)."""
        self._catalog.pop(rid, None)

    def latest_t(self, rid: int) -> float:
        rec = self._catalog.get(rid)
        return rec.t if rec is not None else float("-inf")
