"""A serving replica: a ``ServingEngine`` treated as a PE.

The cluster maps the paper's runtime objects onto serving (§III/§IV):
replicas are PEs with *measured* heterogeneous rates; in-flight requests
are migratable chares.  Each replica wraps an engine with

* an ``InstanceType`` (the EC2-flavor analogue: relative speed, spot flag),
* a feed into the shared ``RateMonitor`` — measured tokens/sec, never
  ground-truth speed, so stragglers and jitter are handled identically,
* checkpointable slot state: a drain checkpoints every in-flight slot
  through an ``InMemoryStore`` (the §II-B shm substrate) and hands the
  snapshots back for re-admission elsewhere.

Virtual-time pacing is *message-driven*: each replica schedules its own
next ``replica_step`` event on the shared ``EventLoop`` at its measured
cadence (``step_interval = 1/speed`` virtual seconds per engine step),
so a 2x instance runs twice as many decode steps per virtual second and
slow replicas never quantize fast ones to a global tick.  Decode itself
is real (jitted serve_step); only the pacing is simulated, which keeps
runs deterministic on any host.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.checkpointing import InMemoryStore
from repro.core.rates import RateMonitor
from repro.serving.engine import Request, ServingEngine, SlotSnapshot


@dataclasses.dataclass(frozen=True)
class InstanceType:
    name: str
    speed: float                 # engine steps per virtual second
    spot: bool = True


class ReplicaState(enum.Enum):
    LAUNCHING = "launching"      # requested; warming up until ready_at
    RUNNING = "running"
    AT_RISK = "at_risk"          # rebalance recommendation received
    DRAINING = "draining"        # interruption notice: no new admissions
    TERMINATED = "terminated"


class Replica:
    def __init__(self, rid: int, cfg: ModelConfig, params,
                 itype: InstanceType, *, batch_size: int = 2,
                 max_seq: int = 64, temperature: float = 0.0,
                 monitor: Optional[RateMonitor] = None,
                 store: Optional[InMemoryStore] = None,
                 ready_at: float = 0.0, seed: int = 0):
        self.rid = rid
        self.itype = itype
        self.engine = ServingEngine(cfg, params, batch_size=batch_size,
                                    max_seq=max_seq,
                                    temperature=temperature,
                                    seed=seed + rid)
        self.monitor = monitor
        self.store = store or InMemoryStore()
        self.ready_at = ready_at
        self.state = ReplicaState.LAUNCHING if ready_at > 0 \
            else ReplicaState.RUNNING
        self.tokens_total = 0
        self.completed: List[Request] = []
        self.step_event = None       # pending replica_step on the loop

    # ------------------------------------------------------------- status
    @property
    def serving(self) -> bool:
        """Accepting and executing work (at-risk replicas still serve)."""
        return self.state in (ReplicaState.RUNNING, ReplicaState.AT_RISK)

    @property
    def admitting(self) -> bool:
        """Routable: serving and not scheduled for interruption."""
        return self.state == ReplicaState.RUNNING

    def has_work(self) -> bool:
        return self.engine.n_active > 0 or self.engine.n_queued > 0

    def backlog_tokens(self) -> float:
        return self.engine.backlog_tokens() if self.serving else 0.0

    # ------------------------------------------------------------- driving
    @property
    def step_interval(self) -> float:
        """Virtual seconds one engine step occupies on this instance."""
        return 1.0 / self.itype.speed

    def maybe_ready(self, now: float):
        if self.state == ReplicaState.LAUNCHING and now >= self.ready_at:
            self.state = ReplicaState.RUNNING

    def step_once(self, now: float) -> int:
        """Run ONE engine step (one ``replica_step`` event); returns tokens
        emitted.  The caller schedules the next event ``step_interval``
        later while work remains, so pacing is per-replica, not global."""
        self.maybe_ready(now)
        if not self.serving:
            return 0
        processed0 = self.engine.processed_tokens
        emitted = self.engine.step()
        self.tokens_total += emitted
        self.completed.extend(self.engine.pop_completed())
        processed = self.engine.processed_tokens - processed0
        if self.monitor is not None and processed > 0:
            # measured work-units/sec (prefill counts) over the virtual
            # time this step occupied — an idle replica schedules no step
            # events, so idle time never dilutes the measurement
            self.monitor.record(self.rid, processed, self.step_interval)
        return emitted

    def submit(self, req: Request):
        assert self.serving, self.state
        self.engine.submit(req)

    def restore(self, snaps: List[SlotSnapshot]):
        assert self.serving, self.state
        self.engine.restore_slots(snaps)

    # ------------------------------------------------------------- drain
    def drain(self) -> Tuple[List[SlotSnapshot], List[Request],
                             Tuple[float, float]]:
        """Checkpoint in-flight slots through the store and empty the engine.

        Returns (snapshots, untouched queued requests, (checkpoint_s,
        restore_s)).  The snapshots round-trip through ``InMemoryStore`` so
        the §IV checkpoint/restore stages are actually exercised and
        timed, not assumed.
        """
        self.state = ReplicaState.DRAINING
        snaps, queued = self.engine.drain()
        ckpt_s = restore_s = 0.0
        if snaps:
            import numpy as np
            name = f"drain_r{self.rid}"
            ck0 = self.store.timer.stages.get("checkpoint", 0.0)
            rs0 = self.store.timer.stages.get("restore", 0.0)
            self.store.save(name, [s.cache for s in snaps])
            caches = self.store.restore(name)
            ckpt_s = self.store.timer.stages["checkpoint"] - ck0
            restore_s = self.store.timer.stages["restore"] - rs0
            for s, c in zip(snaps, caches):
                s.cache = {k: np.asarray(v) for k, v in c.items()}
            self.store.drop(name)
        return snaps, queued, (ckpt_s, restore_s)

    def terminate(self):
        self.state = ReplicaState.TERMINATED
