"""A serving replica: a ``ServingEngine`` treated as a PE.

The cluster maps the paper's runtime objects onto serving (§III/§IV):
replicas are PEs with *measured* heterogeneous rates; in-flight requests
are migratable chares (``WorkUnit``s).  Each replica wraps an engine with

* an ``InstanceType`` (the EC2-flavor analogue: relative speed, spot
  flag, dollar cost per hour, accelerator flag),
* a feed into the shared ``RateMonitor`` — measured tokens/sec, never
  ground-truth speed, so stragglers and jitter are handled identically,
* one PUP-style verb set over in-flight work: ``pack_slots``/``unpack``
  (migration), ``preempt``/``resume`` (SLO-aware pausing), and
  ``drain_units`` (spot-drain/retirement).  Every verb that releases
  work stages the payload through the replica's ``MigrationEndpoint``
  — host-RAM (``InMemoryStore``) for plain instances, device-resident
  (``DeviceStore``) when ``InstanceType.accelerator`` is set — so the
  §IV checkpoint/restore stages are exercised and timed on the store
  class that host would really use.

Virtual-time pacing is *message-driven*: each replica schedules its own
next ``replica_step`` event on the shared ``EventLoop``.  One event runs
``decode_block`` fused engine steps (``ServingEngine.step_many``) in a
single dispatch; the next event is scheduled after the *accounted* cost
of that batch — ``decode_block / speed`` virtual seconds, plus any bulk
prefill chunk admitted in the batch at ``prefill_discount`` of a decode
step per chunk token (bulk prefill is cheaper per token than decode).
A 2x instance still runs twice as many decode steps per virtual second
and slow replicas never quantize fast ones to a global tick.  Decode
itself is real (jitted fused decode loop); only the pacing is simulated,
which keeps runs deterministic on any host.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.checkpointing import InMemoryStore
from repro.core.rates import RateMonitor
from repro.serving.engine import Request, ServingEngine
from repro.serving.workunit import WorkUnit

from repro.cluster.endpoint import (DeviceEndpoint, HostEndpoint,
                                    MigrationEndpoint)


@dataclasses.dataclass(frozen=True)
class InstanceType:
    name: str
    speed: float                 # engine steps per virtual second
    spot: bool = True
    model_id: str = "default"    # model pool this instance serves
    cost_per_hour: float = 1.0   # dollar cost per virtual hour alive
    accelerator: bool = False    # drains stage through DeviceStore


class ReplicaState(enum.Enum):
    LAUNCHING = "launching"      # requested; warming up until ready_at
    RUNNING = "running"
    AT_RISK = "at_risk"          # rebalance recommendation received
    DRAINING = "draining"        # interruption notice: no new admissions
    TERMINATED = "terminated"
    DEAD = "dead"                # hard-killed with zero notice: nothing
                                 # announced this — only a heartbeat-based
                                 # FailureDetector can discover it


class Replica:
    # class-level routability epoch: bumped by ANY replica's state or
    # quarantine transition (and by construction, i.e. fleet growth), so
    # routers can cache their admitting-replicas-by-pool index and only
    # rebuild it when membership could actually have changed.
    # Over-invalidation (e.g. RUNNING -> AT_RISK on a replica in another
    # pool) is harmless — the cache is just rebuilt.
    topology_epoch = 0

    def __init__(self, rid: int, cfg: ModelConfig, params,
                 itype: InstanceType, *, batch_size: int = 2,
                 max_seq: int = 64, temperature: float = 0.0,
                 monitor: Optional[RateMonitor] = None,
                 store: Optional[InMemoryStore] = None,
                 ready_at: float = 0.0, seed: int = 0,
                 decode_block: int = 4, prefill_mode: str = "chunked",
                 endpoint: Optional[MigrationEndpoint] = None,
                 engine_kwargs: Optional[dict] = None,
                 engine_cls=None):
        self.rid = rid
        self.itype = itype
        self.decode_block = max(int(decode_block), 1)
        # engine_kwargs passes cache tuning straight through (e.g.
        # cache_mode="paged", block_size, kv_pool_blocks) without the
        # replica layer growing one parameter per engine knob;
        # engine_cls swaps the whole engine (e.g. the token-accounting
        # SimEngine for million-request matrix runs)
        engine_cls = engine_cls or ServingEngine
        self.engine = engine_cls(cfg, params, batch_size=batch_size,
                                 max_seq=max_seq,
                                 temperature=temperature,
                                 seed=seed + rid,
                                 prefill_mode=prefill_mode,
                                 decode_block=self.decode_block,
                                 **(engine_kwargs or {}))
        self.monitor = monitor
        self.store = store or InMemoryStore()
        # migration staging: accelerator hosts keep the round trip
        # device-resident (HBM-to-HBM); plain hosts stage through the
        # shared host-RAM store
        if endpoint is not None:
            self.endpoint = endpoint
        elif itype.accelerator:
            self.endpoint = DeviceEndpoint()
        else:
            self.endpoint = HostEndpoint(self.store)
        self.ready_at = ready_at
        self.state = ReplicaState.LAUNCHING if ready_at > 0 \
            else ReplicaState.RUNNING
        self.tokens_total = 0
        # market mode: the PurchaseRecord this replica was bought under
        # (which market, which strategy) — None outside market runs
        self.purchase = None
        self.completed: List[Request] = []
        self.step_event = None       # pending replica_step on the loop
        self.beat_event = None       # pending heartbeat on the loop
        self.last_step_cost = 1.0 / itype.speed
        # chaos state: slowdown windows degrade the effective speed,
        # stragglers can be quarantined (serving but not routable), and
        # a hard kill leaves a lost-work manifest for the detector
        self.slow_factor = 1.0
        self.slow_until = 0.0
        self.quarantined = False
        self.quarantined_t = 0.0
        self.killed_t: Optional[float] = None
        self.lost: Optional[Dict[str, list]] = None

    # ------------------------------------------------------------- status
    @property
    def state(self) -> ReplicaState:
        return self._state

    @state.setter
    def state(self, value: ReplicaState):
        self._state = value
        Replica.topology_epoch += 1

    @property
    def quarantined(self) -> bool:
        return self._quarantined

    @quarantined.setter
    def quarantined(self, value: bool):
        self._quarantined = bool(value)
        Replica.topology_epoch += 1

    @property
    def model_id(self) -> str:
        return self.itype.model_id

    @property
    def serving(self) -> bool:
        """Accepting and executing work (at-risk replicas still serve)."""
        return self.state in (ReplicaState.RUNNING, ReplicaState.AT_RISK)

    @property
    def admitting(self) -> bool:
        """Routable: serving, not scheduled for interruption, and not
        quarantined as a straggler (a quarantined replica finishes its
        in-flight work but takes nothing new until its rate recovers)."""
        return self.state == ReplicaState.RUNNING and not self.quarantined

    def has_work(self) -> bool:
        return self.engine.n_active > 0 or self.engine.n_queued > 0

    def backlog_tokens(self) -> float:
        return self.engine.backlog_tokens() if self.serving else 0.0

    # ------------------------------------------------------------- driving
    @property
    def step_interval(self) -> float:
        """Virtual seconds one engine step occupies on this instance
        (inflated by an active slowdown window — the RateMonitor then
        *measures* the degradation, which is what straggler detection
        keys off)."""
        return self.slow_factor / self.itype.speed

    def apply_slowdown(self, factor: float, until: float):
        self.slow_factor = max(float(factor), 1.0)
        self.slow_until = until

    def clear_slowdown(self, now: float):
        """End a slowdown window (no-op if a later window superseded)."""
        if now >= self.slow_until:
            self.slow_factor = 1.0

    def maybe_ready(self, now: float):
        if self.state == ReplicaState.LAUNCHING and now >= self.ready_at:
            self.state = ReplicaState.RUNNING

    def step_once(self, now: float) -> int:
        """Run ONE ``replica_step`` event: ``decode_block`` fused engine
        steps in a single dispatch; returns tokens emitted.  The virtual
        cost of the batch (decode steps at ``step_interval`` each + any
        admitted bulk-prefill chunk at the engine's prefill discount) is
        stored in ``last_step_cost``; the caller schedules the next event
        that far out while work remains, so pacing is per-replica."""
        self.maybe_ready(now)
        if not self.serving:
            return 0
        stats = self.engine.step_many(self.decode_block)
        emitted = stats["emitted"]
        self.tokens_total += emitted
        self.completed.extend(self.engine.pop_completed())
        cost = (stats["steps"] + stats["chunk_tokens"]
                * self.engine.prefill_discount) * self.step_interval
        self.last_step_cost = max(cost, self.step_interval)
        if self.monitor is not None and stats["processed"] > 0:
            # measured work-units/sec (bulk-prefilled chunk tokens count
            # as full work units over their discounted cost, so measured
            # rates reflect the prefill/decode cost asymmetry) over the
            # virtual time this batch occupied — an idle replica
            # schedules no step events, so idle time never dilutes the
            # measurement
            self.monitor.record(self.rid, stats["processed"],
                                self.last_step_cost)
        return emitted

    def submit(self, req: Request):
        assert self.serving, self.state
        self.engine.submit(req)

    # ---------------------------------------------------- WorkUnit verbs
    def pack_slots(self, slots: Optional[List[int]] = None
                   ) -> Tuple[List[WorkUnit], Tuple[float, float]]:
        """Mid-stream migration: pack selected in-flight slots and
        release them, while the replica keeps serving everything else —
        the Charm++ migratable-chare move applied for *load*, not just
        spot-drain.  Payloads stage through this replica's endpoint;
        returns (units, (checkpoint_s, restore_s))."""
        units = self.engine.pack(slots)
        times = self._stage(units, f"migrate_r{self.rid}")
        return units, times

    def unpack(self, units: List[WorkUnit]):
        """Admit packed units (migration landing / preemption resume)."""
        assert self.serving, self.state
        self.engine.unpack(units)

    def preempt(self, slots: List[int]
                ) -> Tuple[List[WorkUnit], Tuple[float, float]]:
        """Pause in-flight slots (slot freed, snapshot retained): the
        SLO-aware preemption primitive.  Units come back PAUSED and stay
        parked until a ``resume`` re-admits them somewhere."""
        units = self.engine.preempt(slots)
        times = self._stage(units, f"preempt_r{self.rid}")
        return units, times

    def resume(self, units: List[WorkUnit]):
        """Re-admit paused units; the stream continues bit-identically."""
        assert self.serving, self.state
        self.engine.resume(units)

    def resize(self, *, batch_size: Optional[int] = None,
               decode_block: Optional[int] = None,
               kv_pool_blocks: Optional[int] = None,
               evict_key=None
               ) -> Tuple[List[WorkUnit], Tuple[float, float]]:
        """In-place vertical resize: change the engine's lane count /
        decode block / paged pool without draining — surviving slots
        keep decoding bit-identically.  Evicted units (a shrink past the
        live slot count) stage through the endpoint like any preemption
        and come back PAUSED; the caller parks and later resumes them.
        Bumps the topology epoch: routers cache per-pool capacity
        estimates that a resize invalidates."""
        assert self.serving, self.state
        evicted = self.engine.resize(batch_size=batch_size,
                                     decode_block=decode_block,
                                     kv_pool_blocks=kv_pool_blocks,
                                     evict_key=evict_key)
        if decode_block is not None:
            self.decode_block = max(int(decode_block), 1)
        Replica.topology_epoch += 1
        times = self._stage(evicted, f"resize_r{self.rid}") \
            if evicted else (0.0, 0.0)
        return evicted, times

    def drain_units(self) -> Tuple[List[WorkUnit], List[Request],
                                   Tuple[float, float]]:
        """Pack ALL in-flight work through the endpoint and empty the
        engine.  Returns (units, untouched queued requests,
        (checkpoint_s, restore_s))."""
        self.state = ReplicaState.DRAINING
        units, queued = self.engine.drain_units()
        times = self._stage(units, f"drain_r{self.rid}")
        return units, queued, times

    def _stage(self, units: List[WorkUnit], name: str
               ) -> Tuple[float, float]:
        for u in units:
            if u.origin is None:
                u.origin = self.rid
        return self.endpoint.roundtrip(units, name)

    # ------------------------------------------------ chaos & recovery
    def checkpoint_units(self) -> Tuple[List[WorkUnit], float]:
        """Periodic recovery checkpoint: NON-destructively snapshot
        every live slot and persist the payloads in this replica's
        endpoint store under a stable key.  The engine keeps decoding;
        returns (units, real checkpoint stage seconds)."""
        units = self.engine.checkpoint_units()
        for u in units:
            if u.origin is None:
                u.origin = self.rid
        ckpt_s = self.endpoint.put(units, f"ckpt_r{self.rid}") \
            if units else 0.0
        return units, ckpt_s

    def hard_kill(self, now: float) -> Dict[str, list]:
        """Zero-notice termination: the instance is simply gone.

        Captures the lost-work manifest (in-flight slot requests, the
        untouched queue, restore-queue requests) — the front-end's
        request log, which is what a FailureDetector recovers from.
        Tokens the engine already emitted are materialized first (the
        async poll lag is a simulation artifact, not delivery
        semantics), so the manifest records true kill-time progress and
        replay accounting is exact; slots that had in fact finished
        complete normally rather than count as lost.  The engine's
        device state is NOT consulted again after this: everything not
        checkpointed re-decodes from the prompt."""
        self.engine._poll()
        manifest = {
            "active": [r for _, r in self.engine.slot_requests()],
            "queued": list(self.engine.queued_requests()),
            "pending": [u.request for u in self.engine.pending_units()],
        }
        self.state = ReplicaState.DEAD
        self.killed_t = now
        self.lost = manifest
        return manifest

    def terminate(self):
        self.state = ReplicaState.TERMINATED
