"""ServingCluster: the message-driven loop binding the pieces together.

The serving analogue of the paper's adaptive runtime: ``ServingEngine``
replicas are PEs, in-flight requests are migratable chares, the router is
the rate-aware load balancer, and the autoscaler is the CloudManager
policy layer (pre-warm on rebalance recommendation, drain on the
2-minute notice, elastic grow/shrink on load).

The loop runs on a deterministic ``VirtualClock``: each tick delivers due
request arrivals and spot events, lets the autoscaler react, dispatches
the router, then advances every replica by ``dt`` virtual seconds (a
replica with speed ``s`` runs ``s * dt`` real jitted decode steps).  All
policy decisions consume *measured* rates from the shared
``RateMonitor`` — never the InstanceType ground truth.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.checkpointing import InMemoryStore
from repro.core.cloud import SpotEventFeed
from repro.core.rates import RateMonitor
from repro.serving.engine import Request, SlotSnapshot

from repro.cluster.autoscaler import Autoscaler
from repro.cluster.metrics import ClusterMetrics, VirtualClock
from repro.cluster.replica import InstanceType, Replica
from repro.cluster.router import RateAwareRouter, Router


class ServingCluster:
    def __init__(self, cfg: ModelConfig, params,
                 fleet: Sequence[InstanceType], *,
                 router: Optional[Router] = None,
                 batch_size: int = 2, max_seq: int = 64,
                 temperature: float = 0.0,
                 dt: float = 1.0, seed: int = 0,
                 rebalance_lead: float = 180.0,
                 notice_deadline: float = 120.0,
                 autoscaler_kw: Optional[dict] = None):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.temperature = temperature
        self.dt = dt
        self.seed = seed
        self.clock = VirtualClock()
        self.store = InMemoryStore()
        self.monitor = RateMonitor(len(fleet))
        self.router = router if router is not None else RateAwareRouter()
        self.spot = SpotEventFeed(rebalance_lead=rebalance_lead,
                                  notice_deadline=notice_deadline)
        self.metrics = ClusterMetrics()
        self.autoscaler = Autoscaler(self, **(autoscaler_kw or {}))
        self.timeline: List[Tuple[float, str]] = []
        self._rid = itertools.count()
        self.replicas: List[Replica] = []
        for itype in fleet:
            self.launch(itype, ready_at=0.0)
        self._arrivals: List[Tuple[float, int, Request]] = []
        self._arr_seq = itertools.count()
        self._parked: List[SlotSnapshot] = []

    # ------------------------------------------------------------- fleet
    def launch(self, itype: InstanceType, *, ready_at: float) -> Replica:
        rid = next(self._rid)
        if rid >= self.monitor.n_pes:
            self.monitor.resize(rid + 1)
        rep = Replica(rid, self.cfg, self.params, itype,
                      batch_size=self.batch_size, max_seq=self.max_seq,
                      temperature=self.temperature,
                      monitor=self.monitor, store=self.store,
                      ready_at=ready_at, seed=self.seed)
        self.replicas.append(rep)
        self.metrics.ensure_replica(rid, itype.name)
        return rep

    def replica_by_rid(self, rid: int) -> Optional[Replica]:
        for r in self.replicas:
            if r.rid == rid:
                return r
        return None

    def rates(self) -> Dict[int, float]:
        """Measured, normalized rates keyed by replica id."""
        r = self.monitor.rates()
        return {rep.rid: float(r[rep.rid]) for rep in self.replicas
                if rep.rid < len(r)}

    def readmit(self, snaps: List[SlotSnapshot], now: float) -> bool:
        """Place checkpointed slots on the least-loaded admitting replicas.

        Returns False (and parks the snapshots) when nobody can take them;
        they are re-admitted as soon as a replica is serving again.
        """
        if not snaps:
            return True
        survivors = [r for r in self.replicas if r.admitting]
        if not survivors:
            self._parked.extend(snaps)
            return False
        rates = self.rates()

        def key(r):
            return r.engine.backlog_tokens() / max(rates.get(r.rid, 1.0),
                                                   1e-9)
        for s in snaps:
            tgt = min(survivors, key=key)
            tgt.restore([s])
            self.log(now, f"readmit req{s.request.rid} -> r{tgt.rid}")
        return True

    def log(self, t: float, msg: str):
        self.timeline.append((t, msg))

    # ------------------------------------------------------------- input
    def submit(self, req: Request, at: float = 0.0):
        heapq.heappush(self._arrivals, (at, next(self._arr_seq), req))

    def inject_interruption(self, t: float, replica_rid: int):
        self.spot.inject_interruption(t, replica_rid)

    # ------------------------------------------------------------- loop
    def _pending_work(self) -> bool:
        return (bool(self._arrivals) or bool(self.router.queue)
                or bool(self._parked)
                or any(r.serving and r.has_work() for r in self.replicas))

    def _unpark(self, now: float):
        if not self._parked:
            return
        parked, self._parked = self._parked, []
        self.readmit(parked, now)

    def tick(self):
        """One cluster step: events -> autoscaler -> router -> replicas."""
        now = self.clock.now()
        while self._arrivals and self._arrivals[0][0] <= now:
            _, _, req = heapq.heappop(self._arrivals)
            self.router.submit(req)
            self.metrics.on_submit(req.rid, now)
        for ev in self.spot.poll(now):
            self.autoscaler.handle_spot(ev, now)
        self.autoscaler.tick(now)
        self._unpark(now)
        self.router.dispatch(self.replicas, self.rates())
        for rep in self.replicas:
            busy = rep.serving and rep.has_work()
            emitted = rep.advance(self.dt, now)
            if emitted or busy:
                self.metrics.on_tokens(rep.rid, emitted,
                                       self.dt if busy else 0.0)
            for req in rep.completed:
                self.metrics.on_done(req.rid, now + self.dt,
                                     len(req.out_tokens))
            rep.completed = []
        self.clock.advance(self.dt)

    def run(self, *, max_time: float = 100_000.0) -> Dict[str, float]:
        """Drive until idle (no arrivals, queues, slots, or spot events)."""
        while self.clock.now() < max_time:
            if (not self._pending_work()
                    and self.spot.next_event_t == float("inf")):
                break
            if (not self._pending_work()
                    and self.spot.next_event_t > self.clock.now()):
                # fast-forward idle time to the next spot event (bounded
                # by max_time so a far-future event cannot stall run())
                jump = min(self.spot.next_event_t, max_time) \
                    - self.clock.now()
                if jump > 0:
                    self.clock.advance(jump)
                continue
            self.tick()
        return self.metrics.summary(self.clock.now())
