"""ServingCluster: message-driven replicas on the shared event runtime.

The serving analogue of the paper's adaptive runtime: ``ServingEngine``
replicas are PEs, in-flight requests are migratable chares packed into
``WorkUnit``s, and every control decision — routing, mid-stream
rebalancing, SLO-aware preemption, spot handling, elastic scaling — is a
pluggable policy on the ``ControlPlane`` (``repro.cluster.control``)
operating over a read-only ``ClusterView``.  The cluster itself owns
only *mechanism*: it schedules events, executes policy orders through
the one pack/unpack verb set, and keeps the books.

There is no global lockstep tick.  The cluster registers named handlers
on one ``repro.runtime.EventLoop``:

* ``arrival``       — a request reaches the admission gate (scheduled
                      one-by-one by an open-loop ``ArrivalProcess`` or
                      ``submit``); the preemption policy may hold
                      lazily-admitted classes at the door;
* ``spot``          — one §IV lifecycle event from the bound
                      ``FaultTrace`` (shareable with ``CloudManager``);
* ``replica_step``  — ``decode_block`` fused engine steps on one replica
                      in ONE dispatch (``ServingEngine.step_many``); each
                      replica re-schedules its own next step after the
                      accounted cost of the batch (``decode_block/speed``
                      + discounted bulk-prefill chunk tokens) while it
                      has work, so a slow replica never quantizes a fast
                      one to a global ``dt``;
* ``replica_ready`` — a pre-warmed replacement comes up;
* ``control``       — periodic scaling-policy evaluation while work
                      pends;
* ``rebalance``     — periodic mid-stream migration pass: the placement
                      policy returns ``MigrationPlan``s and in-flight
                      units move through pack/unpack (the Charm++
                      migratable-chare move, exploited *proactively* for
                      load — not just at spot-drain).

After every state-changing event one ``_dispatch`` pass runs: re-admit
parked units, ask the preemption policy about held arrivals, let the
placement policy route, then let the preemption policy pause
batch-class slots whose replicas have urgent waiting work (and resume
parked units once the pressure clears).  All policy decisions consume
*measured* rates from the shared ``RateMonitor`` — never the
InstanceType ground truth.
"""

from __future__ import annotations

import itertools
from typing import (Callable, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple)

from repro.configs.base import ModelConfig
from repro.core.checkpointing import InMemoryStore
from repro.core.rates import RateMonitor
from repro.runtime import CHAOS_KINDS, EventLoop, FaultTrace, VirtualClock
from repro.serving.engine import Request
from repro.serving.workload import STANDARD, SLOClass
from repro.serving.workunit import WorkUnit

from repro.cluster.autoscaler import Autoscaler
from repro.cluster.checkpoint import CheckpointPolicy
from repro.cluster.control import (ClusterView, ControlPlane,
                                   PreemptionPolicy, ScalingPolicy)
from repro.cluster.endpoint import EndpointUnavailable
from repro.cluster.health import (FailureDetector, QuarantineOrder,
                                  StragglerPolicy)
from repro.cluster.metrics import ClusterMetrics
from repro.cluster.replica import InstanceType, Replica, ReplicaState
from repro.cluster.router import RateAwareRouter, Router

# re-exported for callers that only import the cluster module
__all__ = ["ServingCluster", "ClusterView", "ControlPlane"]


class ServingCluster:
    def __init__(self, cfg: ModelConfig, params,
                 fleet: Sequence[InstanceType], *,
                 router: Optional[Router] = None,
                 batch_size: int = 2, max_seq: int = 64,
                 temperature: float = 0.0,
                 decode_block: int = 4, prefill_mode: str = "chunked",
                 dt: float = 1.0, seed: int = 0,
                 rebalance_lead: float = 180.0,
                 notice_deadline: float = 120.0,
                 trace: Optional[FaultTrace] = None,
                 autoscaler_kw: Optional[dict] = None,
                 models: Optional[Dict[str, Tuple[ModelConfig,
                                                  object]]] = None,
                 admission: str = "fifo",
                 batch_admit_headroom: float = 64.0,
                 default_slo: SLOClass = STANDARD,
                 rebalance_interval: Optional[float] = None,
                 rebalance_ratio: float = 1.75,
                 preemption: Optional[PreemptionPolicy] = None,
                 scaling: Optional[ScalingPolicy] = None,
                 market=None, fallback=None,
                 checkpoint: Optional[CheckpointPolicy] = None,
                 health: Optional[FailureDetector] = None,
                 straggler: Optional[StragglerPolicy] = None,
                 vertical=None, qos=None,
                 contention_stage_s: float = 1.0,
                 engine=None, journal: bool = True,
                 retain_traces: bool = True,
                 timeline_cap: Optional[int] = None,
                 dispatch_coalesce: float = 0.0):
        if admission not in ("fifo", "priority"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.cfg = cfg
        self.params = params
        # multi-model fleets: model_id -> (cfg, params); instances whose
        # model_id is absent fall back to the default (cfg, params) pool
        self.models = dict(models or {})
        self.admission = admission
        self.batch_admit_headroom = batch_admit_headroom
        self.default_slo = default_slo
        self.rebalance_interval = rebalance_interval
        self.rebalance_ratio = rebalance_ratio
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.temperature = temperature
        self.decode_block = max(int(decode_block), 1)
        self.prefill_mode = prefill_mode
        self.dt = dt                  # control-plane evaluation interval
        self.seed = seed
        # million-request knobs: engine="sim" swaps every replica's
        # ServingEngine for the token-accounting SimEngine twin;
        # journal=False keeps only the loop's CRC digest; retain_traces=
        # False streams request metrics into bounded aggregates;
        # timeline_cap bounds the human-readable event log; and
        # dispatch_coalesce>0 batches all arrivals within that window
        # into ONE router pass (0.0 = the historical per-timestamp
        # coalescing, bit-identical to old behaviour)
        if engine == "sim":
            from repro.serving.simengine import SimEngine
            engine = SimEngine
        self.engine_cls = engine
        self.timeline_cap = timeline_cap
        self.dispatch_coalesce = float(dispatch_coalesce)
        self.clock = VirtualClock()
        self.loop = EventLoop(self.clock, journal=journal)
        self.store = InMemoryStore()
        self.monitor = RateMonitor(len(fleet))
        self.router = router if router is not None else RateAwareRouter()
        self.faults = trace if trace is not None else FaultTrace(
            rebalance_lead=rebalance_lead, notice_deadline=notice_deadline)
        self.metrics = ClusterMetrics(retain_traces=retain_traces)
        # spot-market mode: every launch becomes a priced purchase on
        # the exchange; the sampled interruption time (a function of the
        # market bought) drives the SAME FaultTrace transport as
        # explicit injections, and the exchange's ledger reports savings
        # through metrics.summary().  A fallback strategy (default:
        # buy on-demand) decides where replacement capacity comes from
        # when a spot notice fires.
        self.exchange = market
        if fallback is not None and market is None:
            raise ValueError("a fallback strategy needs a market "
                             "exchange (pass market=SpotExchange(...))")
        if market is not None:
            from repro.market.fallback import OnDemandFallback, make_fallback
            self.fallback = make_fallback(fallback) or OnDemandFallback()
            market.bind_metrics(self.metrics)
            self.metrics.attach_ledger(market.ledger)
        else:
            self.fallback = None
        # chaos & recovery: periodic WorkUnit checkpoints, heartbeat
        # failure detection, straggler quarantine, and a cluster-wide
        # network-contention window inflating staging/heartbeat latency
        self.checkpoint = checkpoint
        self.health = health
        # vertical elasticity: a VerticalScalingPolicy recommends
        # in-place replica resizes on the control tick; a QoSPolicy
        # grades requests into Guaranteed/Burstable/BestEffort — its
        # door gate composes with the preemption policy's (either may
        # hold) and its evict_key orders shrink evictions
        self.qos = qos
        self.contention_stage_s = contention_stage_s
        self._contention: Tuple[float, float] = (1.0, 0.0)  # factor, until
        self.timeline: List[Tuple[float, str]] = []
        self._rid = itertools.count()
        self.loop.register("arrival", self._on_arrival)
        self.loop.register("spot", self._on_spot)
        self.loop.register("replica_step", self._on_replica_step)
        self.loop.register("replica_ready", self._on_replica_ready)
        self.loop.register("control", self._on_control)
        self.loop.register("dispatch", self._on_dispatch)
        self.loop.register("rebalance", self._on_rebalance)
        self.loop.register("checkpoint", self._on_checkpoint)
        self.loop.register("heartbeat", self._on_heartbeat)
        self.loop.register("health_check", self._on_health_check)
        self.loop.register("chaos_end", self._on_chaos_end)
        self.loop.register("unit_land", self._on_unit_land)
        self.faults.bind(self.loop, kind="spot")
        self.replicas: List[Replica] = []
        self._by_rid: Dict[int, Replica] = {}
        for itype in fleet:
            self.launch(itype, ready_at=0.0)
        # the control plane: three policy seams over one read-only view.
        # The autoscaler owns the scaling policy (it also validates a
        # default_itype against the fleet's pools at construction); the
        # router IS the placement policy; preemption defaults to the
        # hold-only policy parameterized by batch_admit_headroom.
        self.view = ClusterView(self)
        self.autoscaler = Autoscaler(self, scaling=scaling,
                                     **(autoscaler_kw or {}))
        self.control = ControlPlane(
            placement=self.router,
            preemption=(preemption if preemption is not None else
                        PreemptionPolicy(batch_admit_headroom)),
            scaling=self.autoscaler.policy,
            fallback=self.fallback,
            straggler=straggler,
            vertical=vertical)
        self._control_ev = None
        self._dispatch_ev = None
        self._rebalance_ev = None
        self._checkpoint_ev = None
        self._health_ev = None
        self._parked: List[WorkUnit] = []
        self._paused: List[WorkUnit] = []  # preempted, awaiting resume
        self._held: List[Request] = []   # lazily-admitted (batch) arrivals
        self._completion_hooks: List[Callable] = []

    # ------------------------------------------------------------- fleet
    def model_for(self, model_id: str) -> Tuple[ModelConfig, object]:
        return self.models.get(model_id, (self.cfg, self.params))

    def launch(self, itype: InstanceType, *, ready_at: float,
               at: Optional[float] = None, market: str = "auto",
               strategy: str = "initial") -> Replica:
        """Bring up a replica; billing starts at ``at`` (the request
        time — a pre-warmed instance costs money while it warms).

        With a market exchange attached the launch is a *purchase*:
        ``market`` picks the pool ("auto" shops the catalog by the
        exchange's pricing mode, "on_demand" buys the no-risk option, a
        name buys that market) and the sampled interruption time is
        injected into the cluster's ``FaultTrace`` — so who gets
        interrupted, and when, follows from what was bought where.
        """
        rid = next(self._rid)
        if rid >= self.monitor.n_pes:
            self.monitor.resize(rid + 1)
        mcfg, mparams = self.model_for(itype.model_id)
        rep = Replica(rid, mcfg, mparams, itype,
                      batch_size=self.batch_size, max_seq=self.max_seq,
                      temperature=self.temperature,
                      decode_block=self.decode_block,
                      prefill_mode=self.prefill_mode,
                      monitor=self.monitor, store=self.store,
                      ready_at=ready_at, seed=self.seed,
                      engine_cls=self.engine_cls)
        self.replicas.append(rep)
        self._by_rid[rid] = rep
        t_buy = at if at is not None else ready_at
        self.metrics.on_launch(rid, itype.name, model_id=itype.model_id,
                               cost_per_hour=itype.cost_per_hour, t=t_buy)
        if self.exchange is not None:
            rep.purchase, t_int = self.exchange.purchase(
                rid, itype, t=t_buy, market=market, strategy=strategy)
            if t_int is not None:
                self.faults.inject(t_int, rid)
            self.log(t_buy,
                     f"buy r{rid} {itype.name} @ {rep.purchase.market} "
                     f"(${rep.purchase.rate_at_buy:.2f}/h, {strategy})")
        if rep.state == ReplicaState.LAUNCHING:
            self.loop.schedule(ready_at, "replica_ready", rid=rid)
        return rep

    def retire(self, rep: Replica, now: float):
        """Terminate a replica and stop its meter."""
        rep.terminate()
        self.metrics.on_terminate(rep.rid, now)

    def replica_by_rid(self, rid: int) -> Optional[Replica]:
        return self._by_rid.get(rid)

    def rates(self) -> Dict[int, float]:
        """Measured, normalized rates keyed by replica id."""
        r = self.monitor.rates()
        return {rep.rid: float(r[rep.rid]) for rep in self.replicas
                if rep.rid < len(r)}

    def readmit(self, units: List[WorkUnit], now: float) -> bool:
        """Place packed units on the least-loaded admitting replicas.

        Returns False (and parks the units) when nobody can take them;
        they are re-admitted as soon as a replica is serving again.
        """
        if not units:
            return True
        rates = self.rates()
        # queue_work fallback: drained units only land on replicas with
        # free slots — they wait parked rather than pile onto engines
        # that are already saturated
        need_free = (self.fallback is not None
                     and self.fallback.queue_until_free)
        free = {r.rid: r.engine.free_slots for r in self.replicas}

        def key(r):
            return r.engine.backlog_tokens() / max(rates.get(r.rid, 1.0),
                                                   1e-9)
        all_placed = True
        for u in units:
            # placement never crosses model pools: a unit only fits an
            # engine built from the same (cfg, max_seq)
            survivors = [r for r in self.replicas if r.admitting
                         and r.model_id == u.request.model_id]
            if need_free:
                survivors = [r for r in survivors if free.get(r.rid, 0) > 0]
            if not survivors:
                self._parked.append(u)
                all_placed = False
                continue
            tgt = min(survivors, key=key)
            if need_free:
                free[tgt.rid] -= 1
            # a contention window inflates the staging leg: the unit is
            # in transit for the extra latency and lands via an event
            # (by then the target may have died — unit_land re-places)
            delay = (self.net_factor(now) - 1.0) * self.contention_stage_s
            if delay > 0.0:
                self.loop.schedule(now + delay, "unit_land",
                                   rid=tgt.rid, unit=u)
                self.metrics.contention_delay_s += delay
                self.log(now, f"readmit req{u.rid} -> r{tgt.rid} "
                              f"(+{delay:.3g}s contention)")
                continue
            tgt.unpack([u])
            u.record_hop(tgt.rid, now, "land")
            self._kick(tgt, now)
            self.log(now, f"readmit req{u.rid} -> r{tgt.rid}")
        return all_placed

    def log(self, t: float, msg: str):
        if (self.timeline_cap is None
                or len(self.timeline) < self.timeline_cap):
            self.timeline.append((t, msg))

    # ------------------------------------------------------------- input
    def submit(self, req: Request, at: float = 0.0):
        self.loop.schedule(at, "arrival", request=req)

    def attach_arrivals(self, process: Iterable[Tuple[float, Request]]):
        """Open-loop arrivals: schedule the process's first request; each
        arrival event then schedules the next (message-driven, no heap of
        pre-materialized arrivals)."""
        it = iter(process)
        self._schedule_next_arrival(it)

    def _schedule_next_arrival(self, it: Iterator[Tuple[float, Request]]):
        for at, req in it:
            self.loop.schedule(at, "arrival", request=req, source=it)
            return

    def attach_closed_loop(self, proc):
        """Closed-loop offered load (``ClosedLoopThinkTime``): the first
        ``n_users`` arrivals are scheduled now; every completion re-arms
        the next one after the process's think time."""
        self._completion_hooks.append(proc.on_complete)
        for at, req in proc.initial():
            self.loop.schedule(at, "arrival", request=req)

    def inject_interruption(self, t: float, replica_rid: int):
        self.faults.inject(t, replica_rid)

    # ------------------------------------------------------------- handlers
    def _on_arrival(self, ev, t: float):
        req: Request = ev.payload["request"]
        if req.slo is None:
            req.slo = self.default_slo
        req.arrival_t = t
        self.metrics.on_submit(req.rid, t, slo=req.slo.name,
                               deadline_t=req.deadline_t(),
                               model_id=req.model_id)
        # priority admission: lazily-admitted classes (batch) wait at the
        # door while the preemption policy's headroom gate says the fleet
        # is loaded, so they never crowd out latency-sensitive work;
        # everyone else enters the router queue, where an SLO-aware
        # router lets interactive requests queue-jump by (priority,
        # deadline) order
        hold = (self.admission == "priority" and req.slo.admit_lazily
                and self.control.preemption.hold(req, self.view))
        # QoS gate composes: BestEffort bursts into idle capacity only
        if not hold and self.qos is not None:
            hold = self.qos.hold(req, self.view)
        if hold:
            self._held.append(req)
            self.log(t, f"hold req{req.rid} ({req.slo.name}: no headroom)")
        else:
            self.router.submit(req)
        source = ev.payload.get("source")
        if source is not None:
            self._schedule_next_arrival(source)
        # coalesce: N same-timestamp arrivals (batch submission) trigger
        # ONE router pass, after the last of them — not N full
        # greedy_refine re-placements.  dispatch_coalesce > 0 widens the
        # window: all arrivals within it share one router pass
        if self._dispatch_ev is None:
            self._dispatch_ev = self.loop.schedule(
                t + self.dispatch_coalesce, "dispatch")

    def _on_dispatch(self, ev, t: float):
        nxt = self.loop.peek()
        if nxt is not None and nxt.kind == "arrival" and nxt.t <= t:
            # a chained arrival at this same timestamp is still in flight
            # (its schedule order interleaves with ours): defer the router
            # pass behind it rather than re-placing per arrival
            self._dispatch_ev = self.loop.schedule(t, "dispatch")
            return
        self._dispatch_ev = None
        self._dispatch(t)

    def _on_spot(self, ev, t: float):
        notice = ev.payload["notice"]
        if notice.kind in CHAOS_KINDS:
            self._on_chaos(notice, t)
        else:
            self.autoscaler.handle_spot(notice, t)
        self._dispatch(t)

    # --------------------------------------------------------------- chaos
    def net_factor(self, now: float) -> float:
        """Current network-contention multiplier on staging latency and
        heartbeat delivery (1.0 outside a contention window)."""
        factor, until = self._contention
        return factor if now < until else 1.0

    def _on_chaos(self, notice, t: float):
        rep = self.replica_by_rid(notice.target) \
            if notice.target >= 0 else None
        if self.checkpoint is not None:
            # adaptive cadence input: every chaos event is a measured
            # fault the policy may tighten the checkpoint interval for
            self.checkpoint.note_fault(t)
        if notice.kind == "hard_kill":
            if rep is None or not rep.serving:
                return
            if rep.step_event is not None:
                self.loop.cancel(rep.step_event)
                rep.step_event = None
            manifest = rep.hard_kill(t)
            # requests that had finished BEFORE the kill (surfaced by the
            # manifest's flush) were delivered — they complete, not lose
            self._harvest(rep, t)
            n_lost = sum(len(v) for v in manifest.values())
            self.metrics.on_hard_kill(rep.rid, n_lost)
            self.metrics.on_terminate(rep.rid, t)  # provider stops billing
            self.log(t, f"hard_kill r{rep.rid}: {n_lost} request(s) "
                        f"in flight, zero notice")
            # deliberately NO drain and NO readmission here: nothing
            # announced this kill, so only heartbeat silence (the
            # FailureDetector) can discover and recover the lost work
        elif notice.kind == "slowdown":
            if rep is None or not rep.serving:
                return
            rep.apply_slowdown(notice.factor, t + notice.duration)
            self.metrics.slowdowns += 1
            self.loop.schedule(t + notice.duration, "chaos_end",
                               rid=rep.rid, what="slowdown")
            self.log(t, f"slowdown r{rep.rid} x{notice.factor:g} "
                        f"for {notice.duration:g}s")
        elif notice.kind == "network_contention":
            factor = max(notice.factor, 1.0)
            until = t + notice.duration
            cur_f, cur_until = self._contention
            if t < cur_until:       # overlapping windows: worst of both
                factor, until = max(factor, cur_f), max(until, cur_until)
            self._contention = (factor, until)
            self.metrics.contention_windows += 1
            self.loop.schedule(until, "chaos_end", rid=-1,
                               what="network_contention")
            self.log(t, f"network_contention x{notice.factor:g} "
                        f"for {notice.duration:g}s")
        elif notice.kind == "endpoint_failure":
            if rep is None:
                return
            rep.endpoint.arm_failures(notice.count)
            self.metrics.endpoint_faults += 1
            self.log(t, f"endpoint_failure r{rep.rid}: next "
                        f"{notice.count} staging op(s) fail")

    def _on_chaos_end(self, ev, t: float):
        if ev.payload["what"] == "slowdown":
            rep = self.replica_by_rid(ev.payload["rid"])
            if rep is not None:
                rep.clear_slowdown(t)
                self.log(t, f"slowdown r{rep.rid} ended")
        # contention clears itself through net_factor's until-timestamp
        self._dispatch(t)

    def _on_replica_ready(self, ev, t: float):
        rep = self.replica_by_rid(ev.payload["rid"])
        if rep is not None:
            rep.maybe_ready(t)
        self._dispatch(t)

    def _on_replica_step(self, ev, t: float):
        rep = self.replica_by_rid(ev.payload["rid"])
        if rep is None:
            return
        rep.step_event = None
        if not (rep.serving and rep.has_work()):
            return                     # drained/terminated since scheduling
        emitted = rep.step_once(t)
        self.metrics.on_tokens(rep.rid, emitted, rep.last_step_cost)
        self.metrics.on_occupancy(rep.rid, rep.engine.occupancy())
        if self.qos is not None:
            # slot-seconds by QoS tier: each still-occupied slot held a
            # lane for the virtual cost of the batch just run
            for _slot, r in rep.engine.slot_requests():
                self.metrics.on_qos_slot(self.qos.qos_for(r.slo).name,
                                         rep.last_step_cost)
        done = self._harvest(rep, t)
        # the batch just run occupies [t, t + last_step_cost): the next
        # step event lands after its accounted cost
        self._kick(rep, t, delay=rep.last_step_cost)
        if done:
            self._dispatch(t)   # headroom may have opened for held work

    def _harvest(self, rep: Replica, t: float) -> List[Request]:
        """Collect completed requests from a replica: record metrics and
        fire completion hooks (closed-loop arrival re-arming).  Called
        after step events AND after any pack path that can complete a
        slot mid-poll (drain, rebalance migration, preemption)."""
        done = rep.completed + rep.engine.pop_completed()
        rep.completed = []
        for req in done:
            self.metrics.on_done(req.rid, t, len(req.out_tokens))
            for hook in self._completion_hooks:
                nxt = hook(req, t)
                if nxt is not None:
                    at, nreq = nxt
                    self.loop.schedule(max(at, t), "arrival", request=nreq)
        return done

    def _on_control(self, ev, t: float):
        self._control_ev = None
        self.autoscaler.tick(t)
        self._straggler_pass(t)
        self._vertical_pass(t)
        self._dispatch(t)

    def _on_rebalance(self, ev, t: float):
        self._rebalance_ev = None
        self._rebalance_pass(t)
        self._dispatch(t)

    # --------------------------------------------------- checkpoint events
    def _on_checkpoint(self, ev, t: float):
        """Periodic recovery checkpoint: every serving replica with live
        slots non-destructively packs them into its endpoint store.
        Pure observation — no dispatch pass, nothing moves."""
        self._checkpoint_ev = None
        for rep in self.replicas:
            if not (rep.serving and rep.engine.n_active):
                continue
            try:
                n, ckpt_s = self.checkpoint.take(rep, t)
            except EndpointUnavailable:
                self.log(t, f"checkpoint r{rep.rid} failed past retry "
                            f"budget; next pass retries")
                continue
            if n:
                self.metrics.on_checkpoint(rep.rid, n, ckpt_s)
            # the checkpoint's poll can surface just-finished slots
            self._harvest(rep, t)
        self._ensure_checkpoint(t)

    # ------------------------------------------------------ health events
    def _on_heartbeat(self, ev, t: float):
        rep = self.replica_by_rid(ev.payload["rid"])
        if rep is None or self.health is None:
            return
        rep.beat_event = None
        if rep.state is ReplicaState.TERMINATED:
            self.health.forget(rep.rid)     # retired gracefully
            return
        if rep.state is ReplicaState.DEAD:
            return   # silence — exactly the signal the detector needs
        self.health.beat(rep.rid, t,
                         progress=rep.engine.processed_tokens,
                         busy=rep.engine.n_active > 0)
        if self._pending_work():
            # contention inflates delivery: the next beat lands late,
            # which is what pushes a tight suspect_after into false
            # suspicions (cleared when the late beat arrives)
            rep.beat_event = self.loop.schedule(
                t + self.health.heartbeat_interval * self.net_factor(t),
                "heartbeat", rid=rep.rid)

    def _on_health_check(self, ev, t: float):
        self._health_ev = None
        if self.health is None:
            return
        suspects, cleared, confirmed = self.health.scan(self.replicas, t)
        for rid in suspects:
            self.log(t, f"suspect r{rid} (heartbeat silent)")
        for rid in cleared:
            self.log(t, f"clear r{rid} (heartbeat resumed)")
        for rep in confirmed:
            self._recover(rep, t)
        if self._pending_work():
            self._health_ev = self.loop.schedule(
                t + self.health.check_interval, "health_check")
        self._dispatch(t)

    def _recover(self, rep: Replica, t: float):
        """Confirmed-dead recovery: restore the last checkpoint's units
        (original request objects rewound to checkpoint progress — the
        lost tail re-decodes deterministically, so final streams stay
        bit-identical), readmit everything un-checkpointed from the
        prompt, and strike the replica from the books."""
        manifest, rep.lost = rep.lost, None
        rep.state = ReplicaState.TERMINATED
        self.health.forget(rep.rid)
        if manifest is None:
            # a false confirm (e.g. extreme contention): the replica
            # was never killed — treat as an operator-forced retirement
            self.log(t, f"confirm r{rep.rid} dead but replica alive; "
                        f"retiring it")
            self.metrics.on_terminate(rep.rid, t)
            return
        lost = {r.rid: r for r in manifest["active"]}
        lost.update({r.rid: r for r in manifest["pending"]})
        recovered_units: List[WorkUnit] = []
        restore_s, replayed = 0.0, 0
        if self.checkpoint is not None:
            units, restore_s = self.checkpoint.recover(rep)
            for u in units:
                orig = lost.pop(u.request.rid, None)
                if orig is None:
                    continue   # completed or migrated after checkpoint
                ckpt_out = list(u.snapshot.request.out_tokens)
                replayed += max(0, len(orig.out_tokens) - len(ckpt_out))
                orig.out_tokens[:] = ckpt_out    # rewind to checkpoint
                orig.done = False
                u.snapshot.request = orig  # stream continues into the
                recovered_units.append(u)  # caller's own object
        # un-checkpointed in-flight work replays from the prompt; the
        # untouched queue just re-routes
        resubmit: List[Request] = []
        for orig in lost.values():
            replayed += len(orig.out_tokens)
            orig.out_tokens[:] = []
            orig.done = False
            resubmit.append(orig)
        resubmit.extend(manifest["queued"])
        self.metrics.on_recovery(
            rep.rid, recovered=len(recovered_units) + len(resubmit),
            replayed=replayed, latency=t - (rep.killed_t or t),
            restore_s=restore_s)
        self.log(t, f"recover r{rep.rid}: {len(recovered_units)} unit(s) "
                    f"from checkpoint, {len(resubmit)} from prompt, "
                    f"{replayed} token(s) replayed")
        if recovered_units:
            self.readmit(recovered_units, t)
        for req in resubmit:
            self.router.submit(req)

    # ------------------------------------------------ straggler mitigation
    def _straggler_pass(self, now: float):
        """Execute the straggler policy's quarantine/release orders:
        quarantined replicas stop admitting (they finish what they
        hold), and their urgent slots migrate to healthy peers."""
        pol = self.control.straggler
        if pol is None:
            return
        for order in pol.orders(self.view, now):
            rep = self.replica_by_rid(order.rid)
            if rep is None or not rep.serving:
                continue
            if isinstance(order, QuarantineOrder):
                rep.quarantined = True
                rep.quarantined_t = now
                self.metrics.quarantines += 1
                self.log(now, f"quarantine r{rep.rid} (straggler)")
                if order.slots:
                    units, _times = rep.pack_slots(list(order.slots))
                    self._harvest(rep, now)
                    for u in units:
                        u.packed_t = now
                        u.record_hop(rep.rid, now, "straggler")
                        self.metrics.on_migration(u.rid)
                    self.metrics.rebalance_migrations += len(units)
                    self.readmit(units, now)
            else:
                rep.quarantined = False
                self.log(now, f"release r{rep.rid} (rate recovered)")

    # ---------------------------------------------- vertical elasticity
    def _vertical_pass(self, now: float):
        """Execute the vertical policy's in-place resize orders.

        A grow just rebuilds the replica's geometry (surviving streams
        continue bit-identically through the canonical snapshot path);
        a shrink may evict slots — those units park exactly like
        preempted ones (the preemption policy's resume liveness
        fallback guarantees they re-admit), so no WorkUnit is ever lost
        to a resize.  Eviction order is the QoS policy's when one is
        attached (BestEffort first)."""
        pol = self.control.vertical
        if pol is None:
            return
        evict_key = self.qos.evict_key if self.qos is not None else None
        for order in pol.decide(self.view, now):
            rep = self.replica_by_rid(order.rid)
            if rep is None or not rep.serving:
                continue
            old_batch = rep.engine.batch
            units, (ckpt_s, restore_s) = rep.resize(
                batch_size=order.batch_size,
                decode_block=order.decode_block,
                kv_pool_blocks=order.kv_pool_blocks,
                evict_key=evict_key)
            self._harvest(rep, now)   # the pack poll may complete slots
            new_batch = rep.engine.batch
            self.metrics.on_resize(rep.rid, old_batch, new_batch,
                                   evicted=len(units),
                                   stage_s=ckpt_s + restore_s)
            for u in units:
                u.packed_t = now
                u.record_hop(rep.rid, now, "resize")
                self.log(now, f"evict req{u.rid} ({u.slo_name}) "
                              f"by resize r{rep.rid}")
            self._paused.extend(units)
            self.log(now, f"resize r{rep.rid} {old_batch}->{new_batch} "
                          f"lanes ({order.reason})")
            self._kick(rep, now)

    def _on_unit_land(self, ev, t: float):
        """Contention-delayed unit landing (the in-transit leg of a
        migration under an inflated-staging-latency window)."""
        unit: WorkUnit = ev.payload["unit"]
        rep = self.replica_by_rid(ev.payload["rid"])
        if rep is None or not rep.serving:
            self.readmit([unit], t)   # target vanished in transit
            return
        rep.unpack([unit])
        unit.record_hop(rep.rid, t, "land")
        self._kick(rep, t)
        self._dispatch(t)

    # ------------------------------------------------------------- driving
    def _kick(self, rep: Replica, now: float,
              delay: Optional[float] = None):
        """Schedule ``rep``'s next engine step unless one is pending.

        ``delay`` is the virtual cost of the batch that just ran (from
        ``step_once``); a first kick after idle uses one step interval
        as admission latency."""
        if rep.step_event is not None:
            return
        if not (rep.serving and rep.has_work()):
            return
        if delay is None:
            delay = rep.step_interval
        rep.step_event = self.loop.schedule(
            now + delay, "replica_step", rid=rep.rid)

    def _dispatch(self, now: float):
        """One control-plane pass; runs after any state-changing event.

        Mechanism only — every decision is delegated: parked units
        re-admit, the preemption policy rules on held arrivals, the
        placement policy routes, then the preemption policy may pause
        saturated batch work / resume parked units.
        """
        self._unpark(now)
        self._admit_held(now)
        for rep in self.control.placement.place(self.view, now):
            self._kick(rep, now)
        self._preemption_pass(now)
        self._ensure_control(now)
        self._ensure_rebalance(now)
        self._ensure_checkpoint(now)
        self._ensure_health(now)

    def _ensure_control(self, now: float):
        if self._control_ev is None and self._pending_work():
            self._control_ev = self.loop.schedule(now + self.dt, "control")

    def _ensure_checkpoint(self, now: float):
        """Keep the recovery-checkpoint cadence alive while any serving
        replica holds in-flight slots (an idle fleet has nothing worth
        checkpointing, and the loop must be able to drain)."""
        if (self.checkpoint is not None
                and self._checkpoint_ev is None
                and any(r.serving and r.engine.n_active
                        for r in self.replicas)):
            self._checkpoint_ev = self.loop.schedule(
                now + self.checkpoint.next_interval(self.replicas, now),
                "checkpoint")

    def _ensure_health(self, now: float):
        """Arm heartbeat chains for live replicas that lack one and the
        recurring health-check scan.  Both are gated on pending work so
        the event loop drains once the fleet goes (and stays) idle."""
        if self.health is None or not self._pending_work():
            return
        for rep in self.replicas:
            if (rep.state in (ReplicaState.RUNNING, ReplicaState.AT_RISK)
                    and rep.beat_event is None):
                # arming the chain records a birth beat: the replica is
                # demonstrably alive right now, and without it a kill
                # landing before the first scheduled heartbeat would
                # leave the replica unmonitored — and unrecovered —
                # forever
                self.health.beat(rep.rid, now)
                rep.beat_event = self.loop.schedule(
                    now + self.health.heartbeat_interval
                    * self.net_factor(now),
                    "heartbeat", rid=rep.rid)
        if self._health_ev is None:
            self._health_ev = self.loop.schedule(
                now + self.health.check_interval, "health_check")

    def _unrecovered(self) -> bool:
        """True while a hard-killed replica still holds a lost-work
        manifest nobody has recovered."""
        return any(r.state is ReplicaState.DEAD and r.lost is not None
                   for r in self.replicas)

    def _ensure_rebalance(self, now: float):
        """Keep the recurring mid-stream-migration pass alive while any
        replica holds in-flight slots (queue-only backlog is the
        router's job, not the rebalancer's)."""
        if (self.rebalance_interval is not None
                and self._rebalance_ev is None
                and any(r.serving and r.engine.n_active
                        for r in self.replicas)):
            self._rebalance_ev = self.loop.schedule(
                now + self.rebalance_interval, "rebalance")

    def _pending_work(self) -> bool:
        # an unrecovered hard kill counts as pending work only when a
        # FailureDetector is attached: with recovery ON the health loop
        # keeps ticking until the manifest is recovered; with recovery
        # OFF the loop drains and the lost requests stay demonstrably
        # lost (the A/B the chaos benchmark measures)
        return (bool(self.router.queue) or bool(self._parked)
                or bool(self._held) or bool(self._paused)
                or any(r.serving and r.has_work() for r in self.replicas)
                or (self.health is not None and self._unrecovered()))

    def _unpark(self, now: float):
        if not self._parked:
            return
        parked, self._parked = self._parked, []
        self.readmit(parked, now)

    # --------------------------------------------------------- admission
    def _admit_held(self, now: float):
        if not self._held:
            return
        admit, self._held = self.control.preemption.admit_held(
            self._held, self.view)
        if self.qos is not None and admit:
            # both gates must open: a request the preemption policy
            # would admit stays held while its QoS tier has no idle
            # capacity to burst into
            admit, still = self.qos.admit_held(admit, self.view)
            self._held.extend(still)
        for req in admit:
            self.router.submit(req)
            self.log(now, f"admit req{req.rid} (headroom opened)")

    # -------------------------------------------------------- preemption
    def _preemption_pass(self, now: float):
        """Execute the preemption policy's pause/resume orders through
        the WorkUnit verbs.  Paused units park on the cluster (their
        snapshot retained, slot freed); resumes re-admit them with
        restore-queue priority, so the stream continues bit-identically
        ahead of fresh arrivals."""
        pol = self.control.preemption
        for order in pol.preempt(self.view, now):
            rep = self.replica_by_rid(order.rid)
            if rep is None or not rep.serving:
                continue
            units, (ckpt_s, restore_s) = rep.preempt(order.slots)
            self._harvest(rep, now)   # the pack poll may complete slots
            if units:                 # one staging round trip per order
                self.metrics.preempt_stage_s += ckpt_s + restore_s
            for u in units:
                u.packed_t = now
                u.record_hop(rep.rid, now, "preempt")
                self.metrics.on_preempt(u.rid)
                self.log(now, f"preempt req{u.rid} ({u.slo_name}) "
                              f"r{rep.rid} slot freed")
            self._paused.extend(units)
            self._kick(rep, now)
        if not self._paused:
            return
        for order in pol.resume(self.view, now):
            rep = self.replica_by_rid(order.rid)
            if rep is None or not rep.admitting:
                continue
            units = [u for u in order.units if u in self._paused]
            if not units:
                continue
            for u in units:
                self._paused.remove(u)
                u.record_hop(rep.rid, now, "resume")
                self.metrics.on_resume(u.rid)
                self.log(now, f"resume req{u.rid} -> r{rep.rid}")
            rep.resume(units)
            self._kick(rep, now)

    # --------------------------------------------------------- rebalance
    def _rebalance_pass(self, now: float):
        """Execute the placement policy's mid-stream migration plans:
        pack the chosen slot, stage it through the source's endpoint,
        unpack on the destination."""
        plans = self.control.placement.rebalance(
            self.view, now, ratio=self.rebalance_ratio)
        for plan in plans:
            src = self.replica_by_rid(plan.src)
            dst = self.replica_by_rid(plan.dst)
            if src is None or dst is None or not dst.admitting:
                continue
            units, _times = src.pack_slots([plan.slot])
            self._harvest(src, now)   # the pack poll may complete slots
            if not units:
                continue
            for u in units:
                u.packed_t = now
                u.record_hop(src.rid, now, "rebalance")
                self.metrics.on_migration(u.rid)
            self.metrics.rebalance_migrations += len(units)
            dst.unpack(units)
            for u in units:
                u.record_hop(dst.rid, now, "land")
            self.log(now, f"rebalance req{units[0].rid} "
                          f"r{src.rid} -> r{dst.rid}")
            self._kick(dst, now)

    def run(self, *, max_time: float = 100_000.0,
            max_events: int = 10_000_000) -> Dict[str, float]:
        """Dispatch events until the loop drains (or ``max_time``).

        Exhausting ``max_events`` with live work still due raises
        (loop-level): a truncated sim must not report partial metrics
        as if complete."""
        self.loop.run(until=max_time, max_events=max_events)
        # endpoint retry accounting lives on the endpoints themselves;
        # fold it into the fleet summary once the run is over
        self.metrics.endpoint_retries = sum(
            rep.endpoint.retries for rep in self.replicas)
        self.metrics.retry_backoff_s = sum(
            rep.endpoint.backoff_s for rep in self.replicas)
        return self.metrics.summary(self.clock.now())
