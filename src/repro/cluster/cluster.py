"""ServingCluster: message-driven replicas on the shared event runtime.

The serving analogue of the paper's adaptive runtime: ``ServingEngine``
replicas are PEs, in-flight requests are migratable chares, the router is
the rate-aware load balancer, and the autoscaler is the CloudManager
policy layer (pre-warm on rebalance recommendation, drain on the
2-minute notice, elastic grow/shrink on load).

There is no global lockstep tick.  The cluster registers named handlers
on one ``repro.runtime.EventLoop``:

* ``arrival``       — a request reaches the router (scheduled one-by-one
                      by an open-loop ``ArrivalProcess`` or ``submit``);
* ``spot``          — one §IV lifecycle event from the bound
                      ``FaultTrace`` (shareable with ``CloudManager``);
* ``replica_step``  — ``decode_block`` fused engine steps on one replica
                      in ONE dispatch (``ServingEngine.step_many``); each
                      replica re-schedules its own next step after the
                      accounted cost of the batch (``decode_block/speed``
                      + discounted bulk-prefill chunk tokens) while it
                      has work, so a slow replica never quantizes a fast
                      one to a global ``dt``;
* ``replica_ready`` — a pre-warmed replacement comes up;
* ``control``       — periodic autoscaler evaluation while work pends.

All policy decisions consume *measured* rates from the shared
``RateMonitor`` — never the InstanceType ground truth.
"""

from __future__ import annotations

import itertools
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.checkpointing import InMemoryStore
from repro.core.rates import RateMonitor
from repro.runtime import EventLoop, FaultTrace, VirtualClock
from repro.serving.engine import Request, SlotSnapshot

from repro.cluster.autoscaler import Autoscaler
from repro.cluster.metrics import ClusterMetrics
from repro.cluster.replica import InstanceType, Replica, ReplicaState
from repro.cluster.router import RateAwareRouter, Router


class ServingCluster:
    def __init__(self, cfg: ModelConfig, params,
                 fleet: Sequence[InstanceType], *,
                 router: Optional[Router] = None,
                 batch_size: int = 2, max_seq: int = 64,
                 temperature: float = 0.0,
                 decode_block: int = 4, prefill_mode: str = "chunked",
                 dt: float = 1.0, seed: int = 0,
                 rebalance_lead: float = 180.0,
                 notice_deadline: float = 120.0,
                 trace: Optional[FaultTrace] = None,
                 autoscaler_kw: Optional[dict] = None):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.temperature = temperature
        self.decode_block = max(int(decode_block), 1)
        self.prefill_mode = prefill_mode
        self.dt = dt                  # control-plane evaluation interval
        self.seed = seed
        self.clock = VirtualClock()
        self.loop = EventLoop(self.clock)
        self.store = InMemoryStore()
        self.monitor = RateMonitor(len(fleet))
        self.router = router if router is not None else RateAwareRouter()
        self.faults = trace if trace is not None else FaultTrace(
            rebalance_lead=rebalance_lead, notice_deadline=notice_deadline)
        self.metrics = ClusterMetrics()
        self.autoscaler = Autoscaler(self, **(autoscaler_kw or {}))
        self.timeline: List[Tuple[float, str]] = []
        self._rid = itertools.count()
        self.loop.register("arrival", self._on_arrival)
        self.loop.register("spot", self._on_spot)
        self.loop.register("replica_step", self._on_replica_step)
        self.loop.register("replica_ready", self._on_replica_ready)
        self.loop.register("control", self._on_control)
        self.loop.register("dispatch", self._on_dispatch)
        self.faults.bind(self.loop, kind="spot")
        self.replicas: List[Replica] = []
        for itype in fleet:
            self.launch(itype, ready_at=0.0)
        self._control_ev = None
        self._dispatch_ev = None
        self._parked: List[SlotSnapshot] = []

    # ------------------------------------------------------------- fleet
    def launch(self, itype: InstanceType, *, ready_at: float) -> Replica:
        rid = next(self._rid)
        if rid >= self.monitor.n_pes:
            self.monitor.resize(rid + 1)
        rep = Replica(rid, self.cfg, self.params, itype,
                      batch_size=self.batch_size, max_seq=self.max_seq,
                      temperature=self.temperature,
                      decode_block=self.decode_block,
                      prefill_mode=self.prefill_mode,
                      monitor=self.monitor, store=self.store,
                      ready_at=ready_at, seed=self.seed)
        self.replicas.append(rep)
        self.metrics.ensure_replica(rid, itype.name)
        if rep.state == ReplicaState.LAUNCHING:
            self.loop.schedule(ready_at, "replica_ready", rid=rid)
        return rep

    def replica_by_rid(self, rid: int) -> Optional[Replica]:
        for r in self.replicas:
            if r.rid == rid:
                return r
        return None

    def rates(self) -> Dict[int, float]:
        """Measured, normalized rates keyed by replica id."""
        r = self.monitor.rates()
        return {rep.rid: float(r[rep.rid]) for rep in self.replicas
                if rep.rid < len(r)}

    def readmit(self, snaps: List[SlotSnapshot], now: float) -> bool:
        """Place checkpointed slots on the least-loaded admitting replicas.

        Returns False (and parks the snapshots) when nobody can take them;
        they are re-admitted as soon as a replica is serving again.
        """
        if not snaps:
            return True
        survivors = [r for r in self.replicas if r.admitting]
        if not survivors:
            self._parked.extend(snaps)
            return False
        rates = self.rates()

        def key(r):
            return r.engine.backlog_tokens() / max(rates.get(r.rid, 1.0),
                                                   1e-9)
        for s in snaps:
            tgt = min(survivors, key=key)
            tgt.restore([s])
            self._kick(tgt, now)
            self.log(now, f"readmit req{s.request.rid} -> r{tgt.rid}")
        return True

    def log(self, t: float, msg: str):
        self.timeline.append((t, msg))

    # ------------------------------------------------------------- input
    def submit(self, req: Request, at: float = 0.0):
        self.loop.schedule(at, "arrival", request=req)

    def attach_arrivals(self, process: Iterable[Tuple[float, Request]]):
        """Open-loop arrivals: schedule the process's first request; each
        arrival event then schedules the next (message-driven, no heap of
        pre-materialized arrivals)."""
        it = iter(process)
        self._schedule_next_arrival(it)

    def _schedule_next_arrival(self, it: Iterator[Tuple[float, Request]]):
        for at, req in it:
            self.loop.schedule(at, "arrival", request=req, source=it)
            return

    def inject_interruption(self, t: float, replica_rid: int):
        self.faults.inject(t, replica_rid)

    # ------------------------------------------------------------- handlers
    def _on_arrival(self, ev, t: float):
        req: Request = ev.payload["request"]
        self.router.submit(req)
        self.metrics.on_submit(req.rid, t)
        source = ev.payload.get("source")
        if source is not None:
            self._schedule_next_arrival(source)
        # coalesce: N same-timestamp arrivals (batch submission) trigger
        # ONE router pass, after the last of them — not N full
        # greedy_refine re-placements
        if self._dispatch_ev is None:
            self._dispatch_ev = self.loop.schedule(t, "dispatch")

    def _on_dispatch(self, ev, t: float):
        nxt = self.loop.peek()
        if nxt is not None and nxt.kind == "arrival" and nxt.t <= t:
            # a chained arrival at this same timestamp is still in flight
            # (its schedule order interleaves with ours): defer the router
            # pass behind it rather than re-placing per arrival
            self._dispatch_ev = self.loop.schedule(t, "dispatch")
            return
        self._dispatch_ev = None
        self._dispatch(t)

    def _on_spot(self, ev, t: float):
        self.autoscaler.handle_spot(ev.payload["notice"], t)
        self._dispatch(t)

    def _on_replica_ready(self, ev, t: float):
        rep = self.replica_by_rid(ev.payload["rid"])
        if rep is not None:
            rep.maybe_ready(t)
        self._dispatch(t)

    def _on_replica_step(self, ev, t: float):
        rep = self.replica_by_rid(ev.payload["rid"])
        if rep is None:
            return
        rep.step_event = None
        if not (rep.serving and rep.has_work()):
            return                     # drained/terminated since scheduling
        emitted = rep.step_once(t)
        self.metrics.on_tokens(rep.rid, emitted, rep.last_step_cost)
        for req in rep.completed:
            self.metrics.on_done(req.rid, t, len(req.out_tokens))
        rep.completed = []
        # the batch just run occupies [t, t + last_step_cost): the next
        # step event lands after its accounted (per-chunk) cost
        self._kick(rep, t, delay=rep.last_step_cost)

    def _on_control(self, ev, t: float):
        self._control_ev = None
        self.autoscaler.tick(t)
        self._dispatch(t)

    # ------------------------------------------------------------- driving
    def _kick(self, rep: Replica, now: float,
              delay: Optional[float] = None):
        """Schedule ``rep``'s next engine step unless one is pending.

        ``delay`` is the virtual cost of the batch that just ran (from
        ``step_once``); a first kick after idle uses one step interval
        as admission latency."""
        if rep.step_event is not None:
            return
        if not (rep.serving and rep.has_work()):
            return
        if delay is None:
            delay = rep.step_interval
        rep.step_event = self.loop.schedule(
            now + delay, "replica_step", rid=rep.rid)

    def _dispatch(self, now: float):
        """Router pass + wake-ups; runs after any state-changing event."""
        self._unpark(now)
        for rep in self.router.dispatch(self.replicas, self.rates()):
            self._kick(rep, now)
        self._ensure_control(now)

    def _ensure_control(self, now: float):
        if self._control_ev is None and self._pending_work():
            self._control_ev = self.loop.schedule(now + self.dt, "control")

    def _pending_work(self) -> bool:
        return (bool(self.router.queue) or bool(self._parked)
                or any(r.serving and r.has_work() for r in self.replicas))

    def _unpark(self, now: float):
        if not self._parked:
            return
        parked, self._parked = self._parked, []
        self.readmit(parked, now)

    def run(self, *, max_time: float = 100_000.0) -> Dict[str, float]:
        """Dispatch events until the loop drains (or ``max_time``)."""
        self.loop.run(until=max_time)
        return self.metrics.summary(self.clock.now())
