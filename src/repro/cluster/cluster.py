"""ServingCluster: message-driven replicas on the shared event runtime.

The serving analogue of the paper's adaptive runtime: ``ServingEngine``
replicas are PEs, in-flight requests are migratable chares, the router is
the rate-aware load balancer, and the autoscaler is the CloudManager
policy layer (pre-warm on rebalance recommendation, drain on the
2-minute notice, elastic grow/shrink on load).

There is no global lockstep tick.  The cluster registers named handlers
on one ``repro.runtime.EventLoop``:

* ``arrival``       — a request reaches the router (scheduled one-by-one
                      by an open-loop ``ArrivalProcess`` or ``submit``);
* ``spot``          — one §IV lifecycle event from the bound
                      ``FaultTrace`` (shareable with ``CloudManager``);
* ``replica_step``  — ``decode_block`` fused engine steps on one replica
                      in ONE dispatch (``ServingEngine.step_many``); each
                      replica re-schedules its own next step after the
                      accounted cost of the batch (``decode_block/speed``
                      + discounted bulk-prefill chunk tokens) while it
                      has work, so a slow replica never quantizes a fast
                      one to a global ``dt``;
* ``replica_ready`` — a pre-warmed replacement comes up;
* ``control``       — periodic autoscaler evaluation while work pends;
* ``rebalance``     — periodic mid-stream migration pass: in-flight
                      slots move from overloaded/slow replicas to
                      underloaded ones through the engine's
                      ``snapshot_slots``/``restore_slots`` path (the
                      Charm++ migratable-chare move, exploited
                      *proactively* for load — not just at spot-drain).

The SLO layer rides these events: requests carry an ``SLOClass``
(deadline + priority); under ``admission="priority"`` latency-sensitive
classes queue-jump while ``admit_lazily`` (batch) classes are held at
arrival until the fleet has backlog headroom; the ``DeadlineAwareRouter``
places by predicted deadline misses.  Replicas belong to per-model pools
(``InstanceType.model_id``) and routing/migration never crosses pools.

All policy decisions consume *measured* rates from the shared
``RateMonitor`` — never the InstanceType ground truth.
"""

from __future__ import annotations

import itertools
from typing import (Callable, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple)

from repro.configs.base import ModelConfig
from repro.core.checkpointing import InMemoryStore
from repro.core.rates import RateMonitor
from repro.runtime import EventLoop, FaultTrace, VirtualClock
from repro.serving.engine import Request, SlotSnapshot, request_cost
from repro.serving.workload import STANDARD, SLOClass

from repro.cluster.autoscaler import Autoscaler
from repro.cluster.metrics import ClusterMetrics
from repro.cluster.replica import InstanceType, Replica, ReplicaState
from repro.cluster.router import RateAwareRouter, Router


class ServingCluster:
    def __init__(self, cfg: ModelConfig, params,
                 fleet: Sequence[InstanceType], *,
                 router: Optional[Router] = None,
                 batch_size: int = 2, max_seq: int = 64,
                 temperature: float = 0.0,
                 decode_block: int = 4, prefill_mode: str = "chunked",
                 dt: float = 1.0, seed: int = 0,
                 rebalance_lead: float = 180.0,
                 notice_deadline: float = 120.0,
                 trace: Optional[FaultTrace] = None,
                 autoscaler_kw: Optional[dict] = None,
                 models: Optional[Dict[str, Tuple[ModelConfig,
                                                  object]]] = None,
                 admission: str = "fifo",
                 batch_admit_headroom: float = 64.0,
                 default_slo: SLOClass = STANDARD,
                 rebalance_interval: Optional[float] = None,
                 rebalance_ratio: float = 1.75):
        if admission not in ("fifo", "priority"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.cfg = cfg
        self.params = params
        # multi-model fleets: model_id -> (cfg, params); instances whose
        # model_id is absent fall back to the default (cfg, params) pool
        self.models = dict(models or {})
        self.admission = admission
        self.batch_admit_headroom = batch_admit_headroom
        self.default_slo = default_slo
        self.rebalance_interval = rebalance_interval
        self.rebalance_ratio = rebalance_ratio
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.temperature = temperature
        self.decode_block = max(int(decode_block), 1)
        self.prefill_mode = prefill_mode
        self.dt = dt                  # control-plane evaluation interval
        self.seed = seed
        self.clock = VirtualClock()
        self.loop = EventLoop(self.clock)
        self.store = InMemoryStore()
        self.monitor = RateMonitor(len(fleet))
        self.router = router if router is not None else RateAwareRouter()
        self.faults = trace if trace is not None else FaultTrace(
            rebalance_lead=rebalance_lead, notice_deadline=notice_deadline)
        self.metrics = ClusterMetrics()
        self.autoscaler = Autoscaler(self, **(autoscaler_kw or {}))
        self.timeline: List[Tuple[float, str]] = []
        self._rid = itertools.count()
        self.loop.register("arrival", self._on_arrival)
        self.loop.register("spot", self._on_spot)
        self.loop.register("replica_step", self._on_replica_step)
        self.loop.register("replica_ready", self._on_replica_ready)
        self.loop.register("control", self._on_control)
        self.loop.register("dispatch", self._on_dispatch)
        self.loop.register("rebalance", self._on_rebalance)
        self.faults.bind(self.loop, kind="spot")
        self.replicas: List[Replica] = []
        for itype in fleet:
            self.launch(itype, ready_at=0.0)
        self._control_ev = None
        self._dispatch_ev = None
        self._rebalance_ev = None
        self._parked: List[SlotSnapshot] = []
        self._held: List[Request] = []   # lazily-admitted (batch) arrivals
        self._completion_hooks: List[Callable] = []

    # ------------------------------------------------------------- fleet
    def model_for(self, model_id: str) -> Tuple[ModelConfig, object]:
        return self.models.get(model_id, (self.cfg, self.params))

    def launch(self, itype: InstanceType, *, ready_at: float) -> Replica:
        rid = next(self._rid)
        if rid >= self.monitor.n_pes:
            self.monitor.resize(rid + 1)
        mcfg, mparams = self.model_for(itype.model_id)
        rep = Replica(rid, mcfg, mparams, itype,
                      batch_size=self.batch_size, max_seq=self.max_seq,
                      temperature=self.temperature,
                      decode_block=self.decode_block,
                      prefill_mode=self.prefill_mode,
                      monitor=self.monitor, store=self.store,
                      ready_at=ready_at, seed=self.seed)
        self.replicas.append(rep)
        self.metrics.ensure_replica(rid, itype.name)
        if rep.state == ReplicaState.LAUNCHING:
            self.loop.schedule(ready_at, "replica_ready", rid=rid)
        return rep

    def replica_by_rid(self, rid: int) -> Optional[Replica]:
        for r in self.replicas:
            if r.rid == rid:
                return r
        return None

    def rates(self) -> Dict[int, float]:
        """Measured, normalized rates keyed by replica id."""
        r = self.monitor.rates()
        return {rep.rid: float(r[rep.rid]) for rep in self.replicas
                if rep.rid < len(r)}

    def readmit(self, snaps: List[SlotSnapshot], now: float) -> bool:
        """Place checkpointed slots on the least-loaded admitting replicas.

        Returns False (and parks the snapshots) when nobody can take them;
        they are re-admitted as soon as a replica is serving again.
        """
        if not snaps:
            return True
        rates = self.rates()

        def key(r):
            return r.engine.backlog_tokens() / max(rates.get(r.rid, 1.0),
                                                   1e-9)
        all_placed = True
        for s in snaps:
            # placement never crosses model pools: a snapshot only fits
            # an engine built from the same (cfg, max_seq)
            survivors = [r for r in self.replicas if r.admitting
                         and r.model_id == s.request.model_id]
            if not survivors:
                self._parked.append(s)
                all_placed = False
                continue
            tgt = min(survivors, key=key)
            tgt.restore([s])
            self._kick(tgt, now)
            self.log(now, f"readmit req{s.request.rid} -> r{tgt.rid}")
        return all_placed

    def log(self, t: float, msg: str):
        self.timeline.append((t, msg))

    # ------------------------------------------------------------- input
    def submit(self, req: Request, at: float = 0.0):
        self.loop.schedule(at, "arrival", request=req)

    def attach_arrivals(self, process: Iterable[Tuple[float, Request]]):
        """Open-loop arrivals: schedule the process's first request; each
        arrival event then schedules the next (message-driven, no heap of
        pre-materialized arrivals)."""
        it = iter(process)
        self._schedule_next_arrival(it)

    def _schedule_next_arrival(self, it: Iterator[Tuple[float, Request]]):
        for at, req in it:
            self.loop.schedule(at, "arrival", request=req, source=it)
            return

    def attach_closed_loop(self, proc):
        """Closed-loop offered load (``ClosedLoopThinkTime``): the first
        ``n_users`` arrivals are scheduled now; every completion re-arms
        the next one after the process's think time."""
        self._completion_hooks.append(proc.on_complete)
        for at, req in proc.initial():
            self.loop.schedule(at, "arrival", request=req)

    def inject_interruption(self, t: float, replica_rid: int):
        self.faults.inject(t, replica_rid)

    # ------------------------------------------------------------- handlers
    def _on_arrival(self, ev, t: float):
        req: Request = ev.payload["request"]
        if req.slo is None:
            req.slo = self.default_slo
        req.arrival_t = t
        self.metrics.on_submit(req.rid, t, slo=req.slo.name,
                               deadline_t=req.deadline_t(),
                               model_id=req.model_id)
        # priority admission: lazily-admitted classes (batch) wait at the
        # door until the fleet has backlog headroom, so they never crowd
        # out latency-sensitive work; everyone else enters the router
        # queue, where an SLO-aware router lets interactive requests
        # queue-jump by (priority, deadline) order
        if (self.admission == "priority" and req.slo.admit_lazily
                and not self._admit_headroom(req.model_id)):
            self._held.append(req)
            self.log(t, f"hold req{req.rid} ({req.slo.name}: no headroom)")
        else:
            self.router.submit(req)
        source = ev.payload.get("source")
        if source is not None:
            self._schedule_next_arrival(source)
        # coalesce: N same-timestamp arrivals (batch submission) trigger
        # ONE router pass, after the last of them — not N full
        # greedy_refine re-placements
        if self._dispatch_ev is None:
            self._dispatch_ev = self.loop.schedule(t, "dispatch")

    def _on_dispatch(self, ev, t: float):
        nxt = self.loop.peek()
        if nxt is not None and nxt.kind == "arrival" and nxt.t <= t:
            # a chained arrival at this same timestamp is still in flight
            # (its schedule order interleaves with ours): defer the router
            # pass behind it rather than re-placing per arrival
            self._dispatch_ev = self.loop.schedule(t, "dispatch")
            return
        self._dispatch_ev = None
        self._dispatch(t)

    def _on_spot(self, ev, t: float):
        self.autoscaler.handle_spot(ev.payload["notice"], t)
        self._dispatch(t)

    def _on_replica_ready(self, ev, t: float):
        rep = self.replica_by_rid(ev.payload["rid"])
        if rep is not None:
            rep.maybe_ready(t)
        self._dispatch(t)

    def _on_replica_step(self, ev, t: float):
        rep = self.replica_by_rid(ev.payload["rid"])
        if rep is None:
            return
        rep.step_event = None
        if not (rep.serving and rep.has_work()):
            return                     # drained/terminated since scheduling
        emitted = rep.step_once(t)
        self.metrics.on_tokens(rep.rid, emitted, rep.last_step_cost)
        done = self._harvest(rep, t)
        # the batch just run occupies [t, t + last_step_cost): the next
        # step event lands after its accounted (per-chunk) cost
        self._kick(rep, t, delay=rep.last_step_cost)
        if done:
            self._dispatch(t)   # headroom may have opened for held work

    def _harvest(self, rep: Replica, t: float) -> List[Request]:
        """Collect completed requests from a replica: record metrics and
        fire completion hooks (closed-loop arrival re-arming).  Called
        after step events AND after any snapshot path that can complete a
        slot mid-poll (drain, rebalance migration)."""
        done = rep.completed + rep.engine.pop_completed()
        rep.completed = []
        for req in done:
            self.metrics.on_done(req.rid, t, len(req.out_tokens))
            for hook in self._completion_hooks:
                nxt = hook(req, t)
                if nxt is not None:
                    at, nreq = nxt
                    self.loop.schedule(max(at, t), "arrival", request=nreq)
        return done

    def _on_control(self, ev, t: float):
        self._control_ev = None
        self.autoscaler.tick(t)
        self._dispatch(t)

    def _on_rebalance(self, ev, t: float):
        self._rebalance_ev = None
        self._rebalance_pass(t)
        self._dispatch(t)

    # ------------------------------------------------------------- driving
    def _kick(self, rep: Replica, now: float,
              delay: Optional[float] = None):
        """Schedule ``rep``'s next engine step unless one is pending.

        ``delay`` is the virtual cost of the batch that just ran (from
        ``step_once``); a first kick after idle uses one step interval
        as admission latency."""
        if rep.step_event is not None:
            return
        if not (rep.serving and rep.has_work()):
            return
        if delay is None:
            delay = rep.step_interval
        rep.step_event = self.loop.schedule(
            now + delay, "replica_step", rid=rep.rid)

    def _dispatch(self, now: float):
        """Router pass + wake-ups; runs after any state-changing event."""
        self._unpark(now)
        self._admit_held(now)
        for rep in self.router.dispatch(self.replicas, self.rates(), now):
            self._kick(rep, now)
        self._ensure_control(now)
        self._ensure_rebalance(now)

    def _ensure_control(self, now: float):
        if self._control_ev is None and self._pending_work():
            self._control_ev = self.loop.schedule(now + self.dt, "control")

    def _ensure_rebalance(self, now: float):
        """Keep the recurring mid-stream-migration pass alive while any
        replica holds in-flight slots (queue-only backlog is the
        router's job, not the rebalancer's)."""
        if (self.rebalance_interval is not None
                and self._rebalance_ev is None
                and any(r.serving and r.engine.n_active
                        for r in self.replicas)):
            self._rebalance_ev = self.loop.schedule(
                now + self.rebalance_interval, "rebalance")

    def _pending_work(self) -> bool:
        return (bool(self.router.queue) or bool(self._parked)
                or bool(self._held)
                or any(r.serving and r.has_work() for r in self.replicas))

    def _unpark(self, now: float):
        if not self._parked:
            return
        parked, self._parked = self._parked, []
        self.readmit(parked, now)

    # --------------------------------------------------------- admission
    def _admit_headroom(self, model_id: str) -> bool:
        """True when the model pool's backlog per admitting replica is
        under ``batch_admit_headroom`` discounted token-units — the gate
        for lazily-admitted (batch) classes."""
        pool = [r for r in self.replicas
                if r.admitting and r.model_id == model_id]
        if not pool:
            return False
        d = getattr(self.router, "prefill_discount", 1.0)
        backlog = sum(r.engine.backlog_tokens() for r in pool)
        backlog += sum(request_cost(q, d) for q in self.router.queue
                       if q.model_id == model_id)
        return backlog / len(pool) < self.batch_admit_headroom

    def _admit_held(self, now: float):
        if not self._held:
            return
        still: List[Request] = []
        for req in self._held:
            if self._admit_headroom(req.model_id):
                self.router.submit(req)
                self.log(now, f"admit req{req.rid} (headroom opened)")
            else:
                still.append(req)
        self._held = still

    # --------------------------------------------------------- rebalance
    def _rebalance_pass(self, now: float):
        """Proactive mid-stream migration (one move per model pool per
        pass): when the slowest-draining replica's ETA exceeds the
        fastest's by ``rebalance_ratio``, its costliest in-flight slot is
        checkpointed and restored on the least-loaded replica with a free
        slot — measured rates and prefill-discounted backlog only, and
        only when the move strictly improves the pool's worst ETA."""
        rates = self.rates()

        def eta(r: Replica) -> float:
            return (r.engine.backlog_tokens()
                    / max(rates.get(r.rid, 1e-9), 1e-9))

        for model_id in sorted({r.model_id for r in self.replicas}):
            pool = [r for r in self.replicas
                    if r.admitting and r.model_id == model_id]
            if len(pool) < 2:
                continue
            src = max(pool, key=eta)
            dsts = [r for r in pool
                    if r is not src and r.engine.free_slots > 0]
            if not dsts:
                continue
            dst = min(dsts, key=eta)
            if eta(src) <= self.rebalance_ratio * eta(dst) + 1e-9:
                continue
            costs = src.engine.slot_costs()
            if not costs:
                continue          # backlog is queue-only: router's job
            slot, cost = max(costs, key=lambda sc: sc[1])
            r_src = max(rates.get(src.rid, 1e-9), 1e-9)
            r_dst = max(rates.get(dst.rid, 1e-9), 1e-9)
            new_worst = max(
                (src.engine.backlog_tokens() - cost) / r_src,
                (dst.engine.backlog_tokens() + cost) / r_dst)
            if new_worst >= eta(src):
                continue          # move would not improve the worst ETA
            snaps, _times = src.checkpoint_slots([slot])
            self._harvest(src, now)   # snapshot poll may complete slots
            if not snaps:
                continue
            for s in snaps:
                self.metrics.on_migration(s.request.rid)
            self.metrics.rebalance_migrations += len(snaps)
            dst.restore(snaps)
            self.log(now, f"rebalance req{snaps[0].request.rid} "
                          f"r{src.rid} -> r{dst.rid}")
            self._kick(dst, now)

    def run(self, *, max_time: float = 100_000.0) -> Dict[str, float]:
        """Dispatch events until the loop drains (or ``max_time``)."""
        self.loop.run(until=max_time)
        return self.metrics.summary(self.clock.now())
