"""Admission queue + dispatch over replicated ServingEngines.

Two policies, the serving analogue of the paper's Fig 3 A/B:

* ``RoundRobinRouter`` — rate-oblivious baseline: queued requests are
  pinned to replicas cyclically, regardless of measured speed.
* ``RateAwareRouter``  — the paper's GreedyRefine applied to serving:
  requests are chares with load = remaining token-units, replicas are PEs
  with *measured* tokens/sec rates (from the shared ``RateMonitor``), and
  in-flight work is non-migratable ``base`` load.  Every dispatch round
  reclaims not-yet-admitted requests, places new arrivals on the
  earliest-finishing replica, then runs ``greedy_refine`` so placements
  self-correct as measured rates drift — with the minimum number of
  queue migrations (§III-B).
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.loadbalance import greedy_refine
from repro.serving.engine import (DEFAULT_PREFILL_DISCOUNT, Request,
                                  request_cost)

from repro.cluster.replica import Replica


class Router:
    """Base: global admission queue; subclasses decide placement."""

    name = "base"

    def __init__(self):
        self.queue: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def requeue(self, reqs: Sequence[Request]):
        """Drained (checkpoint-free) requests come back to the front."""
        self.queue = list(reqs) + self.queue

    def dispatch(self, replicas: List[Replica],
                 rates: Dict[int, float]) -> List[Replica]:
        """Place queued requests; returns the replicas that received work
        (so an event-driven cluster wakes exactly those)."""
        raise NotImplementedError


class RoundRobinRouter(Router):
    """Rate-oblivious baseline: cycle admitting replicas."""

    name = "round_robin"

    def __init__(self):
        super().__init__()
        self._next = 0

    def dispatch(self, replicas: List[Replica],
                 rates: Dict[int, float]) -> List[Replica]:
        targets = [r for r in replicas if r.admitting]
        if not targets or not self.queue:
            return []
        touched = []
        while self.queue:
            req = self.queue.pop(0)
            rep = targets[self._next % len(targets)]
            self._next += 1
            rep.submit(req)
            if rep not in touched:
                touched.append(rep)
        return touched


class RateAwareRouter(Router):
    """GreedyRefine dispatch on measured rates (paper §III applied here)."""

    name = "rate_aware"

    def __init__(self, tolerance: float = 1.05,
                 prefill_discount: float = DEFAULT_PREFILL_DISCOUNT):
        super().__init__()
        self.tolerance = tolerance
        # request load weights prompt tokens at the bulk-prefill discount
        # (matching ServingEngine.backlog_tokens), so prompt-heavy
        # requests don't overstate the load they will place on a replica
        self.prefill_discount = prefill_discount

    def dispatch(self, replicas: List[Replica],
                 rates: Dict[int, float]) -> List[Replica]:
        targets = [r for r in replicas if r.admitting]
        if not targets:
            return []
        # reclaim queued-but-unadmitted work so placement can be revised
        pending: List[Request] = []
        prev_home: Dict[int, int] = {}
        for pe, rep in enumerate(targets):
            for req in rep.engine.reclaim_queue():
                prev_home[req.rid] = pe
                pending.append(req)
        pending.extend(self.queue)
        self.queue = []
        if not pending:
            return []

        rate = np.asarray([max(rates.get(r.rid, 1.0), 1e-9)
                           for r in targets])
        # in-flight slots are pinned: they contribute fixed base load
        base = np.asarray([float(r.engine.backlog_tokens())
                           for r in targets])
        loads = np.asarray([request_cost(q, self.prefill_discount)
                            for q in pending])

        # earliest-finish initial placement for requests with no home yet
        scaled = base / rate
        current = np.zeros(len(pending), dtype=np.int64)
        for i, req in enumerate(pending):
            if req.rid in prev_home:
                current[i] = prev_home[req.rid]
                scaled[current[i]] += loads[i] / rate[current[i]]
            else:
                pe = int(np.argmin(scaled + loads[i] / rate))
                current[i] = pe
                scaled[pe] += loads[i] / rate[pe]

        res = greedy_refine(loads, len(targets), rates=rate,
                            current=current, base=base,
                            tolerance=self.tolerance)
        touched = []
        for i, req in enumerate(pending):
            rep = targets[int(res.assignment[i])]
            rep.submit(req)
            if rep not in touched:
                touched.append(rep)
        return touched


ROUTERS = {
    "round_robin": RoundRobinRouter,
    "rate_aware": RateAwareRouter,
}
