"""Admission queue + dispatch over replicated ServingEngines.

Three policies, the serving analogue of the paper's Fig 3 A/B plus the
elastic-job-scheduler deadline layer (Bhosale & Kale) on top:

* ``RoundRobinRouter`` — rate-oblivious baseline: queued requests are
  pinned to replicas cyclically, regardless of measured speed.
* ``RateAwareRouter``  — the paper's GreedyRefine applied to serving:
  requests are chares with load = remaining token-units, replicas are PEs
  with *measured* tokens/sec rates (from the shared ``RateMonitor``), and
  in-flight work is non-migratable ``base`` load.  Every dispatch round
  reclaims not-yet-admitted requests, places new arrivals on the
  earliest-finishing replica, then runs ``greedy_refine`` so placements
  self-correct as measured rates drift — with the minimum number of
  queue migrations (§III-B).  Admission order is FIFO.
* ``DeadlineAwareRouter`` — extends GreedyRefine to minimize predicted
  deadline misses: pending requests are ordered by (priority, deadline),
  the GreedyRefine assignment is simulated per replica at slot
  granularity (EDF admission as slots free; free and freshly preempted
  slots count as available now) and a repair pass relocates
  predicted-missing requests to whichever replica reduces total
  predicted misses.

Every router is **model-aware**: replicas declare a ``model_id`` (their
``InstanceType``'s pool) and a request is only ever placed on a replica
serving its model; requests whose pool currently has no admitting
replica stay queued until one appears.

Built for million-request runs:

* the admission queue is a ``collections.deque`` — ``submit`` appends
  and ``requeue`` extends the front in O(len(reqs)), instead of the old
  O(queue) wholesale list rebuild per drain (O(queue²) once thousands
  of lazily-admitted batch requests are held);
* the admitting-replicas-by-pool index is cached on the fleet's
  ``topology_epoch`` (bumped by any replica state/quarantine change)
  instead of being rebuilt on every dispatch;
* ``place_cap`` (opt-in) bounds one placement round: when the queue is
  longer than the cap, the head of the queue is placed FIFO onto free
  slots in O(cap x replicas) and the rest stays queued — the full
  GreedyRefine pass over an unbounded backlog is what made toy-scale
  routers melt at 10^6 requests.
"""

from __future__ import annotations

import heapq
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.loadbalance import greedy_refine
from repro.serving.engine import (DEFAULT_PREFILL_DISCOUNT, Request,
                                  request_cost)

from repro.cluster.control import ClusterView, PlacementPolicy
from repro.cluster.replica import Replica


def _pools(replicas: Sequence[Replica]) -> Dict[str, List[Replica]]:
    """Admitting replicas grouped by model pool (stable replica order)."""
    pools: Dict[str, List[Replica]] = {}
    for rep in replicas:
        if rep.admitting:
            pools.setdefault(rep.model_id, []).append(rep)
    return pools


class Router(PlacementPolicy):
    """Base: global admission queue; subclasses decide placement.

    Routers ARE the cluster's ``PlacementPolicy``: ``place`` adapts the
    historical ``dispatch(replicas, rates, now)`` signature to the
    control-plane ``ClusterView``, and the mid-stream ``rebalance``
    decision comes from the policy base class.
    """

    name = "base"

    def __init__(self):
        self.queue: Deque[Request] = deque()
        self._pool_cache: Optional[Tuple[Tuple[int, int],
                                         Dict[str, List[Replica]]]] = None
        # incremental per-pool load aggregates over the queue: the
        # control plane's headroom/backlog checks read these in O(1)
        # instead of scanning the (possibly million-deep) queue per
        # control tick.  Maintained at every queue mutation site below;
        # tiny float drift from add/remove cycles is clamped at read.
        self._q_tokens: Dict[str, float] = {}
        self._q_cost: Dict[str, float] = {}

    def _q_add(self, req: Request):
        m = req.model_id
        self._q_tokens[m] = self._q_tokens.get(m, 0.0) + req.total_tokens
        self._q_cost[m] = self._q_cost.get(m, 0.0) + request_cost(
            req, getattr(self, "prefill_discount", 1.0))

    def _q_rem(self, req: Request):
        m = req.model_id
        self._q_tokens[m] = self._q_tokens.get(m, 0.0) - req.total_tokens
        self._q_cost[m] = self._q_cost.get(m, 0.0) - request_cost(
            req, getattr(self, "prefill_discount", 1.0))

    def queued_tokens(self, model_id: Optional[str] = None) -> float:
        """Token-units queued for ``model_id`` (all pools when None)."""
        if model_id is None:
            return max(0.0, sum(self._q_tokens.values()))
        return max(0.0, self._q_tokens.get(model_id, 0.0))

    def queued_cost(self, model_id: Optional[str] = None) -> float:
        """Discounted router load queued for ``model_id``."""
        if model_id is None:
            return max(0.0, sum(self._q_cost.values()))
        return max(0.0, self._q_cost.get(model_id, 0.0))

    def submit(self, req: Request):
        self._q_add(req)
        self.queue.append(req)

    def requeue(self, reqs: Sequence[Request]):
        """Drained (checkpoint-free) requests come back to the front,
        keeping their relative order (O(len(reqs)), not O(queue))."""
        reqs = list(reqs)
        for req in reqs:
            self._q_add(req)
        self.queue.extendleft(reversed(reqs))

    def pools(self, replicas: Sequence[Replica]) -> Dict[str, List[Replica]]:
        """Admitting replicas by pool, cached on the fleet's topology
        epoch: any replica state/quarantine flip (and every launch)
        bumps ``Replica.topology_epoch``, so the index is rebuilt only
        when membership could actually have changed — not per dispatch.
        """
        key = (Replica.topology_epoch, len(replicas))
        cached = self._pool_cache
        if cached is not None and cached[0] == key:
            return cached[1]
        pools = _pools(replicas)
        self._pool_cache = (key, pools)
        return pools

    def place(self, view: ClusterView, now: float) -> List[Replica]:
        return self.dispatch(list(view.replicas), view.rates(), now)

    def dispatch(self, replicas: List[Replica], rates: Dict[int, float],
                 now: float = 0.0) -> List[Replica]:
        """Place queued requests; returns the replicas that received work
        (so an event-driven cluster wakes exactly those)."""
        raise NotImplementedError


class RoundRobinRouter(Router):
    """Rate-oblivious baseline: cycle admitting replicas per model pool."""

    name = "round_robin"

    def __init__(self):
        super().__init__()
        self._next: Dict[str, int] = {}

    def dispatch(self, replicas: List[Replica], rates: Dict[int, float],
                 now: float = 0.0) -> List[Replica]:
        pools = self.pools(replicas)
        if not pools or not self.queue:
            return []
        touched: List[Replica] = []
        leftover: Deque[Request] = deque()
        while self.queue:
            req = self.queue.popleft()
            self._q_rem(req)
            targets = pools.get(req.model_id)
            if not targets:
                self._q_add(req)
                leftover.append(req)     # no admitting replica for pool
                continue
            n = self._next.get(req.model_id, 0)
            rep = targets[n % len(targets)]
            self._next[req.model_id] = n + 1
            rep.submit(req)
            if rep not in touched:
                touched.append(rep)
        self.queue = leftover
        return touched


class RateAwareRouter(Router):
    """GreedyRefine dispatch on measured rates (paper §III applied here)."""

    name = "rate_aware"

    def __init__(self, tolerance: float = 1.05,
                 prefill_discount: float = DEFAULT_PREFILL_DISCOUNT,
                 place_cap: Optional[int] = None):
        super().__init__()
        self.tolerance = tolerance
        # request load weights prompt tokens at the bulk-prefill discount
        # (matching ServingEngine.backlog_tokens), so prompt-heavy
        # requests don't overstate the load they will place on a replica
        self.prefill_discount = prefill_discount
        # opt-in backlog bound: over the cap, one placement round places
        # only the queue head onto free slots (O(cap x replicas)) and
        # skips the reclaim + GreedyRefine pass; None = exact behaviour
        self.place_cap = place_cap

    # ------------------------------------------------------------ hooks
    def _order_pending(self, pending: List[Request]) -> List[Request]:
        """Admission order within one placement round (FIFO here)."""
        return pending

    def _refine_assignment(self, assignment: np.ndarray,
                           targets: List[Replica], pending: List[Request],
                           loads: np.ndarray, rate: np.ndarray,
                           base: np.ndarray, now: float) -> np.ndarray:
        """Post-GreedyRefine repair hook (load-only router: identity)."""
        return assignment

    # --------------------------------------------------------- dispatch
    def dispatch(self, replicas: List[Replica], rates: Dict[int, float],
                 now: float = 0.0) -> List[Replica]:
        pools = self.pools(replicas)
        if not pools:
            return []
        if self.place_cap is not None:
            # bounded mode: never reclaim + re-place the whole backlog —
            # the queue head fills free slots and the rest STAYS in the
            # router deque (engines hold only running work), so one pass
            # is O(cap x replicas) regardless of backlog depth
            return self._fast_place(pools)
        # reclaim queued-but-unadmitted work so placement can be revised
        pending_by_model: Dict[str, List[Request]] = {}
        prev_home: Dict[int, int] = {}
        for model_id, targets in pools.items():
            for pe, rep in enumerate(targets):
                for req in rep.engine.reclaim_queue():
                    prev_home[req.rid] = pe
                    pending_by_model.setdefault(model_id, []).append(req)
        leftover: Deque[Request] = deque()
        while self.queue:
            req = self.queue.popleft()
            if req.model_id in pools:
                self._q_rem(req)
                pending_by_model.setdefault(req.model_id, []).append(req)
            else:
                leftover.append(req)
        self.queue = leftover

        touched: List[Replica] = []
        for model_id, targets in pools.items():
            pending = pending_by_model.get(model_id)
            if not pending:
                continue
            for rep in self._place_pool(targets, pending, rates,
                                        prev_home, now):
                if rep not in touched:
                    touched.append(rep)
        return touched

    def _fast_place(self, pools: Dict[str, List[Replica]]) -> List[Replica]:
        """Backlog fast path: admit the FIFO head of the queue onto free
        slots only, leaving the rest queued (the deque holds the backlog
        in O(1) memory per request instead of engine queues growing
        unboundedly).  Each completion-driven dispatch pass admits the
        next head, so admission order is identical to the exact path's
        FIFO order — only the placement refinement is skipped."""
        touched: List[Replica] = []
        leftover: Deque[Request] = deque()
        free: Dict[int, int] = {}
        scanned = 0
        while self.queue and scanned < self.place_cap:
            scanned += 1
            req = self.queue.popleft()
            targets = pools.get(req.model_id)
            if not targets:
                leftover.append(req)
                continue
            best = None
            for rep in targets:
                f = free.get(rep.rid)
                if f is None:
                    # headroom = free lanes minus work already waiting
                    # to admit into them (placed this timestamp but not
                    # yet stepped): keeps engine queues ~empty so their
                    # backlog scans stay O(active slots)
                    f = free[rep.rid] = (rep.engine.free_slots
                                         - rep.engine.n_queued)
                if f > 0 and (best is None or f > free[best.rid]):
                    best = rep
            if best is None:
                leftover.append(req)   # pool full: wait for completions
                continue
            free[best.rid] -= 1
            self._q_rem(req)
            best.submit(req)
            if best not in touched:
                touched.append(best)
        self.queue.extendleft(reversed(leftover))
        return touched

    def _place_pool(self, targets: List[Replica], pending: List[Request],
                    rates: Dict[int, float], prev_home: Dict[int, int],
                    now: float) -> List[Replica]:
        pending = self._order_pending(pending)
        rate = np.asarray([max(rates.get(r.rid, 1.0), 1e-9)
                           for r in targets])
        # in-flight slots are pinned: they contribute fixed base load
        base = np.asarray([float(r.engine.backlog_tokens())
                           for r in targets])
        loads = np.asarray([request_cost(q, self.prefill_discount)
                            for q in pending])

        # earliest-finish initial placement for requests with no home yet
        scaled = base / rate
        current = np.zeros(len(pending), dtype=np.int64)
        for i, req in enumerate(pending):
            if req.rid in prev_home:
                current[i] = prev_home[req.rid]
                scaled[current[i]] += loads[i] / rate[current[i]]
            else:
                pe = int(np.argmin(scaled + loads[i] / rate))
                current[i] = pe
                scaled[pe] += loads[i] / rate[pe]

        res = greedy_refine(loads, len(targets), rates=rate,
                            current=current, base=base,
                            tolerance=self.tolerance)
        assignment = self._refine_assignment(
            np.asarray(res.assignment), targets, pending, loads, rate,
            base, now)
        touched = []
        for i, req in enumerate(pending):
            rep = targets[int(assignment[i])]
            rep.submit(req)
            if rep not in touched:
                touched.append(rep)
        return touched


def _slo_key(req: Request) -> Tuple[int, float, int]:
    prio = req.slo.priority if req.slo is not None else 1
    return (prio, req.deadline_t(), req.rid)


class DeadlineAwareRouter(RateAwareRouter):
    """GreedyRefine extended to minimize predicted deadline misses.

    On top of the rate-aware placement: pending requests are admitted in
    (priority, deadline) order — interactive work queue-jumps batch work
    — and the GreedyRefine assignment is repaired by relocating requests
    predicted to miss their deadline (slot-level EDF simulation per
    replica at the measured rate: free — including freshly preempted or
    drained — slots admit immediately, active slots free at their
    predicted completion) onto the replica that minimizes total
    predicted misses.
    """

    name = "slo_aware"

    def __init__(self, tolerance: float = 1.05,
                 prefill_discount: float = DEFAULT_PREFILL_DISCOUNT,
                 max_repairs: int = 32,
                 place_cap: Optional[int] = None):
        super().__init__(tolerance, prefill_discount, place_cap=place_cap)
        self.max_repairs = max_repairs

    def _order_pending(self, pending: List[Request]) -> List[Request]:
        return sorted(pending, key=_slo_key)

    def _slot_free_times(self, targets: List[Replica],
                         rate: np.ndarray) -> List[List[float]]:
        """Per-replica slot-availability offsets for the EDF simulation.

        Every currently-free slot is available *immediately* — including
        slots just freed by a preemption or a drain — and every active
        slot frees at its predicted completion.  Restore-queue units
        (admitted ahead of fresh work) claim the earliest slots first.
        The old serial model charged the whole base backlog before any
        queued request could start, so a replica with one long slot and
        three freed ones looked as busy as a fully loaded engine.
        """
        out = []
        for pe, rep in enumerate(targets):
            free = [0.0] * rep.engine.free_slots
            free += [c / rate[pe] for _, c in rep.engine.slot_costs()]
            heapq.heapify(free)
            for c in rep.engine.restore_costs(self.prefill_discount):
                start = heapq.heappop(free) if free else 0.0
                heapq.heappush(free, start + c / rate[pe])
            out.append(free or [0.0])
        return out

    def _predicted_misses(self, assignment: np.ndarray, loads: np.ndarray,
                          rate: np.ndarray,
                          slot_free: List[List[float]],
                          deadlines: np.ndarray,
                          now: float) -> Tuple[int, List[int]]:
        """Simulate slot-level EDF service per replica; count predicted
        misses.  ``pending`` is already in (priority, deadline) order,
        so each replica admits its assigned requests in EDF order as
        slots free up — queued work runs in parallel across slots, not
        serially behind the entire base load."""
        misses, missed = 0, []
        for pe in range(len(rate)):
            free = list(slot_free[pe])
            heapq.heapify(free)
            for i in np.flatnonzero(assignment == pe):
                start = heapq.heappop(free)
                done = start + loads[i] / rate[pe]
                heapq.heappush(free, done)
                if now + done > deadlines[i]:
                    misses += 1
                    missed.append(int(i))
        return misses, missed

    def _refine_assignment(self, assignment: np.ndarray,
                           targets: List[Replica], pending: List[Request],
                           loads: np.ndarray, rate: np.ndarray,
                           base: np.ndarray, now: float) -> np.ndarray:
        deadlines = np.asarray([q.deadline_t() for q in pending])
        if not np.isfinite(deadlines).any() or len(targets) < 2:
            return assignment
        slot_free = self._slot_free_times(targets, rate)
        best, missed = self._predicted_misses(
            assignment, loads, rate, slot_free, deadlines, now)
        repairs = 0
        while missed and best > 0 and repairs < self.max_repairs:
            improved = False
            # most urgent predicted miss first
            for i in sorted(missed, key=lambda j: deadlines[j]):
                home = int(assignment[i])
                for pe in range(len(targets)):
                    if pe == home:
                        continue
                    trial = assignment.copy()
                    trial[i] = pe
                    m, mi = self._predicted_misses(
                        trial, loads, rate, slot_free, deadlines, now)
                    if m < best:
                        assignment, best, missed = trial, m, mi
                        improved = True
                        break
                if improved:
                    break
            repairs += 1
            if not improved:
                break
        return assignment


ROUTERS = {
    "round_robin": RoundRobinRouter,
    "rate_aware": RateAwareRouter,
    "slo_aware": DeadlineAwareRouter,
}
