"""Cluster observability: request traces, fleet summaries.

Everything is keyed off *virtual* time so cluster runs are deterministic
and reproducible on any host; only checkpoint/restore stage timings (from
the ``InMemoryStore`` timers) are real wall-clock measurements.  The
clock itself is the shared ``repro.runtime.VirtualClock`` (re-exported
here for back-compat).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.runtime import VirtualClock  # noqa: F401  (re-export)


class _LatencyHist:
    """Log-spaced latency histogram: O(1)-memory approximate percentiles
    for streaming (``retain_traces=False``) runs.  320 geometric buckets
    over [1e-4, 1e6] virtual seconds give ~7.5% relative resolution —
    plenty for a p99 floor — without holding one latency per request."""

    _EDGES = np.geomspace(1e-4, 1e6, 321)

    def __init__(self):
        self.counts = np.zeros(self._EDGES.size + 1, dtype=np.int64)
        self.n = 0
        self.max_seen = 0.0

    def add(self, lat: float):
        self.counts[int(np.searchsorted(self._EDGES, lat))] += 1
        self.n += 1
        if lat > self.max_seen:
            self.max_seen = lat

    def percentile(self, q: float) -> float:
        if self.n == 0:
            return 0.0
        target = q / 100.0 * self.n
        cum = 0
        for idx in range(self.counts.size):
            cum += int(self.counts[idx])
            if cum >= target:
                if idx == 0:
                    return float(min(self._EDGES[0], self.max_seen))
                if idx >= self._EDGES.size:
                    return self.max_seen
                # geometric bucket midpoint
                return float(np.sqrt(self._EDGES[idx - 1]
                                     * self._EDGES[idx]))
        return self.max_seen


@dataclasses.dataclass
class _ClassAgg:
    """Streaming per-(SLO, pool) completion aggregate."""
    completed: int = 0
    met: int = 0                 # completed at or before the deadline
    finite_misses: int = 0       # completed late against a finite deadline
    tokens: int = 0


@dataclasses.dataclass
class RequestTrace:
    rid: int
    arrival_t: float
    done_t: Optional[float] = None
    tokens: int = 0
    migrations: int = 0          # times this request was migrated
    preemptions: int = 0         # times this request was paused mid-stream
    slo: str = "standard"        # SLO class name
    deadline_t: float = float("inf")   # absolute completion deadline
    model_id: str = "default"

    @property
    def latency(self) -> Optional[float]:
        return None if self.done_t is None else self.done_t - self.arrival_t

    @property
    def met_deadline(self) -> bool:
        """Completed at or before the deadline (incomplete = missed)."""
        return self.done_t is not None and self.done_t <= self.deadline_t


@dataclasses.dataclass
class ReplicaStats:
    rid: int
    itype: str
    tokens: int = 0
    busy_s: float = 0.0          # virtual seconds with work in the engine
    model_id: str = "default"    # pool this replica serves
    cost_per_hour: float = 0.0   # dollars per virtual hour alive
    launched_t: float = 0.0      # billing start (launch request time)
    terminated_t: Optional[float] = None   # billing stop (None = alive)
    # engine cache occupancy (high-water): concurrent occupied slots,
    # and — paged-cache engines only — blocks in use vs pool size
    peak_slots: int = 0
    peak_blocks: int = 0
    pool_blocks: int = 0

    def dollar_cost(self, horizon: float) -> float:
        """Dollars accrued by ``horizon`` (virtual seconds) — a live
        replica bills through the horizon, a retired one to its end."""
        end = self.terminated_t if self.terminated_t is not None \
            else horizon
        return max(end - self.launched_t, 0.0) / 3600.0 \
            * self.cost_per_hour


@dataclasses.dataclass
class DrainRecord:
    t: float
    replica: int
    slots_migrated: int
    queued_requeued: int
    checkpoint_s: float          # real (measured) store stage seconds
    restore_s: float = 0.0
    endpoint: str = "host"       # MigrationEndpoint kind (host | device)


class ClusterMetrics:
    """Fleet observability.

    Two retention modes:

    * ``retain_traces=True`` (default): one ``RequestTrace`` per request
      for the whole run — exact percentiles, windowed attainment.
    * ``retain_traces=False`` (million-request runs): only *live*
      requests hold a trace; completions fold into per-(SLO, pool)
      counters and log-spaced latency histograms, so memory is bounded
      by the number of in-flight requests, not the request count.
      Percentiles become histogram-approximate (~7.5% relative) and
      ``class_attainment``'s ``since``/``until`` window only scopes the
      still-live population (completed requests aggregate globally).
    """

    def __init__(self, retain_traces: bool = True):
        self.retain_traces = retain_traces
        self.traces: Dict[int, RequestTrace] = {}
        # streaming aggregates (only fed when retain_traces=False)
        self._classes: Set[str] = set()
        self._submitted = 0
        self._done_count = 0
        self._done_tokens = 0
        self._max_done_t = 0.0
        self._hist = _LatencyHist()
        self._slo_hist: Dict[str, _LatencyHist] = {}
        self._agg: Dict[Tuple[str, str], _ClassAgg] = {}
        self.replicas: Dict[int, ReplicaStats] = {}
        self.drains: List[DrainRecord] = []
        self.rebalance_migrations = 0    # mid-stream (load) slot moves
        self.preemptions = 0             # slots paused by the preemptor
        self.resumes = 0                 # paused units re-admitted
        self.preempt_stage_s = 0.0       # real store seconds spent pausing
        self.ledger = None               # SavingsLedger (market mode only)
        # chaos & recovery (zero-filled in summary() so fault-free
        # scenarios emit the same stable schema)
        self.hard_kills = 0              # zero-notice terminations
        self.requests_lost = 0           # in-flight on a dead replica,
                                         # not (yet) recovered
        self.requests_recovered = 0      # restored from checkpoint or
                                         # readmitted from the prompt
        self.recoveries = 0              # confirmed-dead recovery passes
        self.replayed_tokens = 0         # decoded tokens lost + redone
        self.recovery_latency_s = 0.0    # kill -> confirmed, summed
        self.recovery_restore_s = 0.0    # real store restore seconds
        self.checkpoints = 0             # checkpoint passes that staged
        self.checkpointed_units = 0      # slots captured across passes
        self.checkpoint_stage_s = 0.0    # real store checkpoint seconds
        self.slowdowns = 0               # slowdown windows applied
        self.contention_windows = 0      # network-contention windows
        self.contention_delay_s = 0.0    # virtual staging delay added
        self.endpoint_faults = 0         # endpoint_failure faults armed
        self.endpoint_retries = 0        # staging ops that retried
        self.retry_backoff_s = 0.0       # accounted retry backoff
        self.quarantines = 0             # straggler quarantine orders
        # vertical elasticity & QoS (zero-filled in summary() like the
        # chaos block, so horizontal-only runs keep the same schema)
        self.vertical_grows = 0          # in-place lane-count increases
        self.vertical_shrinks = 0        # in-place lane-count decreases
        self.vertical_evictions = 0      # slots displaced by a shrink
        self.resize_stage_s = 0.0        # real pack/stage seconds spent
        self.qos_slot_seconds: Dict[str, float] = {}   # tier -> slot-s

    def attach_ledger(self, ledger):
        """Market mode: the exchange's ``SavingsLedger`` reports savings
        vs all-on-demand (with by-market / by-strategy breakdowns)
        through ``summary()``, and terminations stamp purchase ends."""
        self.ledger = ledger

    # ------------------------------------------------------------ request
    def on_submit(self, rid: int, now: float, *, slo: str = "standard",
                  deadline_t: float = float("inf"),
                  model_id: str = "default"):
        self._submitted += 1
        self._classes.add(slo)
        self.traces[rid] = RequestTrace(rid, now, slo=slo,
                                        deadline_t=deadline_t,
                                        model_id=model_id)

    def on_done(self, rid: int, now: float, tokens: int):
        tr = self.traces[rid]
        tr.done_t = now
        tr.tokens = tokens
        if self.retain_traces:
            return
        # streaming: fold the completion into the aggregates and drop
        # the trace — memory stays bounded by in-flight requests
        self._done_count += 1
        self._done_tokens += tokens
        if now > self._max_done_t:
            self._max_done_t = now
        lat = now - tr.arrival_t
        self._hist.add(lat)
        self._slo_hist.setdefault(tr.slo, _LatencyHist()).add(lat)
        agg = self._agg.setdefault((tr.slo, tr.model_id), _ClassAgg())
        agg.completed += 1
        agg.tokens += tokens
        if tr.met_deadline:
            agg.met += 1
        elif np.isfinite(tr.deadline_t):
            agg.finite_misses += 1
        del self.traces[rid]

    def on_migration(self, rid: int):
        if rid in self.traces:
            self.traces[rid].migrations += 1

    def on_preempt(self, rid: int):
        self.preemptions += 1
        if rid in self.traces:
            self.traces[rid].preemptions += 1

    def on_resume(self, rid: int):
        self.resumes += 1

    # ---------------------------------------------------- chaos/recovery
    def on_hard_kill(self, rid: int, n_lost: int):
        self.hard_kills += 1
        self.requests_lost += n_lost

    def on_recovery(self, rid: int, *, recovered: int, replayed: int,
                    latency: float, restore_s: float):
        self.recoveries += 1
        self.requests_recovered += recovered
        self.requests_lost = max(0, self.requests_lost - recovered)
        self.replayed_tokens += replayed
        self.recovery_latency_s += latency
        self.recovery_restore_s += restore_s

    def on_checkpoint(self, rid: int, units: int, ckpt_s: float):
        self.checkpoints += 1
        self.checkpointed_units += units
        self.checkpoint_stage_s += ckpt_s

    # ------------------------------------------------------ vertical/QoS
    def on_resize(self, rid: int, old_batch: int, new_batch: int, *,
                  evicted: int, stage_s: float):
        """One executed ``ResizeOrder``: grow or shrink by lane delta,
        plus the slots it displaced and the real staging seconds."""
        if new_batch > old_batch:
            self.vertical_grows += 1
        elif new_batch < old_batch:
            self.vertical_shrinks += 1
        self.vertical_evictions += evicted
        self.resize_stage_s += stage_s

    def on_qos_slot(self, tier: str, seconds: float):
        """Accumulate slot-seconds of lane occupancy for a QoS tier."""
        self.qos_slot_seconds[tier] = (
            self.qos_slot_seconds.get(tier, 0.0) + seconds)

    # ------------------------------------------------------------ replica
    def on_launch(self, rid: int, itype: str, *,
                  model_id: str = "default", cost_per_hour: float = 0.0,
                  t: float = 0.0):
        """Start a replica's meter: billing runs from the launch request
        until termination (or the summary horizon while alive)."""
        if rid not in self.replicas:
            self.replicas[rid] = ReplicaStats(
                rid, itype, model_id=model_id,
                cost_per_hour=cost_per_hour, launched_t=t)

    def on_terminate(self, rid: int, now: float):
        st = self.replicas.get(rid)
        if st is not None and st.terminated_t is None:
            st.terminated_t = now
        if self.ledger is not None:
            self.ledger.on_terminate(rid, now)

    def on_tokens(self, rid: int, tokens: int, busy_s: float):
        st = self.replicas[rid]
        st.tokens += tokens
        st.busy_s += busy_s

    def on_occupancy(self, rid: int, occ: Dict[str, int]):
        """Fold an engine ``occupancy()`` sample into the replica's
        high-water marks (slots always; blocks for paged caches)."""
        st = self.replicas.get(rid)
        if st is None:
            return
        st.peak_slots = max(st.peak_slots,
                            int(occ.get("max_concurrent_slots", 0)))
        st.peak_blocks = max(st.peak_blocks,
                             int(occ.get("peak_blocks_in_use", 0)))
        st.pool_blocks = max(st.pool_blocks,
                             int(occ.get("pool_blocks", 0)))

    # --------------------------------------------------------------- cost
    def pool_dollar_cost(self, horizon: float) -> Dict[str, float]:
        """Per-model-pool fleet dollars accrued by ``horizon``."""
        out: Dict[str, float] = {}
        for st in self.replicas.values():
            out[st.model_id] = out.get(st.model_id, 0.0) \
                + st.dollar_cost(horizon)
        return out

    def fleet_dollar_cost(self, horizon: float) -> float:
        return sum(self.pool_dollar_cost(horizon).values())

    # ------------------------------------------------------------ summary
    def latencies(self, slo: Optional[str] = None) -> np.ndarray:
        return np.asarray([t.latency for t in self.traces.values()
                           if t.latency is not None
                           and (slo is None or t.slo == slo)],
                          dtype=np.float64)

    def class_attainment(self, slo: str, *, model_id: Optional[str] = None,
                         since: float = -np.inf,
                         until: float = np.inf) -> Optional[float]:
        """Fraction of a class's requests that met their deadline.

        Scope: requests ARRIVED in [since, until] (so a truncated run
        counts still-running late requests as misses, and the autoscaler
        can ask about a recent window).  None when the class saw no
        traffic in the window.
        """
        pop = [t for t in self.traces.values()
               if t.slo == slo and since <= t.arrival_t <= until
               and (model_id is None or t.model_id == model_id)]
        if self.retain_traces:
            if not pop:
                return None
            return sum(t.met_deadline for t in pop) / len(pop)
        # streaming: completed requests live only in the aggregates,
        # which carry no arrival time — the window scopes just the
        # still-live population (all live requests count as misses)
        completed = met = 0
        for (s, m), agg in self._agg.items():
            if s == slo and (model_id is None or m == model_id):
                completed += agg.completed
                met += agg.met
        if completed + len(pop) == 0:
            return None
        return met / (completed + len(pop))

    def slo_classes(self) -> List[str]:
        if self.retain_traces:
            return sorted({t.slo for t in self.traces.values()})
        return sorted(self._classes)

    def overdue(self, now: float,
                model_id: Optional[str] = None) -> Dict[str, int]:
        """Per-class count of live requests already past their deadline.

        The autoscaler's SLO-attainment signal: an overdue-but-running
        request is a *decided* miss (it cannot un-miss), so a nonzero
        count means the pool is under-provisioned for that class right
        now — no completion statistics needed.
        """
        out: Dict[str, int] = {}
        for t in self.traces.values():
            if (t.done_t is None and t.deadline_t < now
                    and (model_id is None or t.model_id == model_id)):
                out[t.slo] = out.get(t.slo, 0) + 1
        return out

    def summary(self, now: float) -> Dict[str, float]:
        total_tokens = sum(s.tokens for s in self.replicas.values())
        # horizon = last request completion, NOT the loop's last event —
        # trailing bookkeeping events (a pre-warmed replica coming up, a
        # stale step) must not dilute or equalize throughput.  tok_per_s
        # pairs that horizon with the tokens of *completed* requests so a
        # max_time-truncated run can't overstate throughput (on a fully
        # drained run the two token counts coincide).
        if self.retain_traces:
            lat = self.latencies()
            done = int(sum(t.done_t is not None
                           for t in self.traces.values()))
            done_ts = [t.done_t for t in self.traces.values()
                       if t.done_t is not None]
            done_tokens = sum(t.tokens for t in self.traces.values()
                              if t.done_t is not None)
            now = max(done_ts) if done_ts else now
            submitted = len(self.traces)
            p50 = float(np.percentile(lat, 50)) if lat.size else 0.0
            p99 = float(np.percentile(lat, 99)) if lat.size else 0.0
            lat_max = float(lat.max()) if lat.size else 0.0
        else:
            done = self._done_count
            done_tokens = self._done_tokens
            submitted = self._submitted
            if done:
                now = self._max_done_t
            p50 = self._hist.percentile(50)
            p99 = self._hist.percentile(99)
            lat_max = self._hist.max_seen
        out = {
            "virtual_seconds": now,
            "submitted": submitted,
            "completed": done,
            "dropped": submitted - done,
            "total_tokens": total_tokens,
            "tok_per_s": done_tokens / max(now, 1e-9),
            "p50_latency": p50,
            "p99_latency": p99,
            "max_latency": lat_max,
            "migrated_slots": sum(d.slots_migrated for d in self.drains),
            "drains": len(self.drains),
            "rebalance_migrations": self.rebalance_migrations,
            "preemptions": self.preemptions,
            "resumes": self.resumes,
            "preempt_stage_s": self.preempt_stage_s,
            "interruption_overhead_s": sum(
                d.checkpoint_s + d.restore_s for d in self.drains),
            # fleet dollars through the completion horizon (per-pool
            # figures follow; single-pool fleets just get one entry)
            "fleet_dollar_cost": self.fleet_dollar_cost(now),
            # cache-occupancy high-water across the fleet: most slots any
            # replica ran concurrently, and (paged engines) the fullest
            # any block pool got, as a fraction
            "max_concurrent_slots": max(
                (s.peak_slots for s in self.replicas.values()), default=0),
            "peak_block_occupancy": max(
                (s.peak_blocks / s.pool_blocks
                 for s in self.replicas.values() if s.pool_blocks),
                default=0.0),
            # chaos & recovery — always emitted (zero-filled) so
            # fault-free scenarios keep a stable schema
            "hard_kills": self.hard_kills,
            "requests_lost": self.requests_lost,
            "requests_recovered": self.requests_recovered,
            "recoveries": self.recoveries,
            "replayed_tokens": self.replayed_tokens,
            "recovery_latency_s": self.recovery_latency_s,
            "recovery_restore_s": self.recovery_restore_s,
            "checkpoints": self.checkpoints,
            "checkpointed_units": self.checkpointed_units,
            "checkpoint_stage_s": self.checkpoint_stage_s,
            "slowdowns": self.slowdowns,
            "contention_windows": self.contention_windows,
            "contention_delay_s": self.contention_delay_s,
            "endpoint_faults": self.endpoint_faults,
            "endpoint_retries": self.endpoint_retries,
            "retry_backoff_s": self.retry_backoff_s,
            "quarantines": self.quarantines,
            # vertical elasticity & QoS — always emitted (zero-filled)
            # so horizontal-only scenarios keep a stable schema
            "vertical_grows": self.vertical_grows,
            "vertical_shrinks": self.vertical_shrinks,
            "vertical_evictions": self.vertical_evictions,
            "resize_stage_s": self.resize_stage_s,
            "qos_guaranteed_slot_s": self.qos_slot_seconds.get(
                "guaranteed", 0.0),
            "qos_burstable_slot_s": self.qos_slot_seconds.get(
                "burstable", 0.0),
            "qos_best_effort_slot_s": self.qos_slot_seconds.get(
                "best_effort", 0.0),
        }
        for pool, cost in sorted(self.pool_dollar_cost(now).items()):
            out[f"dollar_cost_{pool}"] = cost
        # per-SLO-class attainment + tail latency (only when classed
        # traffic was offered, so class-less runs keep the old summary)
        for slo in self.slo_classes():
            if slo == "standard" and len(self.slo_classes()) == 1:
                break
            att = self.class_attainment(slo)
            out[f"attainment_{slo}"] = att if att is not None else 1.0
            if self.retain_traces:
                lat = self.latencies(slo)
                out[f"p99_latency_{slo}"] = (float(np.percentile(lat, 99))
                                             if lat.size else 0.0)
                out[f"misses_{slo}"] = int(sum(
                    t.slo == slo and not t.met_deadline
                    and np.isfinite(t.deadline_t)
                    for t in self.traces.values()))
            else:
                h = self._slo_hist.get(slo)
                out[f"p99_latency_{slo}"] = h.percentile(99) if h else 0.0
                fmiss = sum(agg.finite_misses
                            for (s, _), agg in self._agg.items()
                            if s == slo)
                live_miss = sum(t.slo == slo and np.isfinite(t.deadline_t)
                                for t in self.traces.values())
                out[f"misses_{slo}"] = int(fmiss + live_miss)
        # market mode: savings vs all-on-demand + by-market/by-strategy
        # breakdowns, billed through the same completion horizon as
        # fleet_dollar_cost (which keeps its static-rate semantics)
        if self.ledger is not None:
            out.update(self.ledger.report(now))
        return out

    def per_replica(self) -> List[Dict[str, float]]:
        return [{"rid": s.rid, "itype": s.itype, "tokens": s.tokens,
                 "tok_per_s": s.tokens / max(s.busy_s, 1e-9)}
                for s in self.replicas.values()]
