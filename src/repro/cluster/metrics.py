"""Cluster observability: request traces, fleet summaries.

Everything is keyed off *virtual* time so cluster runs are deterministic
and reproducible on any host; only checkpoint/restore stage timings (from
the ``InMemoryStore`` timers) are real wall-clock measurements.  The
clock itself is the shared ``repro.runtime.VirtualClock`` (re-exported
here for back-compat).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.runtime import VirtualClock  # noqa: F401  (re-export)


@dataclasses.dataclass
class RequestTrace:
    rid: int
    arrival_t: float
    done_t: Optional[float] = None
    tokens: int = 0
    migrations: int = 0          # times this request was drain-migrated

    @property
    def latency(self) -> Optional[float]:
        return None if self.done_t is None else self.done_t - self.arrival_t


@dataclasses.dataclass
class ReplicaStats:
    rid: int
    itype: str
    tokens: int = 0
    busy_s: float = 0.0          # virtual seconds with work in the engine


@dataclasses.dataclass
class DrainRecord:
    t: float
    replica: int
    slots_migrated: int
    queued_requeued: int
    checkpoint_s: float          # real (measured) store stage seconds
    restore_s: float = 0.0


class ClusterMetrics:
    def __init__(self):
        self.traces: Dict[int, RequestTrace] = {}
        self.replicas: Dict[int, ReplicaStats] = {}
        self.drains: List[DrainRecord] = []

    # ------------------------------------------------------------ request
    def on_submit(self, rid: int, now: float):
        self.traces[rid] = RequestTrace(rid, now)

    def on_done(self, rid: int, now: float, tokens: int):
        tr = self.traces[rid]
        tr.done_t = now
        tr.tokens = tokens

    def on_migration(self, rid: int):
        if rid in self.traces:
            self.traces[rid].migrations += 1

    # ------------------------------------------------------------ replica
    def ensure_replica(self, rid: int, itype: str):
        if rid not in self.replicas:
            self.replicas[rid] = ReplicaStats(rid, itype)

    def on_tokens(self, rid: int, tokens: int, busy_s: float):
        st = self.replicas[rid]
        st.tokens += tokens
        st.busy_s += busy_s

    # ------------------------------------------------------------ summary
    def latencies(self) -> np.ndarray:
        return np.asarray([t.latency for t in self.traces.values()
                           if t.latency is not None], dtype=np.float64)

    def summary(self, now: float) -> Dict[str, float]:
        lat = self.latencies()
        total_tokens = sum(s.tokens for s in self.replicas.values())
        done = int(sum(t.done_t is not None for t in self.traces.values()))
        # horizon = last request completion, NOT the loop's last event —
        # trailing bookkeeping events (a pre-warmed replica coming up, a
        # stale step) must not dilute or equalize throughput.  tok_per_s
        # pairs that horizon with the tokens of *completed* requests so a
        # max_time-truncated run can't overstate throughput (on a fully
        # drained run the two token counts coincide).
        done_ts = [t.done_t for t in self.traces.values()
                   if t.done_t is not None]
        done_tokens = sum(t.tokens for t in self.traces.values()
                          if t.done_t is not None)
        now = max(done_ts) if done_ts else now
        out = {
            "virtual_seconds": now,
            "submitted": len(self.traces),
            "completed": done,
            "dropped": len(self.traces) - done,
            "total_tokens": total_tokens,
            "tok_per_s": done_tokens / max(now, 1e-9),
            "p50_latency": float(np.percentile(lat, 50)) if lat.size else 0.0,
            "p99_latency": float(np.percentile(lat, 99)) if lat.size else 0.0,
            "max_latency": float(lat.max()) if lat.size else 0.0,
            "migrated_slots": sum(d.slots_migrated for d in self.drains),
            "drains": len(self.drains),
            "interruption_overhead_s": sum(
                d.checkpoint_s + d.restore_s for d in self.drains),
        }
        return out

    def per_replica(self) -> List[Dict[str, float]]:
        return [{"rid": s.rid, "itype": s.itype, "tokens": s.tokens,
                 "tok_per_s": s.tokens / max(s.busy_s, 1e-9)}
                for s in self.replicas.values()]
