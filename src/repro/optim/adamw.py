"""AdamW with cosine schedule, warmup and global-norm clipping.

Plain pytree implementation (no optax dependency).  Optimizer state is fp32
and shards like the params; with ``zero1`` the launcher additionally shards
the first dim of m/v over the data axis (see launch/shardings_for_state).
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class HParams(NamedTuple):
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    m: Any
    v: Any


def init(params) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jax.tree.map(z, params), jax.tree.map(z, params))


def abstract_init(params) -> AdamWState:
    z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(jax.tree.map(z, params), jax.tree.map(z, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def schedule(step, hp: HParams) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = hp.lr * step / max(hp.warmup_steps, 1)
    frac = jnp.clip((step - hp.warmup_steps)
                    / max(hp.total_steps - hp.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * hp.lr * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < hp.warmup_steps, warm, cos)


def update(params, grads, state: AdamWState, step, hp: HParams):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, hp.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(step, hp)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - hp.b1 ** t
    bc2 = 1.0 - hp.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = hp.b1 * m + (1 - hp.b1) * g
        v = hp.b2 * v + (1 - hp.b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + hp.eps) + hp.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(new_m, new_v)
