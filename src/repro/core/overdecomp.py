"""Overdecomposed tile runtime — chare collections for stencil apps (C1).

The global 2D domain is decomposed into ``odf x n_pes`` tiles ("chares").
Tiles are migratable units: the runtime owns a tile->PE map produced by the
load balancer, measures per-PE execution rates, and exposes
checkpoint/shrink/expand hooks used by the elastic runtime and CloudManager.

Two execution backends:

* ``HostTileRuntime`` (this module) — host-orchestrated, one jitted tile
  kernel; per-PE wall-times are *measured* (with optional per-PE rate
  multipliers emulating heterogeneous instance pools, and an optional
  per-message latency model emulating cloud TCP).  This is the harness for
  the paper's Figures 2-3 experiments.
* ``spmd_stencil`` (core/spmd_stencil.py) — the TPU-production shard_map
  path with ppermute halo exchange, dry-runnable on the 512-chip mesh.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import loadbalance as lb
from repro.core.rates import RateMonitor
from repro.runtime import EventLoop, FaultTrace


# --------------------------------------------------------------------- tiles
@dataclasses.dataclass(frozen=True)
class TileGrid:
    """Decomposition of an (H, W) domain into (tr x tc) tiles."""
    H: int
    W: int
    tr: int
    tc: int

    @property
    def n_tiles(self) -> int:
        return self.tr * self.tc

    @property
    def tile_shape(self) -> Tuple[int, int]:
        assert self.H % self.tr == 0 and self.W % self.tc == 0
        return self.H // self.tr, self.W // self.tc

    def neighbors(self, t: int) -> Dict[str, Optional[int]]:
        r, c = divmod(t, self.tc)
        return {
            "up": t - self.tc if r > 0 else None,
            "down": t + self.tc if r < self.tr - 1 else None,
            "left": t - 1 if c > 0 else None,
            "right": t + 1 if c < self.tc - 1 else None,
        }


def choose_tiling(n_tiles: int) -> Tuple[int, int]:
    """Near-square factorization."""
    best = (1, n_tiles)
    for a in range(1, int(n_tiles ** 0.5) + 1):
        if n_tiles % a == 0:
            best = (a, n_tiles // a)
    return best


# --------------------------------------------------------------- tile kernels
def jacobi_tile_step(tile, up, down, left, right):
    """5-point Jacobi update for one tile given neighbor halo rows/cols.

    tile: (h, w); up/down: (w,); left/right: (h,).
    """
    padded = jnp.pad(tile, 1)
    padded = padded.at[0, 1:-1].set(up)
    padded = padded.at[-1, 1:-1].set(down)
    padded = padded.at[1:-1, 0].set(left)
    padded = padded.at[1:-1, -1].set(right)
    return 0.25 * (padded[:-2, 1:-1] + padded[2:, 1:-1]
                   + padded[1:-1, :-2] + padded[1:-1, 2:])


def lulesh_tile_step(tile, up, down, left, right, *, inner_iters: int = 8):
    """Compute-bound proxy (LULESH stand-in): same halo pattern, but each
    step runs ``inner_iters`` rounds of stencil + EOS-like transcendental
    pointwise work, making compute >> communication (paper §III-B)."""
    padded = jnp.pad(tile, 1)
    padded = padded.at[0, 1:-1].set(up)
    padded = padded.at[-1, 1:-1].set(down)
    padded = padded.at[1:-1, 0].set(left)
    padded = padded.at[1:-1, -1].set(right)

    def body(x, _):
        lap = (padded[:-2, 1:-1] + padded[2:, 1:-1] + padded[1:-1, :-2]
               + padded[1:-1, 2:] - 4.0 * x)
        # artificial EOS: e = e + dt * (p / (rho + eps)); p ~ e^gamma
        e = jnp.abs(x) + 1e-6
        p = jnp.exp(0.4 * jnp.log(e))
        x = x + 1e-3 * lap + 1e-4 * (p / (e + 0.1) - 1.0)
        return x, ()

    out, _ = jax.lax.scan(body, tile, None, length=inner_iters)
    return out


TILE_KERNELS = {"jacobi": jacobi_tile_step, "lulesh": lulesh_tile_step}


# --------------------------------------------------------------- the runtime
@dataclasses.dataclass
class CommModel:
    """Per-halo-message latency model (cloud TCP vs HPC fabric).

    cost = latency_s + bytes / bw.  Applied as *accounted* time (added to
    the measured step wall-time), so experiments can sweep network quality
    deterministically on one host.
    """
    latency_s: float = 0.0
    bw_Bps: float = float("inf")

    def cost(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bw_Bps


class HostTileRuntime:
    """Charm++-style overdecomposed execution of a stencil app."""

    def __init__(self, grid: TileGrid, n_pes: int, *, kernel: str = "jacobi",
                 odf: Optional[int] = None, dtype=jnp.float32,
                 pe_rate_multipliers: Optional[Sequence[float]] = None,
                 comm: Optional[CommModel] = None,
                 rng: Optional[np.random.Generator] = None):
        assert grid.n_tiles % n_pes == 0, (grid.n_tiles, n_pes)
        self.grid = grid
        self.n_pes = n_pes
        self.odf = odf or grid.n_tiles // n_pes
        self.kernel_name = kernel
        self.comm = comm or CommModel()
        self.rng = rng or np.random.default_rng(0)
        h, w = grid.tile_shape
        self.np_dtype = np.dtype(jnp.dtype(dtype).name)
        self.tiles: Dict[int, np.ndarray] = {
            t: np.zeros((h, w), self.np_dtype) for t in range(grid.n_tiles)
        }
        # boundary conditions: hot top edge (classic Laplace problem)
        for t in range(grid.tc):
            self.tiles[t][0, :] = 1.0
        self.assignment = np.arange(grid.n_tiles) % n_pes  # block-cyclic home
        self.monitor = RateMonitor(n_pes)
        self._pe_mult = (np.asarray(pe_rate_multipliers, dtype=np.float64)
                         if pe_rate_multipliers is not None
                         else np.ones(n_pes))
        # one vmapped kernel launch per PE per step: chare scheduling
        # overhead stays micro-seconds-scale, as in Charm++
        self._kernel = jax.jit(jax.vmap(TILE_KERNELS[kernel]))
        self._warm = set()
        self.iteration = 0

    # ----------------------------------------------------------- halo + step
    def _halos(self, t: int):
        h, w = self.grid.tile_shape
        nb = self.grid.neighbors(t)
        # top boundary row is held at 1.0 (hot edge), others at 0.0
        if nb["up"] is not None:
            up = self.tiles[nb["up"]][-1, :]
        else:
            up = (np.ones if t < self.grid.tc else np.zeros)(
                (w,), self.np_dtype)
        down = (self.tiles[nb["down"]][0, :] if nb["down"] is not None
                else np.zeros((w,), self.np_dtype))
        left = (self.tiles[nb["left"]][:, -1] if nb["left"] is not None
                else np.zeros((h,), self.np_dtype))
        right = (self.tiles[nb["right"]][:, 0] if nb["right"] is not None
                 else np.zeros((h,), self.np_dtype))
        return up, down, left, right

    def _comm_seconds(self, pe: int, objs) -> float:
        """Accounted halo communication time for one PE's tiles.

        Message latencies overlap each other (async sends, all in flight
        concurrently); bytes serialize on the NIC.  Remote edges only.
        """
        h, w = self.grid.tile_shape
        itemsize = self.np_dtype.itemsize
        total_bytes = 0
        n_remote = 0
        for t in objs:
            for side, n in self.grid.neighbors(t).items():
                if n is None or self.assignment[n] == pe:
                    continue  # on-PE neighbor: shared memory, free
                total_bytes += (w if side in ("up", "down") else h) * itemsize
                n_remote += 1
        if n_remote == 0:
            return 0.0
        return self.comm.latency_s + total_bytes / self.comm.bw_Bps

    def step(self) -> Dict[str, float]:
        """One iteration; returns measured per-PE seconds (incl. accounted
        heterogeneity multipliers + comm model)."""
        new_tiles = {}
        pe_compute = np.zeros(self.n_pes)
        pe_comm = np.zeros(self.n_pes)
        pe_ntiles = np.zeros(self.n_pes)
        for pe in range(self.n_pes):
            objs = [int(t) for t in np.nonzero(self.assignment == pe)[0]]
            if not objs:
                continue
            pe_ntiles[pe] = len(objs)
            # halo assembly is host-side numpy (the "message" contents)
            stacks = [np.stack(a) for a in zip(
                *[(self.tiles[t], *self._halos(t)) for t in objs])]
            if stacks[0].shape not in self._warm:   # exclude jit compile
                self._kernel(*stacks).block_until_ready()
                self._warm.add(stacks[0].shape)
            t0 = time.perf_counter()
            out = self._kernel(*stacks)
            out.block_until_ready()
            pe_compute[pe] = (time.perf_counter() - t0) / self._pe_mult[pe]
            out_np = np.asarray(out)
            for i, t in enumerate(objs):
                new_tiles[t] = out_np[i]
            pe_comm[pe] = self._comm_seconds(pe, objs)
        self.tiles = new_tiles
        self.iteration += 1
        # Overdecomposition overlap (Fig 1): while one tile's halos are in
        # flight the PE computes its other tiles.  A single tile per PE
        # cannot overlap anything; with k tiles, (k-1)/k of the compute is
        # available to hide the comm window.
        overlappable = pe_compute * np.maximum(pe_ntiles - 1, 0) \
            / np.maximum(pe_ntiles, 1)
        exposed = np.maximum(pe_comm - overlappable, 0.0)
        pe_seconds = pe_compute + exposed
        # Accounted time: the same model, but per-PE compute rebuilt from
        # this iteration's *fastest measured per-tile cost* scaled by tile
        # count and the PE's rate multiplier.  Tile placement, modeled
        # heterogeneity, and modeled comm all still move it; OS scheduling
        # jitter on a contended host does not — assertions about LB and
        # overlap effects compare this, not raw wall-clock.  The rate
        # monitor keeps consuming the MEASURED seconds: a genuinely slow
        # PE (no declared multiplier) must still show up as a straggler
        # to the load balancer.
        active = pe_ntiles > 0
        unit = float((pe_compute[active] * self._pe_mult[active]
                      / pe_ntiles[active]).min()) if active.any() else 0.0
        acc_compute = np.where(active,
                               unit * pe_ntiles / self._pe_mult, 0.0)
        acc_overlappable = acc_compute * np.maximum(pe_ntiles - 1, 0) \
            / np.maximum(pe_ntiles, 1)
        acc_exposed = np.maximum(pe_comm - acc_overlappable, 0.0)
        acc_seconds = acc_compute + acc_exposed
        self.monitor.record_step(
            per_pe_work=[float((self.assignment == pe).sum())
                         for pe in range(self.n_pes)],
            per_pe_seconds=pe_seconds)
        return {
            "time_per_iter": float(pe_seconds.max()),
            "accounted_time_per_iter": float(acc_seconds.max()),
            "compute_max": float(pe_compute.max()),
            "comm_exposed_max": float(exposed.max()),
        }

    # ----------------------------------------------------------- LB hooks
    def load_balance(self, strategy: str = "greedy_refine",
                     rate_aware: bool = True) -> lb.LBResult:
        loads = np.ones(self.grid.n_tiles)   # uniform tiles (paper's apps)
        rates = self.monitor.rates() if rate_aware else None
        res = lb.balance(strategy, loads, self.n_pes, rates=rates,
                         current=self.assignment)
        self.assignment = res.assignment
        return res

    # ----------------------------------------------------------- elasticity
    def checkpoint(self):
        """The migratable-object state: tiles + assignment + iteration."""
        return {
            "tiles": {t: v.copy() for t, v in self.tiles.items()},
            "assignment": self.assignment.copy(),
            "iteration": self.iteration,
        }

    def restore(self, snap, n_pes: Optional[int] = None):
        n_pes = n_pes or self.n_pes
        self.tiles = {t: np.asarray(v) for t, v in snap["tiles"].items()}
        self.iteration = snap["iteration"]
        self.n_pes = n_pes
        self.monitor.resize(n_pes)
        if len(self._pe_mult) != n_pes:
            self._pe_mult = np.ones(n_pes)
        # remap objects onto the new PE set, then LB
        self.assignment = snap["assignment"] % n_pes
        self.odf = self.grid.n_tiles // n_pes

    def global_grid(self) -> np.ndarray:
        h, w = self.grid.tile_shape
        out = np.zeros((self.grid.H, self.grid.W), dtype=np.float64)
        for t, v in self.tiles.items():
            r, c = divmod(t, self.grid.tc)
            out[r * h:(r + 1) * h, c * w:(c + 1) * w] = np.asarray(v)
        return out


# ------------------------------------------------------------ event driver
class TileRuntimeDriver:
    """Event-driven stencil execution on the shared ``EventLoop``.

    Replaces host-side ``for it in range(iters)`` driving: iterations are
    ``tile_step`` events at a virtual cadence, load balancing fires as its
    own periodic events, and a bound :class:`FaultTrace` triggers the §IV
    responses — a proactive rebalance at the *recommendation* and an
    application checkpoint at the *interruption notice* — at exactly the
    trace's timestamps, so a stencil app and a serving cluster handed the
    same trace replay the identical fault schedule.
    """

    _ids = itertools.count()

    def __init__(self, rt: HostTileRuntime, loop: EventLoop, *,
                 iters: int, step_interval: float = 1.0,
                 lb_interval: float = 0.0,
                 lb_strategy: str = "greedy_refine", rate_aware: bool = True,
                 trace: Optional[FaultTrace] = None, t0: float = 0.0):
        self.rt = rt
        self.loop = loop
        self.iters = iters
        self.step_interval = step_interval
        self.lb_interval = lb_interval
        self.lb_strategy = lb_strategy
        self.rate_aware = rate_aware
        self.per_iter: List[Dict[str, float]] = []
        self.timeline: List[Tuple[float, str]] = []
        self.checkpoints: List[Tuple[float, dict]] = []
        n = next(self._ids)
        self._step_kind = f"tile_step_{n}"
        self._lb_kind = f"tile_lb_{n}"
        self._fault_kind = f"tile_fault_{n}"
        loop.register(self._step_kind, self._on_step)
        loop.schedule(t0 + step_interval, self._step_kind)
        if lb_interval > 0:
            loop.register(self._lb_kind, self._on_lb)
            loop.schedule(t0 + lb_interval, self._lb_kind)
        if trace is not None:
            loop.register(self._fault_kind, self._on_fault)
            trace.bind(loop, kind=self._fault_kind)

    @property
    def done(self) -> bool:
        return self.rt.iteration >= self.iters

    def _on_step(self, ev, t: float):
        if self.done:
            return
        self.per_iter.append(self.rt.step())
        if not self.done:
            self.loop.schedule(t + self.step_interval, self._step_kind)

    def _on_lb(self, ev, t: float):
        if self.done:
            return
        res = self.rt.load_balance(self.lb_strategy,
                                   rate_aware=self.rate_aware)
        self.timeline.append((t, f"lb migrations={res.migrations}"))
        self.loop.schedule(t + self.lb_interval, self._lb_kind)

    def _on_fault(self, ev, t: float):
        notice = ev.payload["notice"]
        self.timeline.append((t, f"{notice.kind} target={notice.target}"))
        if self.done:
            return
        if notice.kind == "rebalance_recommendation":
            # proactive: rebalance off the doomed capacity ahead of the
            # notice (paper Mode C applied to the stencil app)
            res = self.rt.load_balance(self.lb_strategy,
                                       rate_aware=self.rate_aware)
            self.timeline.append((t, f"lb migrations={res.migrations}"))
        elif notice.kind == "interruption_notice":
            self.checkpoints.append((t, self.rt.checkpoint()))
