"""CloudManager — proactive spot-instance management (paper §IV, Fig 4).

A deterministic discrete-event simulation of an EC2-style fleet (spot pools,
rebalance recommendations, 2-minute interruption notices, replacement launch
latency) driving an elastic application.  Interruptions can be injected
explicitly (the AWS Fault-Injection-Simulator analogue used in the paper's
experiments) or sampled.

Interruption-handling modes (§IV-C):

* ``Mode.A_FILESYSTEM`` — checkpoint to a shared filesystem on the notice;
  the app restarts from disk once capacity is back (3 stages: checkpoint /
  restart / restore; both ends scale with fleet size).
* ``Mode.B_REACTIVE``   — Bhosale et al. [6]: in-memory checkpoint; shrink
  before the deadline, then a second rescale (expand) when the replacement
  eventually launches.  Two full rescale cycles.
* ``Mode.C_PROACTIVE``  — this paper: capacity rebalancing.  Replacements are
  requested at the *rebalance recommendation*; the rescale is deferred until
  one of three trigger conditions (complete / emergency / T_timeout), so a
  single rescale swaps doomed instances for ready replacements.

Stage costs come from a ``StageCostModel`` fitted from *measured*
checkpoint/restore/restart timings on real pytrees (benchmarks/measure.py),
so the simulation reproduces the paper's Figures 5-8 quantitatively from
first-principles measurements rather than assumed constants.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class Mode(enum.Enum):
    A_FILESYSTEM = "A"
    B_REACTIVE = "B"
    C_PROACTIVE = "C"


# ------------------------------------------------------------------ fleet
@dataclasses.dataclass
class Instance:
    iid: int
    itype: str
    is_spot: bool = True
    state: str = "running"      # running | at_risk | doomed | terminated
    launched_at: float = 0.0


@dataclasses.dataclass(order=True)
class Event:
    t: float
    seq: int
    kind: str = dataclasses.field(compare=False)
    payload: dict = dataclasses.field(compare=False, default_factory=dict)


@dataclasses.dataclass
class StageCostModel:
    """Seconds per rescale stage as a function of fleet size n.

    Fitted from real measurements: checkpoint/restore scale with per-instance
    bytes (total/n for in-memory; total and shared-bandwidth-limited for
    filesystem), restart grows ~log(n) (startup), LB ~ bytes moved.
    """
    state_bytes: float                     # application state size
    host_bw: float = 8e9                   # host-RAM copy bytes/s ("shm")
    device_bw: float = 400e9               # on-device copy bytes/s (daemon)
    fs_bw: float = 0.35e9                  # shared-FS bytes/s (EFS elastic)
    restart_base: float = 4.0              # app startup, 1 instance
    restart_log: float = 1.2               # + log2(n) growth (paper Fig 5)
    restart_accel_extra: float = 9.0       # CUDA-init analogue (paper Fig 6)
    lb_frac: float = 0.3                   # fraction of state migrated by LB
    accelerator: bool = False

    def checkpoint(self, n: int, store: str) -> float:
        per_inst = self.state_bytes / max(n, 1)
        bw = {"memory": self.host_bw, "device": self.device_bw,
              "filesystem": self.fs_bw}[store]
        if store == "filesystem":
            # shared FS: aggregate bandwidth, grows with total size
            return self.state_bytes / bw / max(math.sqrt(n), 1.0)
        return per_inst / bw

    restore = checkpoint

    def restart(self, n: int) -> float:
        extra = self.restart_accel_extra if self.accelerator else 0.0
        return self.restart_base + extra + self.restart_log * math.log2(
            max(n, 2))

    def loadbalance(self, n: int, moved_frac: Optional[float] = None) -> float:
        frac = self.lb_frac if moved_frac is None else moved_frac
        bw = self.device_bw if self.accelerator else self.host_bw
        # migrating GPU-resident data without RDMA goes via host staging
        if self.accelerator:
            bw = self.host_bw * 2  # staged copies overlap both directions
        return frac * self.state_bytes / max(n, 1) / bw

    def rescale(self, n: int, store: str,
                lb_frac: Optional[float] = None) -> Dict[str, float]:
        return {
            "checkpoint": self.checkpoint(n, store),
            "loadbalance": 0.0 if store == "filesystem"
            else self.loadbalance(n, lb_frac),
            "restart": self.restart(n),
            "restore": self.restore(n, store),
        }


# ------------------------------------------------------------------ feed
@dataclasses.dataclass(frozen=True)
class SpotNotice:
    """One spot-lifecycle event delivered to a subscriber."""
    t: float
    kind: str       # rebalance_recommendation | interruption_notice | terminate
    target: int     # subscriber-defined id (instance / serving replica)


class SpotEventFeed:
    """Deterministic spot-lifecycle event source for external subscribers.

    ``CloudManager`` runs a closed-loop simulation of the *training* fleet;
    subsystems that own their own execution loop (the serving cluster)
    instead subscribe to this feed, which emits the same §IV lifecycle per
    injected interruption: a *rebalance recommendation* leading the
    2-minute *interruption notice* by ``rebalance_lead`` seconds, and the
    *terminate* following ``notice_deadline`` seconds after the notice —
    the AWS FIS analogue used in the paper's experiments.
    """

    def __init__(self, *, rebalance_lead: float = 180.0,
                 notice_deadline: float = 120.0):
        self.rebalance_lead = rebalance_lead
        self.notice_deadline = notice_deadline
        self._events: List[Tuple[float, int, SpotNotice]] = []
        self._seq = itertools.count()

    def _push(self, ev: SpotNotice):
        heapq.heappush(self._events, (ev.t, next(self._seq), ev))

    def inject_interruption(self, t: float, target: int):
        """FIS analogue: schedule the full lifecycle for ``target``."""
        self._push(SpotNotice(t, "rebalance_recommendation", target))
        t_notice = t + self.rebalance_lead
        self._push(SpotNotice(t_notice, "interruption_notice", target))
        self._push(SpotNotice(t_notice + self.notice_deadline, "terminate",
                              target))

    def poll(self, now: float) -> List[SpotNotice]:
        """Pop every event due at or before ``now``, in time order."""
        due = []
        while self._events and self._events[0][0] <= now:
            due.append(heapq.heappop(self._events)[2])
        return due

    @property
    def next_event_t(self) -> float:
        return self._events[0][0] if self._events else math.inf


# ------------------------------------------------------------------ manager
@dataclasses.dataclass
class RunReport:
    total_time: float
    ideal_time: float
    rescales: List[Dict[str, float]]
    interruption_overhead: float
    timeline: List[Tuple[float, str]]

    @property
    def overhead_frac(self) -> float:
        return self.total_time / self.ideal_time - 1.0


class CloudManager:
    """Monitoring task + replacement policy + rescale triggers (Fig 4)."""

    def __init__(self, *, n_instances: int, mode: Mode,
                 cost: StageCostModel,
                 t_timeout: float = 120.0,
                 replacement_latency: float = 90.0,
                 notice_deadline: float = 120.0,
                 rebalance_lead: float = 180.0,
                 iter_seconds: float = 1.0,
                 total_iters: int = 5000,
                 seed: int = 0):
        self.mode = mode
        self.cost = cost
        self.t_timeout = t_timeout
        self.replacement_latency = replacement_latency
        self.notice_deadline = notice_deadline
        self.rebalance_lead = rebalance_lead
        self.iter_seconds = iter_seconds
        self.total_iters = total_iters
        self.target = n_instances
        self.rng = np.random.default_rng(seed)

        self._ids = itertools.count()
        self.fleet: Dict[int, Instance] = {
            (i := next(self._ids)): Instance(i, "spot.xlarge")
            for _ in range(n_instances)
        }
        self._events: List[Event] = []
        self._seq = itertools.count()
        self._oldest_rebalance: Optional[float] = None
        self._pending_replacements = 0
        self.timeline: List[Tuple[float, str]] = []
        self.rescales: List[Dict[str, float]] = []

    # ------------------------------------------------------------ events
    def push(self, t: float, kind: str, **payload):
        heapq.heappush(self._events, Event(t, next(self._seq), kind, payload))

    def inject_interruption(self, t: float, count: int = 1):
        """FIS analogue: at virtual time t, ``count`` running spot instances
        get a rebalance recommendation, followed by the 2-minute notice."""
        self.push(t, "fis", count=count)

    # ------------------------------------------------------------ dynamics
    def _running(self) -> List[Instance]:
        return [i for i in self.fleet.values() if i.state != "terminated"]

    def _at_risk(self) -> List[Instance]:
        return [i for i in self.fleet.values()
                if i.state in ("at_risk", "doomed")]

    def run(self) -> RunReport:
        """Simulate until the application completes ``total_iters``."""
        t = 0.0
        work_done = 0.0
        work_total = float(self.total_iters)
        ideal = self.total_iters * self.iter_seconds
        stalled_until = 0.0
        overhead = 0.0
        last_t = 0.0

        def capacity() -> float:
            if self._down:  # Mode A: a terminated rank kills the whole job
                return 0.0
            n_up = len([i for i in self.fleet.values()
                        if i.state in ("running", "at_risk", "doomed")])
            return min(n_up, self.target) / self.target

        while work_done < work_total:
            # next event or completion, whichever first
            rate = capacity() / self.iter_seconds  # iters per second
            if stalled_until > t:
                t_free = stalled_until
            else:
                t_free = t
            if rate > 0:
                t_done = t_free + (work_total - work_done) / rate
            else:
                t_done = math.inf
            t_next = self._events[0].t if self._events else math.inf
            if t_done <= t_next:
                work_done = work_total
                t = t_done
                break
            # progress until the event
            ev = heapq.heappop(self._events)
            span = max(ev.t - max(t, 0.0), 0.0)
            prog_start = max(t, stalled_until)
            if ev.t > prog_start and rate > 0:
                work_done += (ev.t - prog_start) * rate
            t = ev.t
            self._handle(ev, t)
            # handlers may stall the app (rescale downtime)
            if self._stall_pending:
                stalled_until = max(stalled_until, t) + self._stall_pending
                overhead += self._stall_pending
                self._stall_pending = 0.0
            if self._mark_request:       # checkpoint: remember progress
                self._work_mark = work_done
                self._mark_request = False
            if self._rollback_request:   # rank death: lose work since ckpt
                work_done = min(work_done, self._work_mark)
                self._rollback_request = False

        return RunReport(
            total_time=t,
            ideal_time=ideal,
            rescales=self.rescales,
            interruption_overhead=overhead,
            timeline=self.timeline,
        )

    _stall_pending: float = 0.0
    _down: bool = False
    _mark_request: bool = False
    _rollback_request: bool = False
    _work_mark: float = 0.0

    def _stall(self, seconds: float):
        self._stall_pending += seconds

    def _log(self, t: float, msg: str):
        self.timeline.append((t, msg))

    # ------------------------------------------------------------ handlers
    def _handle(self, ev: Event, t: float):
        if ev.kind == "fis":
            victims = [i for i in self._running() if i.state == "running"]
            victims = victims[:ev.payload["count"]]
            for v in victims:
                v.state = "at_risk"
                self._log(t, f"rebalance_recommendation i{v.iid}")
                if self._oldest_rebalance is None:
                    self._oldest_rebalance = t
                    if self.mode == Mode.C_PROACTIVE:
                        self.push(t + self.t_timeout, "timeout", started=t)
                self.push(t + self.rebalance_lead, "notice", iid=v.iid)
                if self.mode == Mode.C_PROACTIVE:
                    # proactively request a replacement from the pools
                    self._pending_replacements += 1
                    self.push(t + self.replacement_latency, "replacement")
            return

        if ev.kind == "notice":
            inst = self.fleet.get(ev.payload["iid"])
            if inst is None or inst.state == "terminated":
                return
            inst.state = "doomed"
            self._log(t, f"interruption_notice i{inst.iid}")
            self.push(t + self.notice_deadline, "terminate", iid=inst.iid)
            if self.mode == Mode.C_PROACTIVE:
                # emergency override: rescale NOW with whatever is ready
                self._trigger_rescale(t, reason="emergency")
            elif self.mode == Mode.B_REACTIVE:
                # reactive shrink before the deadline + request replacement
                self._do_rescale(t, reason="shrink", store="memory",
                                 drop_doomed=True)
                self._pending_replacements += 1
                self.push(t + self.replacement_latency, "replacement")
            else:  # Mode A: checkpoint to FS; app dies with the instance
                n = len(self._running())
                ck = self.cost.checkpoint(n, "filesystem")
                self._stall(ck)
                self._mark_request = True
                self._log(t, f"fs_checkpoint {ck:.1f}s")
                self._pending_replacements += 1
                self.push(t + self.replacement_latency, "replacement")
            return

        if ev.kind == "terminate":
            inst = self.fleet.get(ev.payload["iid"])
            if inst is None or inst.state == "terminated":
                return
            inst.state = "terminated"
            self._log(t, f"terminated i{inst.iid}")
            if self.mode == Mode.A_FILESYSTEM:
                # rigid ranks: the whole job is down until fs_restart,
                # and loses all work since the last checkpoint
                self._down = True
                self._rollback_request = True
                self._log(t, "job_down (rigid MPI-style ranks)")
                self._maybe_fs_restart(t)
            return

        if ev.kind == "replacement":
            self._pending_replacements -= 1
            i = next(self._ids)
            self.fleet[i] = Instance(i, "spot.xlarge", launched_at=t)
            self.fleet[i].state = "spare" if self.mode == Mode.C_PROACTIVE \
                else "running"
            self._log(t, f"replacement_launched i{i}")
            if self.mode == Mode.C_PROACTIVE:
                if not any(v.state == "at_risk" or v.state == "doomed"
                           for v in self.fleet.values()
                           if v.state in ("at_risk", "doomed")):
                    pass
                # complete-replacement trigger
                n_spare = len([x for x in self.fleet.values()
                               if x.state == "spare"])
                if n_spare >= len(self._at_risk()) and self._at_risk():
                    self._trigger_rescale(t, reason="complete")
            elif self.mode == Mode.B_REACTIVE:
                self._do_rescale(t, reason="expand", store="memory")
            else:  # Mode A: new rank available; restart when whole
                self._maybe_fs_restart(t)
            return

        if ev.kind == "timeout":
            if (self._oldest_rebalance is not None
                    and ev.payload["started"] == self._oldest_rebalance
                    and self._at_risk()):
                self._trigger_rescale(t, reason="timeout")
            return

        raise ValueError(ev.kind)

    def _maybe_fs_restart(self, t: float):
        """Mode A restart: needs all doomed ranks dead and full capacity."""
        if not self._down:
            return
        doomed_alive = any(i.state == "doomed" for i in self.fleet.values())
        n = len([x for x in self.fleet.values()
                 if x.state in ("running", "spare")])
        if doomed_alive or n < self.target:
            return
        for x in self.fleet.values():
            if x.state == "spare":
                x.state = "running"
        stages = {
            "restart": self.cost.restart(n),
            "restore": self.cost.restore(n, "filesystem"),
        }
        self.rescales.append(dict(stages, reason="fs_restart", t=t, n=n))
        self._stall(sum(stages.values()))
        self._down = False
        self._log(t, "fs_restart")

    # ------------------------------------------------------------ rescale
    def _trigger_rescale(self, t: float, reason: str):
        """Mode C single-rescale: swap doomed/at-risk for ready spares."""
        spares = [i for i in self.fleet.values() if i.state == "spare"]
        at_risk = self._at_risk()
        # replace as many as we have spares for; leftover at-risk keep running
        for v, s in zip(at_risk, spares):
            v.state = "terminated"
            s.state = "running"
        for v in at_risk[len(spares):]:
            if v.state == "at_risk":
                v.state = "running"   # not replaced; keeps running for now
        self._oldest_rebalance = None
        self._do_rescale(t, reason=f"proactive_{reason}", store="memory",
                         single=True)

    def _do_rescale(self, t: float, reason: str, store: str,
                    drop_doomed: bool = False, single: bool = False):
        if drop_doomed:
            for v in list(self.fleet.values()):
                if v.state == "doomed":
                    v.state = "terminated"
        n = len([i for i in self.fleet.values()
                 if i.state in ("running", "at_risk")])
        stages = self.cost.rescale(max(n, 1), store)
        self.rescales.append(dict(stages, reason=reason, t=t, n=n))
        self._stall(sum(stages.values()))
        self._log(t, f"rescale[{reason}] n={n} "
                     f"total={sum(stages.values()):.1f}s")
