"""CloudManager — proactive spot-instance management (paper §IV, Fig 4).

A deterministic discrete-event simulation of an EC2-style fleet (spot pools,
rebalance recommendations, 2-minute interruption notices, replacement launch
latency) driving an elastic application.  Interruptions can be injected
explicitly (the AWS Fault-Injection-Simulator analogue used in the paper's
experiments) or sampled.

Interruption-handling modes (§IV-C):

* ``Mode.A_FILESYSTEM`` — checkpoint to a shared filesystem on the notice;
  the app restarts from disk once capacity is back (3 stages: checkpoint /
  restart / restore; both ends scale with fleet size).
* ``Mode.B_REACTIVE``   — Bhosale et al. [6]: in-memory checkpoint; shrink
  before the deadline, then a second rescale (expand) when the replacement
  eventually launches.  Two full rescale cycles.
* ``Mode.C_PROACTIVE``  — this paper: capacity rebalancing.  Replacements are
  requested at the *rebalance recommendation*; the rescale is deferred until
  one of three trigger conditions (complete / emergency / T_timeout), so a
  single rescale swaps doomed instances for ready replacements.

Stage costs come from a ``StageCostModel`` fitted from *measured*
checkpoint/restore/restart timings on real pytrees (benchmarks/measure.py),
so the simulation reproduces the paper's Figures 5-8 quantitatively from
first-principles measurements rather than assumed constants.

Event plumbing lives in ``repro.runtime``: the manager registers named
handlers on a shared :class:`~repro.runtime.EventLoop` and consumes its
interruption schedule from a :class:`~repro.runtime.FaultTrace`, so a
serving cluster handed the *same* trace observes the identical
rebalance/notice/terminate timestamps.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime import EventLoop, FaultTrace, SpotEventFeed, SpotNotice

__all__ = ["Mode", "Instance", "StageCostModel", "SpotEventFeed",
           "SpotNotice", "RunReport", "CloudManager"]


class Mode(enum.Enum):
    A_FILESYSTEM = "A"
    B_REACTIVE = "B"
    C_PROACTIVE = "C"


# ------------------------------------------------------------------ fleet
@dataclasses.dataclass
class Instance:
    iid: int
    itype: str
    is_spot: bool = True
    state: str = "running"      # running | at_risk | doomed | terminated
    launched_at: float = 0.0


@dataclasses.dataclass
class StageCostModel:
    """Seconds per rescale stage as a function of fleet size n.

    Fitted from real measurements: checkpoint/restore scale with per-instance
    bytes (total/n for in-memory; total and shared-bandwidth-limited for
    filesystem), restart grows ~log(n) (startup), LB ~ bytes moved.
    """
    state_bytes: float                     # application state size
    host_bw: float = 8e9                   # host-RAM copy bytes/s ("shm")
    device_bw: float = 400e9               # on-device copy bytes/s (daemon)
    fs_bw: float = 0.35e9                  # shared-FS bytes/s (EFS elastic)
    restart_base: float = 4.0              # app startup, 1 instance
    restart_log: float = 1.2               # + log2(n) growth (paper Fig 5)
    restart_accel_extra: float = 9.0       # CUDA-init analogue (paper Fig 6)
    lb_frac: float = 0.3                   # fraction of state migrated by LB
    accelerator: bool = False

    def checkpoint(self, n: int, store: str) -> float:
        per_inst = self.state_bytes / max(n, 1)
        bw = {"memory": self.host_bw, "device": self.device_bw,
              "filesystem": self.fs_bw}[store]
        if store == "filesystem":
            # shared FS: aggregate bandwidth, grows with total size
            return self.state_bytes / bw / max(math.sqrt(n), 1.0)
        return per_inst / bw

    restore = checkpoint

    def restart(self, n: int) -> float:
        extra = self.restart_accel_extra if self.accelerator else 0.0
        return self.restart_base + extra + self.restart_log * math.log2(
            max(n, 2))

    def loadbalance(self, n: int, moved_frac: Optional[float] = None) -> float:
        frac = self.lb_frac if moved_frac is None else moved_frac
        bw = self.device_bw if self.accelerator else self.host_bw
        # migrating GPU-resident data without RDMA goes via host staging
        if self.accelerator:
            bw = self.host_bw * 2  # staged copies overlap both directions
        return frac * self.state_bytes / max(n, 1) / bw

    def rescale(self, n: int, store: str,
                lb_frac: Optional[float] = None) -> Dict[str, float]:
        return {
            "checkpoint": self.checkpoint(n, store),
            "loadbalance": 0.0 if store == "filesystem"
            else self.loadbalance(n, lb_frac),
            "restart": self.restart(n),
            "restore": self.restore(n, store),
        }


# ------------------------------------------------------------------ manager
@dataclasses.dataclass
class RunReport:
    total_time: float
    ideal_time: float
    rescales: List[Dict[str, float]]
    interruption_overhead: float
    timeline: List[Tuple[float, str]]

    @property
    def overhead_frac(self) -> float:
        return self.total_time / self.ideal_time - 1.0


class CloudManager:
    """Monitoring task + replacement policy + rescale triggers (Fig 4).

    The manager owns no event heap: it registers handlers on a
    ``repro.runtime.EventLoop`` and receives the spot lifecycle from a
    ``FaultTrace`` (its own by default; pass ``trace=`` to share one
    schedule with other subsystems, e.g. a serving cluster).
    """

    def __init__(self, *, n_instances: int, mode: Mode,
                 cost: StageCostModel,
                 t_timeout: float = 120.0,
                 replacement_latency: float = 90.0,
                 notice_deadline: float = 120.0,
                 rebalance_lead: float = 180.0,
                 iter_seconds: float = 1.0,
                 total_iters: int = 5000,
                 seed: int = 0,
                 trace: Optional[FaultTrace] = None):
        self.mode = mode
        self.cost = cost
        self.t_timeout = t_timeout
        self.replacement_latency = replacement_latency
        self.trace = trace if trace is not None else FaultTrace(
            rebalance_lead=rebalance_lead, notice_deadline=notice_deadline)
        self.notice_deadline = self.trace.notice_deadline
        self.rebalance_lead = self.trace.rebalance_lead
        self.iter_seconds = iter_seconds
        self.total_iters = total_iters
        self.target = n_instances
        self.rng = np.random.default_rng(seed)

        self._ids = itertools.count()
        self.fleet: Dict[int, Instance] = {
            (i := next(self._ids)): Instance(i, "spot.xlarge")
            for _ in range(n_instances)
        }
        self.loop = EventLoop()
        self.loop.register("spot", self._on_spot)
        self.loop.register("replacement", self._on_replacement)
        self.loop.register("timeout", self._on_timeout)
        self.trace.bind(self.loop, kind="spot")
        # lifecycle id -> victim iid: keyed per interruption, not per
        # target, because a sampled trace cycles target ids and the same
        # target can have overlapping lifecycles in flight
        self._victim_of: Dict[int, int] = {}
        self._fis_targets = itertools.count(10_000)
        self._oldest_rebalance: Optional[float] = None
        self._pending_replacements = 0
        self.timeline: List[Tuple[float, str]] = []
        self.rescales: List[Dict[str, float]] = []

    # ------------------------------------------------------------ events
    def inject_interruption(self, t: float, count: int = 1):
        """FIS analogue: at virtual time t, ``count`` running spot instances
        get a rebalance recommendation, followed by the 2-minute notice."""
        for _ in range(count):
            self.trace.inject(t, next(self._fis_targets))

    # ------------------------------------------------------------ dynamics
    def _running(self) -> List[Instance]:
        return [i for i in self.fleet.values() if i.state != "terminated"]

    def _at_risk(self) -> List[Instance]:
        return [i for i in self.fleet.values()
                if i.state in ("at_risk", "doomed")]

    def run(self) -> RunReport:
        """Simulate until the application completes ``total_iters``."""
        t = self.loop.now()
        work_done = 0.0
        work_total = float(self.total_iters)
        ideal = self.total_iters * self.iter_seconds
        stalled_until = 0.0
        overhead = 0.0

        def capacity() -> float:
            if self._down:  # Mode A: a terminated rank kills the whole job
                return 0.0
            n_up = len([i for i in self.fleet.values()
                        if i.state in ("running", "at_risk", "doomed")])
            return min(n_up, self.target) / self.target

        while work_done < work_total:
            # next event or completion, whichever first
            rate = capacity() / self.iter_seconds  # iters per second
            if stalled_until > t:
                t_free = stalled_until
            else:
                t_free = t
            if rate > 0:
                t_done = t_free + (work_total - work_done) / rate
            else:
                t_done = math.inf
            t_next = self.loop.peek_t()
            if t_done <= t_next:
                work_done = work_total
                t = t_done
                break
            # progress until the event, then dispatch its handler
            prog_start = max(t, stalled_until)
            if t_next > prog_start and rate > 0:
                work_done += (t_next - prog_start) * rate
            t = t_next
            self.loop.dispatch_next()
            # handlers may stall the app (rescale downtime)
            if self._stall_pending:
                stalled_until = max(stalled_until, t) + self._stall_pending
                overhead += self._stall_pending
                self._stall_pending = 0.0
            if self._mark_request:       # checkpoint: remember progress
                self._work_mark = work_done
                self._mark_request = False
            if self._rollback_request:   # rank death: lose work since ckpt
                work_done = min(work_done, self._work_mark)
                self._rollback_request = False

        return RunReport(
            total_time=t,
            ideal_time=ideal,
            rescales=self.rescales,
            interruption_overhead=overhead,
            timeline=self.timeline,
        )

    _stall_pending: float = 0.0
    _down: bool = False
    _mark_request: bool = False
    _rollback_request: bool = False
    _work_mark: float = 0.0

    def _stall(self, seconds: float):
        self._stall_pending += seconds

    def _log(self, t: float, msg: str):
        self.timeline.append((t, msg))

    # ------------------------------------------------------------ handlers
    def _on_spot(self, ev, t: float):
        """One §IV lifecycle event from the shared ``FaultTrace``."""
        notice: SpotNotice = ev.payload["notice"]
        if notice.kind == "rebalance_recommendation":
            victims = [i for i in self._running() if i.state == "running"]
            if not victims:
                return
            v = victims[0]
            self._victim_of[notice.lifecycle] = v.iid
            v.state = "at_risk"
            self._log(t, f"rebalance_recommendation i{v.iid}")
            if self._oldest_rebalance is None:
                self._oldest_rebalance = t
                if self.mode == Mode.C_PROACTIVE:
                    self.loop.schedule(t + self.t_timeout, "timeout",
                                       started=t)
            if self.mode == Mode.C_PROACTIVE:
                # proactively request a replacement from the pools
                self._pending_replacements += 1
                self.loop.schedule(t + self.replacement_latency,
                                   "replacement")
            return

        inst = self.fleet.get(self._victim_of.get(notice.lifecycle, -1))
        if inst is None or inst.state == "terminated":
            return

        if notice.kind == "interruption_notice":
            inst.state = "doomed"
            self._log(t, f"interruption_notice i{inst.iid}")
            if self.mode == Mode.C_PROACTIVE:
                # emergency override: rescale NOW with whatever is ready
                self._trigger_rescale(t, reason="emergency")
            elif self.mode == Mode.B_REACTIVE:
                # reactive shrink before the deadline + request replacement
                self._do_rescale(t, reason="shrink", store="memory",
                                 drop_doomed=True)
                self._pending_replacements += 1
                self.loop.schedule(t + self.replacement_latency,
                                   "replacement")
            else:  # Mode A: checkpoint to FS; app dies with the instance
                n = len(self._running())
                ck = self.cost.checkpoint(n, "filesystem")
                self._stall(ck)
                self._mark_request = True
                self._log(t, f"fs_checkpoint {ck:.1f}s")
                self._pending_replacements += 1
                self.loop.schedule(t + self.replacement_latency,
                                   "replacement")
            return

        if notice.kind == "terminate":
            inst.state = "terminated"
            self._log(t, f"terminated i{inst.iid}")
            if self.mode == Mode.A_FILESYSTEM:
                # rigid ranks: the whole job is down until fs_restart,
                # and loses all work since the last checkpoint
                self._down = True
                self._rollback_request = True
                self._log(t, "job_down (rigid MPI-style ranks)")
                self._maybe_fs_restart(t)
            return

        raise ValueError(notice.kind)

    def _on_replacement(self, ev, t: float):
        self._pending_replacements -= 1
        i = next(self._ids)
        self.fleet[i] = Instance(i, "spot.xlarge", launched_at=t)
        self.fleet[i].state = "spare" if self.mode == Mode.C_PROACTIVE \
            else "running"
        self._log(t, f"replacement_launched i{i}")
        if self.mode == Mode.C_PROACTIVE:
            # complete-replacement trigger
            n_spare = len([x for x in self.fleet.values()
                           if x.state == "spare"])
            if n_spare >= len(self._at_risk()) and self._at_risk():
                self._trigger_rescale(t, reason="complete")
        elif self.mode == Mode.B_REACTIVE:
            self._do_rescale(t, reason="expand", store="memory")
        else:  # Mode A: new rank available; restart when whole
            self._maybe_fs_restart(t)

    def _on_timeout(self, ev, t: float):
        if (self._oldest_rebalance is not None
                and ev.payload["started"] == self._oldest_rebalance
                and self._at_risk()):
            self._trigger_rescale(t, reason="timeout")

    def _maybe_fs_restart(self, t: float):
        """Mode A restart: needs all doomed ranks dead and full capacity."""
        if not self._down:
            return
        doomed_alive = any(i.state == "doomed" for i in self.fleet.values())
        n = len([x for x in self.fleet.values()
                 if x.state in ("running", "spare")])
        if doomed_alive or n < self.target:
            return
        for x in self.fleet.values():
            if x.state == "spare":
                x.state = "running"
        stages = {
            "restart": self.cost.restart(n),
            "restore": self.cost.restore(n, "filesystem"),
        }
        self.rescales.append(dict(stages, reason="fs_restart", t=t, n=n))
        self._stall(sum(stages.values()))
        self._down = False
        self._log(t, "fs_restart")

    # ------------------------------------------------------------ rescale
    def _trigger_rescale(self, t: float, reason: str):
        """Mode C single-rescale: swap doomed/at-risk for ready spares."""
        spares = [i for i in self.fleet.values() if i.state == "spare"]
        at_risk = self._at_risk()
        # replace as many as we have spares for; leftover at-risk keep running
        for v, s in zip(at_risk, spares):
            v.state = "terminated"
            s.state = "running"
        for v in at_risk[len(spares):]:
            if v.state == "at_risk":
                v.state = "running"   # not replaced; keeps running for now
        self._oldest_rebalance = None
        self._do_rescale(t, reason=f"proactive_{reason}", store="memory",
                         single=True)

    def _do_rescale(self, t: float, reason: str, store: str,
                    drop_doomed: bool = False, single: bool = False):
        if drop_doomed:
            for v in list(self.fleet.values()):
                if v.state == "doomed":
                    v.state = "terminated"
        n = len([i for i in self.fleet.values()
                 if i.state in ("running", "at_risk")])
        stages = self.cost.rescale(max(n, 1), store)
        self.rescales.append(dict(stages, reason=reason, t=t, n=n))
        self._stall(sum(stages.values()))
        self._log(t, f"rescale[{reason}] n={n} "
                     f"total={sum(stages.values()):.1f}s")
