"""Checkpoint stores — the paper's three interruption-handling substrates.

Paper mapping (DESIGN.md §2):

* ``InMemoryStore``   — Charm++'s Linux-shared-memory checkpoint (§II-B):
                        state pulled to host RAM; survives an application
                        "restart" (re-jit / mesh rebuild) within the job.
* ``DeviceStore``     — the GPU *daemon process* checkpoint (§IV-A, CUDA
                        IPC): TPU-idiomatic analogue keeps a second
                        device-resident copy so interruption handling never
                        crosses the host link (HBM-to-HBM copy).
* ``FilesystemStore`` — the traditional shared-filesystem checkpoint
                        (Mode A in §IV-C): serialize to disk (EFS analogue).

All stores checkpoint arbitrary pytrees of jax.Arrays and report per-stage
timings so the benchmark harness can reproduce Figures 5-7.
"""

from __future__ import annotations

import io
import pickle
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


class StageTimer:
    def __init__(self):
        self.stages: Dict[str, float] = {}

    def time(self, name: str):
        timer = self

        class _Ctx:
            def __enter__(self):
                self.t0 = time.perf_counter()
                return self

            def __exit__(self, *exc):
                timer.stages[name] = timer.stages.get(name, 0.0) + (
                    time.perf_counter() - self.t0)
        return _Ctx()


class InMemoryStore:
    """Host-RAM checkpoint (Linux shm analogue).

    ``save`` device_get's the state into host numpy buffers; ``restore``
    device_put's onto a (possibly different) mesh/sharding -- this is exactly
    the shrink/expand path of §II-B.
    """

    def __init__(self):
        self._data: Dict[str, Any] = {}
        self.timer = StageTimer()

    def save(self, name: str, state) -> float:
        with self.timer.time("checkpoint"):
            host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                state)
            self._data[name] = host
        return self.timer.stages["checkpoint"]

    def restore(self, name: str, shardings=None):
        with self.timer.time("restore"):
            host = self._data[name]
            if shardings is None:
                out = jax.tree.map(jnp.asarray, host)
            else:
                out = jax.tree.map(
                    lambda h, s: jax.device_put(h, s), host, shardings)
            out = jax.block_until_ready(out)
        return out

    def exists(self, name: str) -> bool:
        return name in self._data

    def nbytes(self, name: str) -> int:
        return sum(x.nbytes for x in jax.tree.leaves(self._data[name]))

    def drop(self, name: str):
        self._data.pop(name, None)


class DeviceStore:
    """Device-resident checkpoint replica (daemon-process analogue).

    The copy stays in device memory (a distinct donated-safe buffer), so a
    checkpoint/restore never crosses the host link -- mirroring the paper's
    observation that GDDR6-local daemon copies beat host DDR4 staging.
    """

    def __init__(self):
        self._data: Dict[str, Any] = {}
        self.timer = StageTimer()

    @staticmethod
    def _copy(x):
        # materialize an independent device buffer
        return jax.block_until_ready(x + jnp.zeros((), x.dtype))

    def save(self, name: str, state) -> float:
        with self.timer.time("checkpoint"):
            self._data[name] = jax.block_until_ready(
                jax.tree.map(self._copy, state))
        return self.timer.stages["checkpoint"]

    def restore(self, name: str, shardings=None):
        with self.timer.time("restore"):
            snap = self._data[name]
            if shardings is None:
                out = jax.tree.map(self._copy, snap)
            else:
                out = jax.tree.map(lambda h, s: jax.device_put(h, s),
                                   snap, shardings)
            out = jax.block_until_ready(out)
        return out

    def exists(self, name: str) -> bool:
        return name in self._data

    def nbytes(self, name: str) -> int:
        return sum(x.nbytes for x in jax.tree.leaves(self._data[name]))

    def drop(self, name: str):
        self._data.pop(name, None)


class FilesystemStore:
    """Shared-filesystem checkpoint (Mode A / EFS analogue)."""

    def __init__(self, root: Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.timer = StageTimer()

    def _path(self, name: str) -> Path:
        return self.root / f"{name}.ckpt"

    def save(self, name: str, state) -> float:
        with self.timer.time("checkpoint"):
            host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                state)
            leaves, treedef = jax.tree.flatten(host)
            with open(self._path(name), "wb") as f:
                pickle.dump({"treedef": treedef, "leaves": leaves}, f,
                            protocol=4)
        return self.timer.stages["checkpoint"]

    def restore(self, name: str, shardings=None):
        with self.timer.time("restore"):
            with open(self._path(name), "rb") as f:
                blob = pickle.load(f)
            host = jax.tree.unflatten(blob["treedef"], blob["leaves"])
            if shardings is None:
                out = jax.tree.map(jnp.asarray, host)
            else:
                out = jax.tree.map(lambda h, s: jax.device_put(h, s),
                                   host, shardings)
            out = jax.block_until_ready(out)
        return out

    def exists(self, name: str) -> bool:
        return self._path(name).exists()

    def nbytes(self, name: str) -> int:
        return self._path(name).stat().st_size

    def drop(self, name: str):
        self._path(name).unlink(missing_ok=True)


def make_store(kind: str, root: Optional[Path] = None):
    if kind == "memory":
        return InMemoryStore()
    if kind == "device":
        return DeviceStore()
    if kind == "filesystem":
        return FilesystemStore(root or Path("/tmp/repro_ckpt"))
    raise ValueError(kind)
