"""The paper's contribution: adaptive runtime for cloud-native HPC.

- overdecomp:    chare-style tile runtime (C1)
- rates:         measured per-PE rate EWMA
- loadbalance:   Greedy / GreedyRefine, rate-aware (C2)
- elastic:       shrink/expand via in-memory checkpoint (II-B)
- checkpointing: memory / device / filesystem stores (C3, C5)
- cloud:         CloudManager with capacity rebalancing (C4)
- spmd_stencil:  TPU-production shard_map stencil path
"""
