"""Version-compat shims for fast-moving jax APIs.

The repo targets current jax, but CI / dev containers pin older releases;
every shim here prefers the modern spelling and falls back.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """``jax.shard_map`` (new) or ``jax.experimental.shard_map`` (<=0.4)."""
    modern = getattr(jax, "shard_map", None)
    if modern is not None:
        return modern(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
    from jax.experimental.shard_map import shard_map as legacy
    if "check_vma" in kwargs:  # renamed from check_rep
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kwargs)
