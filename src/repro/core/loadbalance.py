"""Load-balancing strategies (Charm++ suite, rate-aware).

Objects (chares/tiles) carry measured loads; PEs carry measured rates.  A
strategy returns an assignment ``obj -> pe`` minimizing the *rate-weighted*
makespan  max_pe( sum_{obj on pe} load(obj) / rate(pe) ).

Strategies:

* ``greedy``        — classic Charm++ GreedyLB: heaviest object to the PE
                      that finishes it earliest. Ignores current placement
                      (migrates nearly everything).
* ``greedy_refine`` — the paper's GreedyRefine: keep objects home unless a
                      PE is overloaded; move the minimum number of objects
                      from overloaded PEs to the least-loaded PEs. Minimizes
                      migrations and preserves communication locality.
* ``none``          — identity (the paper's no-LB baseline).

All strategies are rate-aware iff given non-uniform ``rates``; with
rates=None they reduce to the homogeneous Charm++ equivalents.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class LBResult:
    assignment: np.ndarray          # (n_objs,) -> pe
    migrations: int                 # objs moved vs current placement
    makespan: float                 # rate-weighted
    baseline_makespan: float        # makespan of the input placement


def _makespan(assignment, loads, rates, base=None) -> float:
    n_pes = len(rates)
    per_pe = np.zeros(n_pes) if base is None \
        else np.asarray(base, dtype=np.float64).copy()
    np.add.at(per_pe, assignment, loads)
    return float((per_pe / rates).max())


def _norm_base(base, n_pes) -> np.ndarray:
    if base is None:
        return np.zeros(n_pes)
    b = np.asarray(base, dtype=np.float64)
    assert len(b) == n_pes
    return b


def _norm_rates(rates, n_pes) -> np.ndarray:
    if rates is None:
        return np.ones(n_pes)
    r = np.asarray(rates, dtype=np.float64)
    assert len(r) == n_pes
    return np.maximum(r, 1e-9)


def greedy(loads: Sequence[float], n_pes: int,
           rates: Optional[Sequence[float]] = None,
           current: Optional[Sequence[int]] = None,
           base: Optional[Sequence[float]] = None) -> LBResult:
    """GreedyLB: heaviest-first onto earliest-finishing PE.

    ``base`` is optional non-migratable load already committed to each PE
    (e.g. in-flight serving requests pinned to their replica); PEs start
    from ``base[pe]/rates[pe]`` instead of zero.
    """
    loads = np.asarray(loads, dtype=np.float64)
    rates = _norm_rates(rates, n_pes)
    base = _norm_base(base, n_pes)
    order = np.argsort(-loads)
    finish = [(base[pe] / rates[pe], pe) for pe in range(n_pes)]
    heapq.heapify(finish)
    assignment = np.zeros(len(loads), dtype=np.int64)
    for obj in order:
        t, pe = heapq.heappop(finish)
        assignment[obj] = pe
        heapq.heappush(finish, (t + loads[obj] / rates[pe], pe))
    cur = (np.asarray(current, dtype=np.int64) if current is not None
           else assignment)
    return LBResult(
        assignment=assignment,
        migrations=int((assignment != cur).sum()),
        makespan=_makespan(assignment, loads, rates, base),
        baseline_makespan=_makespan(cur, loads, rates, base),
    )


def greedy_refine(loads: Sequence[float], n_pes: int,
                  rates: Optional[Sequence[float]] = None,
                  current: Optional[Sequence[int]] = None,
                  tolerance: float = 1.05,
                  base: Optional[Sequence[float]] = None) -> LBResult:
    """GreedyRefine: migrate as few objects as possible.

    PEs with scaled load above ``tolerance * ideal`` donate their smallest
    objects; donations go to the PE that would finish them earliest.
    ``base`` is non-migratable per-PE load (see ``greedy``).
    """
    loads = np.asarray(loads, dtype=np.float64)
    n_objs = len(loads)
    rates = _norm_rates(rates, n_pes)
    base = _norm_base(base, n_pes)
    if current is None:
        # no placement yet: fall back to greedy (initial map)
        return greedy(loads, n_pes, rates, base=base)
    assignment = np.asarray(current, dtype=np.int64).copy()
    baseline = _makespan(assignment, loads, rates, base)

    per_pe = base.copy()
    np.add.at(per_pe, assignment, loads)
    scaled = per_pe / rates
    ideal = (loads.sum() + base.sum()) / rates.sum()
    threshold = tolerance * ideal

    # objects on overloaded PEs, lightest first (cheapest migrations first)
    donors = [pe for pe in range(n_pes) if scaled[pe] > threshold]
    moved = 0
    for pe in sorted(donors, key=lambda q: -scaled[q]):
        objs = [o for o in np.nonzero(assignment == pe)[0]]
        objs.sort(key=lambda o: loads[o])
        for o in objs:
            if scaled[pe] <= threshold:
                break
            # candidate receiver: minimal scaled load after receiving
            cand = np.argmin((per_pe + loads[o]) / rates)
            if cand == pe:
                break
            new_scaled = (per_pe[cand] + loads[o]) / rates[cand]
            if new_scaled >= scaled[pe]:   # would not help
                continue
            assignment[o] = cand
            per_pe[pe] -= loads[o]
            per_pe[cand] += loads[o]
            scaled[pe] = per_pe[pe] / rates[pe]
            scaled[cand] = per_pe[cand] / rates[cand]
            moved += 1
    return LBResult(
        assignment=assignment,
        migrations=moved,
        makespan=_makespan(assignment, loads, rates, base),
        baseline_makespan=baseline,
    )


def no_lb(loads: Sequence[float], n_pes: int,
          rates: Optional[Sequence[float]] = None,
          current: Optional[Sequence[int]] = None) -> LBResult:
    loads = np.asarray(loads, dtype=np.float64)
    rates = _norm_rates(rates, n_pes)
    if current is None:
        current = np.arange(len(loads)) % n_pes     # block-cyclic home
    cur = np.asarray(current, dtype=np.int64)
    ms = _makespan(cur, loads, rates)
    return LBResult(cur, 0, ms, ms)


STRATEGIES = {
    "greedy": greedy,
    "greedy_refine": greedy_refine,
    "none": no_lb,
}


def balance(strategy: str, loads, n_pes, rates=None, current=None,
            **kw) -> LBResult:
    return STRATEGIES[strategy](loads, n_pes, rates=rates, current=current,
                                **kw)
