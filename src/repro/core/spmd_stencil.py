"""SPMD overdecomposed stencil — the TPU-production Jacobi2D path.

Each device owns ``odf`` tiles of the global grid (1-D ring decomposition by
row-blocks); halo exchange crosses devices with ``jax.lax.ppermute`` inside
``shard_map`` while intra-device tile boundaries are handled locally.  With
odf > 1 XLA's latency-hiding scheduler can overlap a tile's ppermute with
the other tiles' compute — the Charm++ Fig-1 overlap, TPU-native.

Used by: examples/jacobi_spmd.py, the multi-device elastic test, and the
dry-run (it lowers/compiles on the production meshes).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _tile_step(tile, up_row, down_row):
    """Jacobi update for a (rows, W) tile given exterior halo rows."""
    upper = jnp.concatenate([up_row[None], tile[:-1]], axis=0)
    lower = jnp.concatenate([tile[1:], down_row[None]], axis=0)
    left = jnp.pad(tile[:, :-1], ((0, 0), (1, 0)))
    right = jnp.pad(tile[:, 1:], ((0, 0), (0, 1)))
    return 0.25 * (upper + lower + left + right)


def make_jacobi_spmd_step(mesh: Mesh, *, axis: str = "data", odf: int = 4,
                          n_iters: int = 1):
    """Returns a jitted step: grid (n_dev*odf*rows, W) -> same, n_iters
    Jacobi sweeps with ppermute halo exchange.

    The grid is sharded by row-blocks over ``axis``; each device's block is
    further split into ``odf`` tiles so the boundary exchange of one tile can
    overlap the interior compute of others.
    """
    n_dev = mesh.shape[axis]

    def local_sweep(block, top_halo, bot_halo):
        """block: (odf, rows, W) local tiles; halos: (W,) from neighbors."""
        odf_, rows, W = block.shape
        # stitched view of tile boundary rows
        ups = jnp.concatenate(
            [top_halo[None], block[:-1, -1, :]], axis=0)     # (odf, W)
        downs = jnp.concatenate(
            [block[1:, 0, :], bot_halo[None]], axis=0)       # (odf, W)
        return jax.vmap(_tile_step)(block, ups, downs)

    def step(grid):
        def inner(block):
            # block: (n_dev*odf*rows, W) / n_dev on this device
            rows_total, W = block.shape
            rows = rows_total // odf
            tiles = block.reshape(odf, rows, W)

            def one_iter(tiles, _):
                # exchange device-boundary rows around the ring
                top_edge = tiles[0, 0, :]      # goes to previous device
                bot_edge = tiles[-1, -1, :]    # goes to next device
                fwd = [(i, (i + 1) % n_dev) for i in range(n_dev)]
                bwd = [(i, (i - 1) % n_dev) for i in range(n_dev)]
                top_halo = jax.lax.ppermute(bot_edge, axis, fwd)
                bot_halo = jax.lax.ppermute(top_edge, axis, bwd)
                # fixed boundary conditions at the global top/bottom
                idx = jax.lax.axis_index(axis)
                top_halo = jnp.where(idx == 0,
                                     jnp.ones_like(top_halo), top_halo)
                bot_halo = jnp.where(idx == n_dev - 1,
                                     jnp.zeros_like(bot_halo), bot_halo)
                return local_sweep(tiles, top_halo, bot_halo), ()

            tiles, _ = jax.lax.scan(one_iter, tiles, None, length=n_iters)
            return tiles.reshape(rows_total, W)

        from repro.core.compat import shard_map
        return shard_map(
            inner, mesh=mesh, in_specs=P(axis, None),
            out_specs=P(axis, None))(grid)

    sharding = NamedSharding(mesh, P(axis, None))
    return jax.jit(step, in_shardings=sharding, out_shardings=sharding)


def reference_jacobi(grid, n_iters: int):
    """Single-device oracle with the same boundary conditions."""
    def one(g, _):
        up = jnp.concatenate([jnp.ones((1, g.shape[1]), g.dtype), g[:-1]])
        down = jnp.concatenate([g[1:], jnp.zeros((1, g.shape[1]), g.dtype)])
        left = jnp.pad(g[:, :-1], ((0, 0), (1, 0)))
        right = jnp.pad(g[:, 1:], ((0, 0), (0, 1)))
        return 0.25 * (up + down + left + right), ()
    out, _ = jax.lax.scan(one, grid, None, length=n_iters)
    return out
