"""Rate monitoring — the measured inputs to rate-aware load balancing.

Charm++'s runtime records per-PE load and speed; here a ``RateMonitor``
keeps an EWMA of measured per-PE throughput (work-units/second).  On
heterogeneous cloud fleets the *rates differ per instance type* (paper
§III-B); the balancer consumes ``rates()``, never ground-truth hardware
specs -- stragglers and multi-tenant jitter show up the same way.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


class RateMonitor:
    def __init__(self, n_pes: int, alpha: float = 0.3):
        self.n_pes = n_pes
        self.alpha = alpha
        self._rate = np.ones(n_pes, dtype=np.float64)
        self._seen = np.zeros(n_pes, dtype=bool)

    def record(self, pe: int, work_units: float, seconds: float):
        if seconds <= 0:
            return
        r = work_units / seconds
        if not self._seen[pe]:
            self._rate[pe] = r
            self._seen[pe] = True
        else:
            self._rate[pe] = (1 - self.alpha) * self._rate[pe] + \
                self.alpha * r

    def record_step(self, per_pe_work: Sequence[float],
                    per_pe_seconds: Sequence[float]):
        for pe, (w, s) in enumerate(zip(per_pe_work, per_pe_seconds)):
            self.record(pe, w, s)

    def rates(self) -> np.ndarray:
        """Normalized rates (mean 1.0). Unseen PEs assume average speed."""
        r = self._rate.copy()
        if self._seen.any():
            r[~self._seen] = r[self._seen].mean()
        return r / max(r.mean(), 1e-12)

    def resize(self, n_pes: int):
        """Elastic shrink/expand keeps overlapping PE history."""
        old_r, old_s = self._rate, self._seen
        self._rate = np.ones(n_pes, dtype=np.float64)
        self._seen = np.zeros(n_pes, dtype=bool)
        n = min(n_pes, len(old_r))
        self._rate[:n] = old_r[:n]
        self._seen[:n] = old_s[:n]
        self.n_pes = n_pes

    def straggler_pes(self, threshold: float = 0.7) -> List[int]:
        """PEs persistently slower than ``threshold`` x mean rate."""
        r = self.rates()
        return [int(i) for i in np.nonzero(r < threshold)[0]]
