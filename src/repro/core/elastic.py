"""Elastic runtime: shrink / expand via in-memory checkpoint + reshard (§II-B).

Charm++ rescaling protocol, step for step:

  1. migrate work away from departing PEs   (implicit: resharding does this)
  2. checkpoint to Linux shared memory      -> ``store.save`` (host RAM)
  3. restart with the new PE count          -> rebuild Mesh + re-jit
  4. restore state                          -> ``store.restore`` with the new
                                               shardings (device_put reshards)
  5. load balance                           -> LB step / sharding rules already
                                               balance SPMD work

Stage timings are recorded per rescale so the benchmark harness reproduces
the paper's four-bar breakdown (checkpoint / load balance / restart /
restore, Figures 5-6).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.checkpointing import InMemoryStore


@dataclasses.dataclass
class RescaleEvent:
    kind: str                 # 'shrink' | 'expand'
    from_devices: int
    to_devices: int
    stages: Dict[str, float]  # checkpoint/loadbalance/restart/restore seconds

    @property
    def total(self) -> float:
        return sum(self.stages.values())


class ElasticRuntime:
    """Wraps a jit-able step function with shrink/expand over device subsets.

    ``mesh_factory(n_devices)``   -> Mesh using the first n devices
    ``shardings_factory(mesh)``   -> (in_shardings pytree for the state)
    ``step_factory(mesh)``        -> jitted step fn(state, batch)

    The runtime owns the current mesh/state and performs the 5-stage
    rescale protocol; the CloudManager calls ``rescale_to``.
    """

    def __init__(self, *, mesh_factory, shardings_factory, step_factory,
                 init_state, n_devices: int,
                 store: Optional[InMemoryStore] = None):
        self.mesh_factory = mesh_factory
        self.shardings_factory = shardings_factory
        self.step_factory = step_factory
        self.store = store or InMemoryStore()
        self.events: List[RescaleEvent] = []
        self.n_devices = n_devices
        self.mesh = mesh_factory(n_devices)
        self.shardings = shardings_factory(self.mesh)
        self._step = step_factory(self.mesh)
        self.state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), init_state, self.shardings)

    def step(self, batch):
        self.state, out = self._step(self.state, batch)
        return out

    def rescale_to(self, n_devices: int) -> RescaleEvent:
        kind = "shrink" if n_devices < self.n_devices else "expand"
        stages: Dict[str, float] = {}

        t0 = time.perf_counter()
        self.store.save("elastic", self.state)
        stages["checkpoint"] = time.perf_counter() - t0

        # "restart": tear down the old executable, rebuild mesh + re-jit.
        t0 = time.perf_counter()
        del self._step
        self.mesh = self.mesh_factory(n_devices)
        self.shardings = self.shardings_factory(self.mesh)
        self._step = self.step_factory(self.mesh)
        stages["restart"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        self.state = self.store.restore("elastic", self.shardings)
        stages["restore"] = time.perf_counter() - t0

        # post-expand LB step (§II-B): for SPMD state the resharding already
        # rebalances; we account the explicit device_put-based rebalance pass.
        t0 = time.perf_counter()
        self.state = jax.block_until_ready(self.state)
        stages["loadbalance"] = time.perf_counter() - t0

        ev = RescaleEvent(kind, self.n_devices, n_devices, stages)
        self.n_devices = n_devices
        self.events.append(ev)
        return ev
