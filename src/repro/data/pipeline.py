"""Deterministic synthetic LM data pipeline.

Goals: (a) reproducible across restarts — a shrink/expand or spot
interruption must resume on exactly the batch it would have seen (the
elastic test asserts bit-continuity); (b) shardable — batches are produced
host-side and device_put with the run's batch sharding; (c) prefetchable.

The "dataset" is a deterministic token stream keyed by (seed, step): a
counter-mode PRNG, so batch(step) never depends on history.  Real corpora
slot in behind the same interface.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model_zoo import batch_spec


class SyntheticLM:
    """Counter-mode synthetic batches matching ``batch_spec(cfg, shape)``."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.spec = batch_spec(cfg, shape)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        out = {}
        for i, (k, v) in enumerate(sorted(self.spec.items())):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, i]))
            if np.issubdtype(np.dtype(v.dtype), np.integer):
                out[k] = rng.integers(0, self.cfg.vocab_size, v.shape,
                                      dtype=np.int32)
            else:
                out[k] = rng.standard_normal(v.shape, dtype=np.float32) \
                    .astype(v.dtype)
        return out

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch + device_put with target shardings."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 shardings: Optional[Any] = None, depth: int = 2):
        self.source = source
        self.shardings = shardings
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            if self.shardings is not None:
                batch = jax.tree.map(jax.device_put, batch, self.shardings)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def next(self):
        step, batch = self._q.get()
        return step, batch

    def stop(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
