"""Pure-jnp oracle for the Jacobi stencil kernel."""
import jax.numpy as jnp


def jacobi_step_ref(grid):
    """One 5-point Jacobi sweep; BCs: top halo = 1.0, others 0.0."""
    up = jnp.concatenate([jnp.ones((1, grid.shape[1]), grid.dtype),
                          grid[:-1]], axis=0)
    down = jnp.concatenate([grid[1:],
                            jnp.zeros((1, grid.shape[1]), grid.dtype)],
                           axis=0)
    left = jnp.pad(grid[:, :-1], ((0, 0), (1, 0)))
    right = jnp.pad(grid[:, 1:], ((0, 0), (0, 1)))
    return 0.25 * (up + down + left + right)
