"""jit'd public entry point for the Jacobi Pallas kernel.

On CPU (this container) the kernel body executes in interpret mode; on TPU
it compiles to Mosaic.  ``impl='ref'`` selects the pure-jnp oracle.
"""
import functools

import jax

from repro.kernels.jacobi.kernel import jacobi_step
from repro.kernels.jacobi.ref import jacobi_step_ref


@functools.partial(jax.jit, static_argnames=("impl", "block_rows"))
def jacobi(grid, *, impl: str = "auto", block_rows: int = 128):
    if impl == "ref":
        return jacobi_step_ref(grid)
    interpret = jax.default_backend() == "cpu"
    return jacobi_step(grid, block_rows=block_rows, interpret=interpret)
