from repro.kernels.jacobi.ops import jacobi
from repro.kernels.jacobi.ref import jacobi_step_ref
