"""Pallas TPU kernel: 5-point Jacobi stencil (the paper's benchmark app).

TPU adaptation of the paper's manually-tiled CPU/GPU loop: the grid is
row-block tiled into VMEM; halo rows come from *neighbor row-blocks* mapped
as two extra (block-granular) input views — prev/cur/next — since Pallas
BlockSpecs index at block granularity.  Left/right halos are handled
in-register by column shifts.  Boundary conditions (hot top edge = 1.0,
others 0.0) are applied via program_id masking.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_kernel(prev_ref, cur_ref, next_ref, out_ref, *, bh: int):
    i = pl.program_id(0)
    n_blocks = pl.num_programs(0)
    cur = cur_ref[...]                       # (bh, W)

    # halo rows from the neighbor blocks (index maps clamp at the edges)
    up_row = prev_ref[bh - 1, :]
    down_row = next_ref[0, :]
    # global boundary conditions
    up_row = jnp.where(i == 0, jnp.ones_like(up_row), up_row)
    down_row = jnp.where(i == n_blocks - 1, jnp.zeros_like(down_row),
                         down_row)

    up = jnp.concatenate([up_row[None, :], cur[:-1]], axis=0)
    down = jnp.concatenate([cur[1:], down_row[None, :]], axis=0)
    left = jnp.pad(cur[:, :-1], ((0, 0), (1, 0)))
    right = jnp.pad(cur[:, 1:], ((0, 0), (0, 1)))
    out_ref[...] = 0.25 * (up + down + left + right)


def jacobi_step(grid: jax.Array, *, block_rows: int = 128,
                interpret: bool = False) -> jax.Array:
    """One Jacobi sweep over a (H, W) grid.

    VMEM working set = 4 row-blocks (prev/cur/next/out) of (block_rows, W)
    fp32; choose block_rows so 4 * block_rows * W * 4B fits ~16 MiB.
    """
    H, W = grid.shape
    bh = min(block_rows, H)
    assert H % bh == 0, (H, bh)
    nb = H // bh

    prev_spec = pl.BlockSpec((bh, W),
                             lambda i: (jnp.maximum(i - 1, 0), 0))
    cur_spec = pl.BlockSpec((bh, W), lambda i: (i, 0))
    next_spec = pl.BlockSpec((bh, W),
                             lambda i: (jnp.minimum(i + 1, nb - 1), 0))

    return pl.pallas_call(
        functools.partial(_jacobi_kernel, bh=bh),
        grid=(nb,),
        in_specs=[prev_spec, cur_spec, next_spec],
        out_specs=pl.BlockSpec((bh, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), grid.dtype),
        interpret=interpret,
    )(grid, grid, grid)
