"""Oracle for the flash-attention kernel: the model's own attention paths.

``blockwise_attention`` (layers.py) is itself validated against
``full_attention``; the Pallas kernel is validated against both.
"""
import jax.numpy as jnp

from repro.models.layers import blockwise_attention, full_attention


def flash_ref(q, k, v, *, causal=True):
    """q: (B, H, S, D) heads-major -> (B, H, S, D), via full_attention."""
    qm = jnp.moveaxis(q, 1, 2)   # (B, S, H, D)
    km = jnp.moveaxis(k, 1, 2)
    vm = jnp.moveaxis(v, 1, 2)
    out = full_attention(qm, km, vm, causal=causal)
    return jnp.moveaxis(out, 2, 1)
