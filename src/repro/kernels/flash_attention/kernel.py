"""Pallas TPU kernel: blockwise causal flash attention with GQA.

Grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the kv axis is the
innermost ("arbitrary") dimension so the online-softmax state for one
(b, h, iq) lives in VMEM scratch across kv iterations.  Causal blocks with
ik > iq are skipped with ``pl.when`` (true block skipping — ~2x fewer FLOPs
than masked-compute).  GQA: the kv BlockSpec index map folds the q-head ->
kv-head mapping (h // group), so no repeated KV materialization.

VMEM working set: q(bq,d) + k,v(bkv,d) + acc(bq,d)f32 + m,l(bq,1)f32.
bq = bkv = 512, d = 128: ~0.9 MiB — well under 16 MiB, MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_kv: int, causal: bool, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    should_run = (ik * block_kv <= iq * block_q + block_q - 1) \
        if causal else True

    @pl.when(should_run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)       # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            kpos = ik * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                        # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)            # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_kv: int = 512, interpret: bool = False):
    """q: (B, H, S, D); k/v: (B, KV, S, D) — heads-major layout.

    Returns (B, H, S, D).
    """
    b, h, sq, d = q.shape
    kv = k.shape[1]
    sk = k.shape[2]
    group = h // kv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, sk)
    assert sq % block_q == 0 and sk % block_kv == 0
    nq, nk = sq // block_q, sk // block_kv
    scale = d ** -0.5

    kernel = functools.partial(_flash_kernel, block_q=block_q,
                               block_kv=block_kv, causal=causal,
                               scale=scale)
    from jax.experimental.pallas import tpu as pltpu
    # renamed TPUCompilerParams -> CompilerParams across pallas releases
    compiler_params_cls = getattr(pltpu, "CompilerParams", None) \
        or pltpu.TPUCompilerParams
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=compiler_params_cls(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q.reshape(b, h, nq * block_q, d),
      k, v)
