"""jit'd flash-attention entry point (model layout: (B, S, H, D))."""
import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_ref


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_kv", "impl"))
def attention(q, k, v, *, causal=True, block_q=512, block_kv=512,
              impl="auto"):
    """q: (B, S, H, D); k/v: (B, S, KV, D) -> (B, S, H, D)."""
    qm = jnp.moveaxis(q, 1, 2)
    km = jnp.moveaxis(k, 1, 2)
    vm = jnp.moveaxis(v, 1, 2)
    if impl == "ref":
        out = flash_ref(qm, km, vm, causal=causal)
    else:
        interpret = jax.default_backend() == "cpu"
        out = flash_attention(qm, km, vm, causal=causal, block_q=block_q,
                              block_kv=block_kv, interpret=interpret)
    return jnp.moveaxis(out, 1, 2)
