"""Oracle: the pure-jnp intra-chunk SSD from the model itself."""
from repro.models.mamba2 import ssd_intra_chunk_ref
