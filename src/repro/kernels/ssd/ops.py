"""jit'd SSD intra-chunk entry point (used by mamba2_block(impl='pallas'))."""
import functools

import jax

from repro.kernels.ssd.kernel import ssd_intra_chunk as _kernel
from repro.kernels.ssd.ref import ssd_intra_chunk_ref


@functools.partial(jax.jit, static_argnames=("impl",))
def ssd_intra_chunk(xr, dtr, dA_cs, Br, Cr, impl: str = "auto"):
    if impl == "ref":
        return ssd_intra_chunk_ref(xr, dtr, dA_cs, Br, Cr)
    interpret = jax.default_backend() == "cpu"
    return _kernel(xr, dtr, dA_cs, Br, Cr, interpret=interpret)
