"""Pallas TPU kernel: Mamba2 SSD intra-chunk block (state-space duality).

Computes, per (batch, chunk, head):

  y_diag[i] = sum_{j<=i} (C_i . B_j) * exp(dAcs_i - dAcs_j) * dt_j * x_j
  state     = sum_j exp(dAcs_last - dAcs_j) * dt_j * B_j (x) x_j

i.e. the quadratic-within-chunk half of SSD; the (cheap) inter-chunk state
recurrence stays a lax.scan in mamba2.py.  The kernel is matmul-dominated
((l,l) x (l,p) on the MXU), which is exactly the SSD paper's point.

Grid = (B, NC, H); blocks carry one chunk of one head:
l=256, p<=128, n<=128 fp32 -> ~0.6 MiB VMEM working set.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, dacs_ref, b_ref, c_ref, y_ref, st_ref):
    # blocks: x (1,1,l,1,p); dt/dacs (1,1,l,1); b/c (1,1,l,n)
    x = x_ref[0, 0, :, 0, :]          # (l, p)
    dt = dt_ref[0, 0, :, 0]           # (l,)
    dacs = dacs_ref[0, 0, :, 0]       # (l,)
    B = b_ref[0, 0]                   # (l, n)
    C = c_ref[0, 0]                   # (l, n)
    l = x.shape[0]

    seg = dacs[:, None] - dacs[None, :]               # (l, l) i - j
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    decay = jnp.where(jj <= ii, jnp.exp(seg), 0.0)    # causal within chunk
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (l, l)
    att = cb * decay
    xdt = x * dt[:, None]                             # (l, p)
    y_ref[0, 0, :, 0, :] = jax.lax.dot_general(
        att, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    w = jnp.exp(dacs[l - 1] - dacs)                   # (l,)
    bw = B * w[:, None]                               # (l, n); dt already in xdt
    # state (p, n) = xdt^T @ bw
    st_ref[0, 0, 0, :, :] = jax.lax.dot_general(
        xdt, bw, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def ssd_intra_chunk(xr, dtr, dA_cs, Br, Cr, *, interpret: bool = False):
    """xr: (b,nc,l,h,p) f32; dtr/dA_cs: (b,nc,l,h); Br/Cr: (b,nc,l,n).

    Returns y_diag (b,nc,l,h,p), states (b,nc,h,p,n) — the same contract as
    ``repro.models.mamba2.ssd_intra_chunk_ref``.
    """
    b, nc, l, h, p = xr.shape
    n = Br.shape[-1]

    grid = (b, nc, h)
    y, st = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, l, 1, p), lambda ib, ic, ih: (ib, ic, 0, ih, 0)),
            pl.BlockSpec((1, 1, l, 1), lambda ib, ic, ih: (ib, ic, 0, ih)),
            pl.BlockSpec((1, 1, l, 1), lambda ib, ic, ih: (ib, ic, 0, ih)),
            pl.BlockSpec((1, 1, l, n), lambda ib, ic, ih: (ib, ic, 0, 0)),
            pl.BlockSpec((1, 1, l, n), lambda ib, ic, ih: (ib, ic, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, l, 1, p),
                         lambda ib, ic, ih: (ib, ic, 0, ih, 0)),
            pl.BlockSpec((1, 1, 1, p, n),
                         lambda ib, ic, ih: (ib, ic, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, l, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xr, dtr, dA_cs, Br, Cr)
    return y, st
