"""Oracle for the paged-attention kernel: gather + the model's own math.

The reference gathers each lane's blocks (via its block table) into the
contiguous ``(B, S, KV, D)`` layout the dense cache uses and runs the
exact ``full_attention`` call from ``layers.decode_attention``.  Because
positions at or beyond ``kv_len`` are masked to an exact-zero softmax
weight, the gathered garbage in unallocated / sentinel blocks
contributes nothing and the result is *bit-identical* to the dense
decode path — this is the property the serving engine's paged mode
leans on for bit-identical output streams.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import full_attention


def gather_pages(pool, block_tables):
    """(num_blocks, bs, ...) + (B, max_blocks) -> (B, max_blocks*bs, ...).

    Sentinel / out-of-range table entries are clamped into the pool (the
    caller masks those positions via ``kv_len``), so a partially filled
    table is safe to gather.
    """
    nb = pool.shape[0]
    bt = jnp.clip(block_tables, 0, nb - 1)
    rows = pool[bt]                       # (B, max_blocks, bs, ...)
    b, mb, bs = rows.shape[:3]
    return rows.reshape((b, mb * bs) + rows.shape[3:])


def paged_attention_ref(q, k_pool, v_pool, block_tables, kv_len):
    """q: (B, H, D) one decode token per lane; pools: (num_blocks, bs,
    KV, D); block_tables: (B, max_blocks) int32; kv_len: (B,) valid
    positions per lane.  Returns (B, H, D)."""
    k = gather_pages(k_pool, block_tables)
    v = gather_pages(v_pool, block_tables)
    out = full_attention(q[:, None], k, v, causal=False, kv_len=kv_len)
    return out[:, 0]
