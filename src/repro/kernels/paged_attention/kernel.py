"""Pallas TPU kernel: paged-attention decode over a block-pooled KV cache.

One query token per lane attends over its lane's KV blocks, addressed
through a scalar-prefetched block table (vLLM-style paging).  Grid =
(batch, kv_heads, max_blocks); the block axis is the innermost
("arbitrary") dimension so the online-softmax state for one (lane, head)
lives in VMEM scratch across block iterations.  The block table and the
per-lane valid length ride in as scalar-prefetch operands
(``PrefetchScalarGridSpec``): the kv BlockSpec index map reads
``block_tables[lane, j]`` to pull the j-th logical block's *physical*
pool row into VMEM — no gather materialization.

Blocks wholly past ``kv_len`` are skipped with ``pl.when`` (true block
skipping); the partial tail block masks positions >= kv_len to an
exact-zero softmax weight.  GQA: q is laid out (B, KV, G, D) so one grid
cell covers a kv head's whole query group.

VMEM working set: q(G,d) + k,v(bs,d) + acc(G,d)f32 + m,l(G,1)f32 — tiny;
the pool itself stays in HBM and only table-addressed blocks move.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_kernel(bt_ref, kl_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, block_size: int, scale: float):
    ib = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    klen = kl_ref[ib]

    @pl.when(j * block_size < klen)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, d)
        k = k_ref[0, :, 0].astype(jnp.float32)       # (bs, d)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < klen, s, NEG_INF)
        m_prev = m_ref[...]                           # (G, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention(q, k_pool, v_pool, block_tables, kv_len, *,
                    interpret: bool = False):
    """q: (B, H, D); pools: (num_blocks, bs, KV, D); block_tables:
    (B, max_blocks) int32 physical pool rows (pre-clamped into range);
    kv_len: (B,) int32 valid positions per lane.  Returns (B, H, D).
    """
    b, h, d = q.shape
    nb, bs, kv, _ = k_pool.shape
    group = h // kv
    max_blocks = block_tables.shape[1]
    scale = d ** -0.5
    qg = q.reshape(b, kv, group, d)

    kernel = functools.partial(_paged_kernel, block_size=bs, scale=scale)
    from jax.experimental.pallas import tpu as pltpu
    # renamed TPUCompilerParams -> CompilerParams across pallas releases
    compiler_params_cls = getattr(pltpu, "CompilerParams", None) \
        or pltpu.TPUCompilerParams
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, group, d),
                         lambda ib, ih, j, bt, kl: (ib, ih, 0, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda ib, ih, j, bt, kl: (bt[ib, j], 0, ih, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda ib, ih, j, bt, kl: (bt[ib, j], 0, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda ib, ih, j, bt, kl: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, group, d), q.dtype),
        compiler_params=compiler_params_cls(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), kv_len.astype(jnp.int32),
      qg, k_pool, v_pool)
    return out.reshape(b, h, d)
