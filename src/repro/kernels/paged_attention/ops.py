"""jit'd paged-attention entry point (decode layout: one token per lane).

``impl`` dispatch mirrors ``kernels.flash_attention.ops``:

* ``"ref"``    — gather-through-the-block-table + ``full_attention``;
  *bit-identical* to the dense decode path (the serving engine's paged
  mode uses this on CPU backends so paged and dense engines emit the
  same token streams).
* ``"kernel"`` — the Pallas kernel (interpret-mode off TPU), validated
  against the ref in tests.
* ``"auto"``   — kernel on TPU, ref elsewhere (interpret-mode Pallas in
  the fused decode hot loop would be pure overhead on CPU).
"""
import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention as \
    _paged_kernel
from repro.kernels.paged_attention.ref import gather_pages, \
    paged_attention_ref


@functools.partial(jax.jit, static_argnames=("impl",))
def paged_attention(q, k_pool, v_pool, block_tables, kv_len, *,
                    impl: str = "auto"):
    """q: (B, H, D); pools: (num_blocks, bs, KV, D); block_tables:
    (B, max_blocks) int32 (sentinel entries allowed — clamped here);
    kv_len: (B,) int32.  Returns (B, H, D)."""
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return paged_attention_ref(q, k_pool, v_pool, block_tables, kv_len)
    nb = k_pool.shape[0]
    bt = jnp.clip(block_tables, 0, nb - 1)
    interpret = jax.default_backend() != "tpu"
    return _paged_kernel(q, k_pool, v_pool, bt, kv_len,
                         interpret=interpret)
