from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import (gather_pages,
                                               paged_attention_ref)
