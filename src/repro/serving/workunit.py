"""WorkUnit: the migratable unit of in-flight serving work.

The paper's central abstraction is the migratable object (a Charm++
chare) with one uniform pack/unpack (PUP) interface: load balancing,
spot-drain and elastic rescaling are all the *same* mechanism applied
under different policies.  ``WorkUnit`` is that abstraction for serving:
an in-flight request checkpointed into a self-contained, migratable
value.

One verb set everywhere (engine, replica, cluster):

* ``pack(slots) -> [WorkUnit]``   — checkpoint + release occupied slots;
* ``unpack(units)``               — admit units into any engine built
                                    from the same ``(cfg, max_seq)``;
* ``preempt(slots) -> [WorkUnit]``— pause slots (slot freed, snapshot
                                    retained); units come back PAUSED;
* ``resume(units)``               — re-admit paused units; the decoded
                                    stream continues bit-identically.

``pack``/``preempt`` are mechanically the same checkpoint; the verbs
differ in intent and bookkeeping — a packed unit is in transit to
another host (migration/drain), a paused unit is parked to free capacity
(SLO-aware preemption) and stays accounted to its origin until resumed.
Because the checkpoint is exact (cache columns + progress counters, see
``SlotSnapshot``), any interleaving of the four verbs round-trips to an
identical greedy token stream — property-tested in
``tests/test_workunit.py``.  The snapshot's cache columns are always
*canonical contiguous* (full ``max_seq`` sequence axes), independent of
the source engine's cache mode: paged engines gather their blocks into
that layout on ``pack`` and re-block on ``unpack``, so a unit moves
freely between dense and paged engines — including paged engines with
different block sizes.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, List, Optional

from repro.serving.engine import (DEFAULT_PREFILL_DISCOUNT, Request,
                                  SlotSnapshot)

# Lifecycle states a unit can be observed in between engines.
PACKED = "packed"        # checkpointed for migration (drain / rebalance)
PAUSED = "paused"        # preempted: parked to free capacity, not in transit

# Payload residency: which store class the unit's cache columns last
# round-tripped through (the ``MigrationEndpoint`` stamps this).
RESIDENCY_NONE = "none"      # packed straight from the engine, not staged
RESIDENCY_HOST = "host"      # host-RAM store (Linux-shm analogue, §II-B)
RESIDENCY_DEVICE = "device"  # device-resident store (daemon analogue, §IV-A)

_UIDS = itertools.count()


@dataclasses.dataclass(frozen=True)
class Hop:
    """One provenance entry: what happened to a unit, where, and when."""
    rid: int        # replica involved
    t: float        # virtual time of the event
    reason: str     # interruption | scale_down | rebalance | preempt |
                    # land | resume


@dataclasses.dataclass
class WorkUnit:
    """A migratable chare: checkpointed request + identity + residency.

    ``snapshot`` is the exact resume payload (``SlotSnapshot``: request,
    progress counters, this slot's cache columns as host arrays).  The
    rest is control-plane metadata: a stable identity across hops, the
    unit's lifecycle state, where its payload currently resides, and
    provenance — ``uid`` survives re-packing on a destination engine
    (the engine remembers which unit each restored slot came from), and
    ``hops`` accumulates one :class:`Hop` per control-plane move, so a
    spot-drain -> fallback -> rebalance chain is traceable end-to-end.
    """

    snapshot: SlotSnapshot
    uid: int = dataclasses.field(default_factory=lambda: next(_UIDS))
    state: str = PACKED             # PACKED | PAUSED
    residency: str = RESIDENCY_NONE
    origin: Optional[int] = None    # replica rid that first packed the unit
    packed_t: Optional[float] = None  # virtual time of the checkpoint
    hops: List[Hop] = dataclasses.field(default_factory=list)

    # --------------------------------------------------------- provenance
    def record_hop(self, rid: int, t: float, reason: str):
        """Append one provenance entry (cluster layer: it knows time)."""
        self.hops.append(Hop(rid, float(t), reason))

    @property
    def n_hops(self) -> int:
        return len(self.hops)

    # ------------------------------------------------------------ payload
    @property
    def request(self) -> Request:
        return self.snapshot.request

    @property
    def rid(self) -> int:
        return self.snapshot.request.rid

    @property
    def slo(self) -> Optional[Any]:
        """The request's ``SLOClass`` (None = cluster default)."""
        return self.snapshot.request.slo

    @property
    def slo_name(self) -> str:
        slo = self.snapshot.request.slo
        return slo.name if slo is not None else "standard"

    @property
    def preemptible(self) -> bool:
        """Lazily-admitted (batch) classes may be paused to free capacity."""
        slo = self.snapshot.request.slo
        return bool(slo is not None and slo.admit_lazily)

    # ----------------------------------------------------------- progress
    @property
    def progress(self) -> int:
        """Measured progress: prompt+generated tokens already in cache."""
        return self.snapshot.fed

    @property
    def remaining_tokens(self) -> int:
        return self.snapshot.remaining_tokens

    def remaining_cost(self,
                       discount: float = DEFAULT_PREFILL_DISCOUNT) -> float:
        """Remaining discounted load (the router/rebalancer signal)."""
        return self.snapshot.remaining_cost(discount)
