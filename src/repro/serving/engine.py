"""Serving engine: continuous-batching decode over the model zoo.

Small but real: request queue, slot-based batching (a fixed decode batch of
``batch_size`` slots; finished sequences release their slot to the next
request), chunked bulk prefill, greedy or temperature sampling.  The decode
step is the same ``serve_step`` the dry run lowers at 32k/500k scale.

The hot path is built around three properties:

* **Chunked bulk prefill** — a request is admitted by running
  ``make_prefill`` over a fixed padded chunk bucket (one jitted function
  per bucket size, bounding recompiles) and scattering the resulting
  cache columns into the slot, instead of streaming one prompt token per
  decode step.  A P-token prompt costs one prefill dispatch (plus a
  streamed tail for prompts longer than the largest bucket) rather than
  P full-batch decode dispatches.  Under greedy decoding the bulk path
  is bit-identical to the streamed baseline (``prefill_mode="streamed"``),
  asserted in tests; with ``temperature > 0`` the two modes consume
  different numbers of rng splits (streaming burns one per prompt token)
  so their samples differ.
* **Sync-free batched decode** — ``step_many(k)`` runs k fused
  sample-and-advance steps (``make_decode_loop``) in ONE dispatch with a
  donated device-resident ``SampleState``: next-token feedback, the
  active mask, per-slot progress and the generated-token buffer all stay
  on device.  The host tracks progress with an *exact* projection (each
  active slot advances one token per step until its precomputed
  ``maxfed``), so steady-state decode performs **zero device->host
  transfers**; ``out_buf`` is fetched only when the projection says a
  slot completed, or at a drain.  ``host_syncs`` counts every fetch.
* **Migratable work units** — ``pack()`` captures each occupied slot
  (request progress + that slot's KV/state cache columns, as host
  arrays) into a self-contained ``WorkUnit``; ``unpack()`` admits units
  into any engine built from the same ``(cfg, max_seq)`` — including
  mid-prefill-chunk.  ``preempt()``/``resume()`` are the same checkpoint
  under pause semantics (slot freed, snapshot retained, bit-identical
  stream on resume).  This one PUP-style verb set is the substrate for
  every control-plane move: spot-drain, mid-stream rebalancing, and
  SLO-aware preemption (paper §III–IV applied to serving).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model_zoo as zoo

# Padded prompt-chunk sizes for bulk prefill.  Ascending; buckets larger
# than the engine's cache are dropped at construction.  One compiled
# prefill per surviving bucket per (cfg, engine shape).
DEFAULT_PREFILL_BUCKETS: Tuple[int, ...] = (16, 64, 256)

# Relative cost of one bulk-prefilled prompt token vs one decode step.
# Bulk prefill amortizes weight reads over the whole chunk, so a prefill
# token is far cheaper than a decode token; the router and the cluster's
# virtual-time accounting both use this factor.
DEFAULT_PREFILL_DISCOUNT = 0.35


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (len,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # SLO metadata (an ``repro.serving.workload.SLOClass``; None = the
    # cluster's default class) + the model pool this request must run on.
    slo: Optional[Any] = None
    model_id: str = "default"
    arrival_t: Optional[float] = None   # stamped by the cluster's arrival

    @property
    def total_tokens(self) -> int:
        """Token-units of work: prompt + planned new tokens (LB load)."""
        return len(self.prompt) + self.max_new_tokens

    def deadline_t(self, default: float = float("inf")) -> float:
        """Absolute completion deadline (inf when class-less/unarrived)."""
        if self.slo is None or self.arrival_t is None:
            return default
        return self.arrival_t + self.slo.deadline


def _deprecated(old: str, new: str):
    warnings.warn(
        f"{old} is deprecated; use the WorkUnit verb {new} instead",
        DeprecationWarning, stacklevel=3)


def request_cost(req: Request,
                 discount: float = DEFAULT_PREFILL_DISCOUNT) -> float:
    """Router load of an unstarted request, with prefill discounted.

    Prompt tokens are bulk-prefilled (cheap); only the decode tokens cost
    a full step each.  The last prompt token doubles as the first decode
    feed, so ``len(prompt) - 1`` tokens ride the discounted prefill path.
    """
    return max(len(req.prompt) - 1, 0) * discount + req.max_new_tokens


@dataclasses.dataclass
class SlotSnapshot:
    """A checkpointed in-flight request: enough to resume decode anywhere."""
    request: Request
    fed: int                    # prompt+generated tokens already in cache
    next_tok: int               # next token to feed
    cache_len: int
    cache: Dict[str, np.ndarray]  # this slot's cache columns (host)

    @property
    def remaining_tokens(self) -> int:
        return max(self.request.total_tokens - self.fed, 1)

    def remaining_cost(self,
                       discount: float = DEFAULT_PREFILL_DISCOUNT) -> float:
        """Remaining load with the not-yet-fed prefill part discounted."""
        rem = self.remaining_tokens
        rem_prefill = min(max(len(self.request.prompt) - 1 - self.fed, 0),
                          rem)
        return rem_prefill * discount + (rem - rem_prefill)


# One jitted fn per (cfg, shape[, bucket/block]): replicas in a cluster
# share the compiled graphs instead of recompiling per engine.
_LOOP_CACHE: Dict[Tuple[ModelConfig, ShapeConfig, int, float,
                        Optional[int]], Any] = {}
_PREFILL_CACHE: Dict[Tuple[ModelConfig, ShapeConfig, int], Any] = {}


def _shared_loop(cfg: ModelConfig, shape: ShapeConfig, n_steps: int,
                 temperature: float, eos_token: Optional[int] = None):
    key = (cfg, shape, n_steps, float(temperature), eos_token)
    if key not in _LOOP_CACHE:
        _LOOP_CACHE[key] = jax.jit(
            zoo.make_decode_loop(cfg, shape, n_steps, temperature,
                                 eos_token=eos_token),
            donate_argnums=(1, 2))
    return _LOOP_CACHE[key]


def _shared_bulk_prefill(cfg: ModelConfig, shape: ShapeConfig, chunk: int):
    key = (cfg, shape, chunk)
    if key not in _PREFILL_CACHE:
        _PREFILL_CACHE[key] = jax.jit(
            zoo.make_bulk_prefill(cfg, shape, chunk), donate_argnums=(1,))
    return _PREFILL_CACHE[key]


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 4,
                 max_seq: int = 128, temperature: float = 0.0, seed: int = 0,
                 prefill_mode: str = "chunked",
                 prefill_buckets: Tuple[int, ...] = DEFAULT_PREFILL_BUCKETS,
                 prefill_discount: float = DEFAULT_PREFILL_DISCOUNT,
                 decode_block: int = 8, eos_token: Optional[int] = None):
        if prefill_mode not in ("chunked", "streamed"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.temperature = temperature
        self.prefill_mode = prefill_mode
        self.prefill_discount = prefill_discount
        self.decode_block = max(int(decode_block), 1)
        # device-side EOS early exit: a slot that samples this token
        # clears its own active flag inside the fused loop.  The host
        # projection can no longer predict completion, so eos engines
        # reconcile against device truth after every window (one fetch
        # per window instead of zero; the saved fused steps dominate).
        self.eos_token = eos_token
        self.shape = ShapeConfig("serve", max_seq, batch_size, "decode")
        self.state = zoo.init_decode_state(cfg, self.shape, fill_len=0)
        self.sample = zoo.init_sample_state(cfg, self.shape, seed=seed)
        self._prompt_buf = jnp.zeros((batch_size, max_seq), jnp.int32)
        self._slots: List[Optional[Request]] = [None] * batch_size
        self._queue: List[Request] = []
        self._restore: List["WorkUnit"] = []
        # per-slot provenance of restored units: slot -> (uid, hops,
        # origin).  ``pack`` re-uses it so a unit keeps ONE identity and
        # one hop history across any number of pack->unpack round trips.
        self._unit_meta: Dict[int, Tuple[int, list, Optional[int]]] = {}
        self._completed: List[Request] = []
        # exact host mirrors of the device progress counters: advanced by
        # projection after every decode window, overwritten with device
        # truth at every poll
        self._fed = np.zeros(batch_size, np.int64)
        self._plen = np.ones(batch_size, np.int64)
        self._maxfed = np.zeros(batch_size, np.int64)
        self._next_tok_host = np.zeros(batch_size, np.int64)
        self._out_read = np.zeros(batch_size, np.int64)
        self.processed_tokens = 0   # prefill + decode work units (rate feed)
        self.host_syncs = 0         # device->host fetches (poll/drain only)
        self.chunk_prefills = 0     # bulk prefill dispatches issued
        self.preemptions = 0        # slots paused via preempt()
        self.resumes = 0            # paused units re-admitted via resume()
        self._chunk_tokens_pending = 0
        if prefill_mode == "chunked" and cfg.family in zoo.BULK_PREFILL_FAMILIES:
            self._buckets = tuple(sorted(
                c for c in prefill_buckets if 0 < c <= max_seq))
        else:
            self._buckets = ()
        if not self._buckets:
            # no bulk path (streamed mode / family without a token-only
            # prefill): every prompt token costs a full decode step, so
            # backlog must not discount prefill work
            self.prefill_discount = 1.0
        # per-leaf batch axis of the cache pytree (slot slicing/placement)
        self._cache_axes = {
            k: ax.index("cache_batch")
            for k, ax in zoo.decode_state_logical_axes(cfg).cache.items()}

    # ------------------------------------------------------------- requests
    def submit(self, req: Request):
        if len(req.prompt) > self.max_seq - 1:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"cannot fit a max_seq={self.max_seq} cache")
        self._queue.append(req)

    def reclaim_queue(self) -> List[Request]:
        """Hand not-yet-admitted requests back (router re-dispatch)."""
        queued, self._queue = self._queue, []
        return queued

    def pop_completed(self) -> List[Request]:
        done, self._completed = self._completed, []
        return done

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self._slots)

    @property
    def n_queued(self) -> int:
        return len(self._queue) + len(self._restore)

    @property
    def free_slots(self) -> int:
        return self.batch - self.n_active

    def fed_tokens(self, slot: int) -> int:
        """Tokens already in ``slot``'s cache (exact, no device sync)."""
        return int(self._fed[slot])

    def queued_requests(self) -> Tuple[Request, ...]:
        """Accepted-but-unadmitted requests (control-plane visibility)."""
        return tuple(self._queue)

    def slot_requests(self) -> List[Tuple[int, Request]]:
        """Per occupied slot: (slot, request) — the preemptor's victim
        candidates, alongside ``slot_costs`` for their remaining load."""
        return [(i, r) for i, r in enumerate(self._slots) if r is not None]

    def backlog_tokens(self) -> float:
        """Remaining load across slots + queue (the router's signal).

        Prefill-remaining tokens are weighted by ``prefill_discount``:
        they are bulk-prefilled in one dispatch, so counting them 1:1
        with decode tokens would overstate the load of prompt-heavy
        engines and mis-steer the rate-aware router.
        """
        d = self.prefill_discount
        load = sum(cost for _, cost in self.slot_costs())
        load += sum(u.snapshot.remaining_cost(d) for u in self._restore)
        load += sum(request_cost(r, d) for r in self._queue)
        return load

    def restore_costs(self, discount: Optional[float] = None) -> List[float]:
        """Remaining discounted load per not-yet-admitted restore-queue
        unit (they claim free slots ahead of fresh work — the router's
        slot-availability simulation must count them)."""
        d = self.prefill_discount if discount is None else discount
        return [u.snapshot.remaining_cost(d) for u in self._restore]

    def slot_costs(self) -> List[Tuple[int, float]]:
        """Per occupied slot: (slot, remaining discounted load).

        The cluster's rebalancer uses this to pick migration victims —
        the slot with the most remaining work moves the most load per
        snapshot/restore round-trip.
        """
        d = self.prefill_discount
        out = []
        for slot, req in enumerate(self._slots):
            if req is None:
                continue
            rem = max(int(self._maxfed[slot] - self._fed[slot]), 1)
            rem_prefill = min(
                max(int(self._plen[slot] - 1 - self._fed[slot]), 0), rem)
            out.append((slot, rem_prefill * d + (rem - rem_prefill)))
        return out

    # ------------------------------------------------------------ admission
    def _pick_chunk(self, n_prefill: int) -> Tuple[int, int]:
        """Bulk-prefill bucket for ``n_prefill`` prompt tokens.

        Returns ``(bucket, n_real)`` — ``bucket`` = 0 means stream.
        Pad-safe (causal attention) families take the smallest bucket
        that covers the prompt and right-pad it; recurrent families take
        the largest fully-real bucket so no pad token ever enters the
        state recurrence.
        """
        if not self._buckets or n_prefill <= 0:
            return 0, 0
        if self.cfg.family in zoo.PAD_SAFE_FAMILIES:
            for c in self._buckets:
                if c >= n_prefill:
                    return c, n_prefill
            return self._buckets[-1], self._buckets[-1]
        best = 0
        chunk = max(self.cfg.ssm_chunk, 1)
        for c in self._buckets:
            if c <= n_prefill and (c <= chunk or c % chunk == 0):
                best = c
        return best, best

    def _set_cache_len(self, slot: int, value: int):
        self.state = zoo.DecodeState(
            self.state.cache, self.state.cache_len.at[slot].set(value))

    def _set_sample_row(self, slot: int, *, next_tok: int, fed: int,
                        plen: int, maxfed: int, active: int = 1):
        s = self.sample
        self.sample = zoo.SampleState(
            next_tok=s.next_tok.at[slot, 0].set(next_tok),
            active=s.active.at[slot].set(active),
            fed=s.fed.at[slot].set(fed),
            plen=s.plen.at[slot].set(plen),
            maxfed=s.maxfed.at[slot].set(maxfed),
            out_buf=s.out_buf.at[slot].set(0),
            rng=s.rng)
        self._fed[slot] = fed
        self._plen[slot] = plen
        self._maxfed[slot] = maxfed
        self._next_tok_host[slot] = next_tok

    def _set_prompt_row(self, slot: int, prompt: np.ndarray):
        row = np.zeros(self.max_seq, np.int32)
        row[:len(prompt)] = prompt
        self._prompt_buf = self._prompt_buf.at[slot].set(jnp.asarray(row))

    def _admit_fresh(self, req: Request, slot: int):
        P = len(req.prompt)
        maxfed = min(P + req.max_new_tokens - 1, self.max_seq - 1)
        self._set_prompt_row(slot, req.prompt)
        chunk, n_real = self._pick_chunk(P - 1)
        if chunk:
            bulk = _shared_bulk_prefill(self.cfg, self.shape, chunk)
            ctoks = np.zeros((1, chunk), np.int32)
            ctoks[0, :n_real] = req.prompt[:n_real]
            self.state = bulk(self.params, self.state, jnp.asarray(ctoks),
                              np.int32(slot), np.int32(n_real))
            self.chunk_prefills += 1
            self._chunk_tokens_pending += n_real
        else:
            self._set_cache_len(slot, 0)
        self._slots[slot] = req
        self._out_read[slot] = 0
        self._set_sample_row(slot, next_tok=int(req.prompt[n_real]),
                             fed=n_real, plen=P, maxfed=maxfed)

    def _install(self, snap: SlotSnapshot, slot: int):
        """Write a snapshot's cache columns into ``slot`` and resume it."""
        new_cache = {}
        for k, arr in self.state.cache.items():
            ax = self._cache_axes[k]
            idx = [slice(None)] * arr.ndim
            idx[ax] = slot
            new_cache[k] = arr.at[tuple(idx)].set(
                jnp.asarray(snap.cache[k], arr.dtype))
        self.state = zoo.DecodeState(new_cache, self.state.cache_len)
        self._set_cache_len(slot, snap.cache_len)
        req = snap.request
        maxfed = min(len(req.prompt) + req.max_new_tokens - 1,
                     self.max_seq - 1)
        self._set_prompt_row(slot, req.prompt)
        self._slots[slot] = req
        self._out_read[slot] = len(req.out_tokens)
        self._set_sample_row(slot, next_tok=snap.next_tok, fed=snap.fed,
                             plen=len(req.prompt), maxfed=maxfed)

    def _admit(self):
        """Fill free slots from the restore queue, then the request queue."""
        for slot in range(self.batch):
            if self._slots[slot] is not None:
                continue
            if self._restore:
                u = self._restore.pop(0)
                self._install(u.snapshot, slot)
                # keep the unit's identity alive on the slot: a later
                # pack() re-emits the SAME uid and extends the same hop
                # history (the list object is shared, so provenance
                # recorded while the slot runs lands on the right unit)
                self._unit_meta[slot] = (u.uid, u.hops, u.origin)
            elif self._queue:
                self._admit_fresh(self._queue.pop(0), slot)

    # ------------------------------------------------------------- stepping
    def step_many(self, n_steps: int) -> Dict[str, int]:
        """Admit, then run ``n_steps`` fused decode steps in ONE dispatch.

        Returns ``{"steps", "emitted", "processed", "chunk_tokens"}``.
        ``processed`` counts work units fed this call (bulk-prefilled
        chunk tokens + per-step feeds); ``emitted`` counts generated
        tokens.  Both come from the host-side exact projection — the
        device is polled only when the projection says a slot finished.
        """
        self._chunk_tokens_pending = 0
        self._admit()
        chunk_tokens = self._chunk_tokens_pending
        stats = {"steps": 0, "emitted": 0, "processed": chunk_tokens,
                 "chunk_tokens": chunk_tokens}
        occupied = [i for i, r in enumerate(self._slots) if r is not None]
        if not occupied:
            self.processed_tokens += stats["processed"]
            return stats
        before = {slot: int(self._fed[slot]) for slot in occupied}
        loop = _shared_loop(self.cfg, self.shape, n_steps, self.temperature,
                            self.eos_token)
        self.state, self.sample = loop(self.params, self.state, self.sample,
                                       self._prompt_buf)
        stats["steps"] = n_steps
        if self.eos_token is not None:
            # EOS can end a slot at any inner step, invisibly to the host
            # projection: reconcile against device truth every window
            # (``_poll`` reads fed/active, harvests finished slots).
            self._poll()
            for slot in occupied:
                after = int(self._fed[slot])
                plen = int(self._plen[slot])
                stats["processed"] += after - before[slot]
                stats["emitted"] += (max(0, after - plen + 1)
                                     - max(0, before[slot] - plen + 1))
            self.processed_tokens += stats["processed"]
            return stats
        done_any = False
        for slot in occupied:
            after = min(before[slot] + n_steps, int(self._maxfed[slot]))
            self._fed[slot] = after
            plen = int(self._plen[slot])
            stats["processed"] += after - before[slot]
            stats["emitted"] += (max(0, after - plen + 1)
                                 - max(0, before[slot] - plen + 1))
            if after >= self._maxfed[slot]:
                done_any = True
        self.processed_tokens += stats["processed"]
        if done_any:
            self._poll()
        return stats

    def step(self) -> int:
        """One engine step (admit + ONE fused decode); returns tokens
        emitted (generated tokens only — prefill doesn't count)."""
        return self.step_many(1)["emitted"]

    def run_until_idle(self, max_steps: int = 10_000) -> Dict[str, float]:
        t0 = time.perf_counter()
        tokens = 0
        steps = 0
        while (any(r is not None for r in self._slots) or self._queue
               or self._restore) and steps < max_steps:
            block = min(self.decode_block, max_steps - steps)
            out = self.step_many(block)
            tokens += out["emitted"]
            steps += max(out["steps"], 1)
        dt = time.perf_counter() - t0
        return {"tokens": tokens, "steps": steps, "seconds": dt,
                "tok_per_s": tokens / max(dt, 1e-9)}

    # ----------------------------------------------------------- host sync
    def _fetch(self, tree):
        """The ONLY device->host path in the engine (counted)."""
        self.host_syncs += 1
        return jax.device_get(tree)

    def _poll(self):
        """Materialize device progress into the Request objects.

        Called when the projection says a slot completed, and at drains —
        never in the steady-state decode loop.
        """
        occupied = [i for i, r in enumerate(self._slots) if r is not None]
        if not occupied:
            return
        out_buf, fed, next_tok, active = self._fetch(
            (self.sample.out_buf, self.sample.fed, self.sample.next_tok,
             self.sample.active))
        for slot in occupied:
            req = self._slots[slot]
            self._fed[slot] = int(fed[slot])
            self._next_tok_host[slot] = int(next_tok[slot, 0])
            n = max(0, int(fed[slot]) - int(self._plen[slot]) + 1)
            new = out_buf[slot, int(self._out_read[slot]):n]
            req.out_tokens.extend(int(t) for t in new)
            self._out_read[slot] = n
            # a device-deactivated occupied slot is finished — either it
            # reached maxfed, or it sampled the EOS token and early-exited
            if fed[slot] >= self._maxfed[slot] or int(active[slot]) == 0:
                req.done = True
                self._completed.append(req)
                self._slots[slot] = None
                self._unit_meta.pop(slot, None)

    # ----------------------------------------------- WorkUnit pack/unpack
    #
    # One verb set for every in-flight-request move (the paper's PUP
    # interface): ``pack``/``unpack`` for migration and drain,
    # ``preempt``/``resume`` for SLO-aware pausing.  The old
    # snapshot_slots/restore_slots/drain names are deprecated shims.

    def _snapshot_slots(self, slots: Optional[List[int]] = None
                        ) -> List[Tuple[int, SlotSnapshot]]:
        """Checkpoint and release occupied slots (the PUP 'pack' step).

        ``slots`` restricts the checkpoint to a subset (the rebalancer's
        mid-stream migration and the preemptor pick single victims);
        None takes every occupied slot.  Works at any point in a
        request's life — including right after a bulk prefill chunk,
        before the prompt is fully fed.  Returns ``(slot, snapshot)``
        pairs so ``pack`` can look up per-slot unit provenance.
        """
        self._poll()
        occupied = [i for i, r in enumerate(self._slots)
                    if r is not None and (slots is None or i in slots)]
        if not occupied:
            return []
        cache_host = {k: np.asarray(v)
                      for k, v in self._fetch(self.state.cache).items()}
        snaps = []
        deactivate = self.sample.active
        for slot in occupied:
            snaps.append((slot, SlotSnapshot(
                request=self._slots[slot],
                fed=int(self._fed[slot]),
                next_tok=int(self._next_tok_host[slot]),
                cache_len=int(self._fed[slot]),
                cache={k: v.take(slot, axis=self._cache_axes[k])
                       for k, v in cache_host.items()},
            )))
            self._slots[slot] = None
            deactivate = deactivate.at[slot].set(0)
        self.sample = self.sample._replace(active=deactivate)
        return snaps

    def pack(self, slots: Optional[List[int]] = None) -> List["WorkUnit"]:
        """Checkpoint + release occupied slots as migratable ``WorkUnit``s.

        A packed unit is self-contained: ``unpack`` admits it into any
        engine built from the same ``(cfg, max_seq)`` and the greedy
        stream continues bit-identically.  A slot that was itself
        restored from a unit re-emits that unit's ``uid``, hop history
        and origin — identity is per in-flight request, not per
        checkpoint, so multi-hop migration chains stay traceable.
        """
        from repro.serving.workunit import WorkUnit
        units = []
        for slot, snap in self._snapshot_slots(slots):
            meta = self._unit_meta.pop(slot, None)
            if meta is None:
                units.append(WorkUnit(snapshot=snap))
            else:
                uid, hops, origin = meta
                units.append(WorkUnit(snapshot=snap, uid=uid, hops=hops,
                                      origin=origin))
        return units

    def unpack(self, units: List["WorkUnit"]):
        """Queue packed units for admission (cache written on admit).

        Unpacked units are admitted into free slots ahead of fresh
        queued requests, so migrated/resumed work never starves behind
        new arrivals.
        """
        self._restore.extend(units)

    def slot_provenance(self) -> Dict[int, Tuple[int, Tuple["Hop", ...]]]:
        """Per restored slot: ``(unit uid, hop history so far)`` — the
        observability window onto in-flight migration chains."""
        return {slot: (uid, tuple(hops))
                for slot, (uid, hops, _origin) in self._unit_meta.items()}

    def preempt(self, slots: Optional[List[int]] = None) -> List["WorkUnit"]:
        """Pause slots mid-stream: slot freed, snapshot retained.

        Mechanically a ``pack``, but the units come back ``PAUSED`` —
        parked by a preemption policy to free capacity for more urgent
        work, not in transit to another host.  ``resume`` continues the
        decoded stream bit-identically (asserted in tests).
        """
        from repro.serving.workunit import PAUSED
        units = self.pack(slots)
        for u in units:
            u.state = PAUSED
        self.preemptions += len(units)
        return units

    def resume(self, units: List["WorkUnit"]):
        """Re-admit paused units (the other half of ``preempt``)."""
        from repro.serving.workunit import PACKED
        for u in units:
            u.state = PACKED
        self.resumes += len(units)
        self.unpack(units)

    def drain_units(self) -> Tuple[List["WorkUnit"], List[Request]]:
        """Empty the engine: packed in-flight work + the untouched queue.

        Not-yet-admitted units waiting in the restore queue ride along
        as-is — same objects, same uids — so a drained engine hands back
        everything it owned without laundering identities.
        """
        units = self.pack()
        units.extend(self._restore)
        self._restore = []
        queued, self._queue = self._queue, []
        return units, queued

    # ------------------------------------------------- deprecated verbs
    def snapshot_slots(self, slots: Optional[List[int]] = None
                       ) -> List[SlotSnapshot]:
        """Deprecated: use ``pack(slots)`` (returns ``WorkUnit``s)."""
        _deprecated("snapshot_slots", "pack")
        return [u.snapshot for u in self.pack(slots)]

    def restore_slots(self, snapshots: List[SlotSnapshot]):
        """Deprecated: use ``unpack(units)``."""
        from repro.serving.workunit import WorkUnit
        _deprecated("restore_slots", "unpack")
        self._restore.extend(WorkUnit(snapshot=s) for s in snapshots)

    def drain(self) -> Tuple[List[SlotSnapshot], List[Request]]:
        """Deprecated: use ``drain_units()`` (returns ``WorkUnit``s)."""
        _deprecated("drain", "drain_units")
        units, queued = self.drain_units()
        return [u.snapshot for u in units], queued
