"""Serving engine: continuous-batching decode over the model zoo.

Small but real: request queue, slot-based batching (a fixed decode batch of
``batch_size`` slots; finished sequences release their slot to the next
request), chunked bulk prefill, greedy or temperature sampling.  The decode
step is the same ``serve_step`` the dry run lowers at 32k/500k scale.

The hot path is built around three properties:

* **Chunked bulk prefill** — a request is admitted by running
  ``make_prefill`` over a fixed padded chunk bucket (one jitted function
  per bucket size, bounding recompiles) and scattering the resulting
  cache columns into the slot, instead of streaming one prompt token per
  decode step.  A P-token prompt costs one prefill dispatch (plus a
  streamed tail for prompts longer than the largest bucket) rather than
  P full-batch decode dispatches.  Under greedy decoding the bulk path
  is bit-identical to the streamed baseline (``prefill_mode="streamed"``),
  asserted in tests; with ``temperature > 0`` the two modes consume
  different numbers of rng splits (streaming burns one per prompt token)
  so their samples differ.
* **Sync-free batched decode** — ``step_many(k)`` runs k fused
  sample-and-advance steps (``make_decode_loop``) in ONE dispatch with a
  donated device-resident ``SampleState``: next-token feedback, the
  active mask, per-slot progress and the generated-token buffer all stay
  on device.  The host tracks progress with an *exact* projection (each
  active slot advances one token per step until its precomputed
  ``maxfed``), so steady-state decode performs **zero device->host
  transfers**; ``out_buf`` is fetched only when the projection says a
  slot completed, or at a drain.  ``host_syncs`` counts every fetch.
* **Paged KV cache** (``cache_mode="paged"``) — kv leaves live in ONE
  device-resident block pool (``kv_pool_blocks`` x ``block_size``
  columns) instead of dense per-lane ``max_seq`` strips; each slot
  addresses its logical positions through a per-lane block table
  (vLLM-style paging, served by the Pallas kernel in
  ``kernels/paged_attention``).  A ``BlockAllocator`` reserves a slot's
  whole block budget at admission and frees it at retire/pack, so the
  fused decode window never needs a mid-flight allocation — steady-state
  decode stays zero-sync.  Admission is capacity-gated on free blocks
  (not just free lanes): with short requests the same pool memory
  sustains more concurrent slots than ``batch_size`` dense lanes, and
  prompts longer than the largest prefill bucket are fed by *multiple*
  state-continued chunk prefills (block-table appends), subsuming
  prefill-with-history.  Token streams are bit-identical to the dense
  engine — paged and dense loops share the exact sampling body and the
  attention cores agree bit-for-bit (asserted in tests).
* **Migratable work units** — ``pack()`` captures each occupied slot
  (request progress + that slot's KV/state cache columns, as host
  arrays) into a self-contained ``WorkUnit``; ``unpack()`` admits units
  into any engine built from the same ``(cfg, max_seq)`` — including
  mid-prefill-chunk.  ``preempt()``/``resume()`` are the same checkpoint
  under pause semantics (slot freed, snapshot retained, bit-identical
  stream on resume).  This one PUP-style verb set is the substrate for
  every control-plane move: spot-drain, mid-stream rebalancing, and
  SLO-aware preemption (paper §III–IV applied to serving).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model_zoo as zoo

# Padded prompt-chunk sizes for bulk prefill.  Ascending; buckets larger
# than the engine's cache are dropped at construction.  One compiled
# prefill per surviving bucket per (cfg, engine shape).
DEFAULT_PREFILL_BUCKETS: Tuple[int, ...] = (16, 64, 256)

# Relative cost of one bulk-prefilled prompt token vs one decode step.
# Bulk prefill amortizes weight reads over the whole chunk, so a prefill
# token is far cheaper than a decode token; the router and the cluster's
# virtual-time accounting both use this factor.
DEFAULT_PREFILL_DISCOUNT = 0.35


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (len,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # SLO metadata (an ``repro.serving.workload.SLOClass``; None = the
    # cluster's default class) + the model pool this request must run on.
    slo: Optional[Any] = None
    model_id: str = "default"
    arrival_t: Optional[float] = None   # stamped by the cluster's arrival

    @property
    def total_tokens(self) -> int:
        """Token-units of work: prompt + planned new tokens (LB load)."""
        return len(self.prompt) + self.max_new_tokens

    def deadline_t(self, default: float = float("inf")) -> float:
        """Absolute completion deadline (inf when class-less/unarrived)."""
        if self.slo is None or self.arrival_t is None:
            return default
        return self.arrival_t + self.slo.deadline


def request_cost(req: Request,
                 discount: float = DEFAULT_PREFILL_DISCOUNT) -> float:
    """Router load of an unstarted request, with prefill discounted.

    Prompt tokens are bulk-prefilled (cheap); only the decode tokens cost
    a full step each.  The last prompt token doubles as the first decode
    feed, so ``len(prompt) - 1`` tokens ride the discounted prefill path.
    """
    return max(len(req.prompt) - 1, 0) * discount + req.max_new_tokens


@dataclasses.dataclass
class SlotSnapshot:
    """A checkpointed in-flight request: enough to resume decode anywhere.

    ``cache`` holds the slot's columns in ONE canonical layout — full
    contiguous ``max_seq`` sequence axes — whatever cache mode produced
    it: a paged engine gathers the slot's blocks through its table into
    the contiguous column on ``pack`` and re-blocks into its own
    geometry on ``unpack``.  Snapshots therefore migrate between dense
    and paged engines, and between paged engines with *different block
    sizes*, bit-identically (asserted in tests/test_paged.py).
    """
    request: Request
    fed: int                    # prompt+generated tokens already in cache
    next_tok: int               # next token to feed
    cache_len: int
    cache: Dict[str, np.ndarray]  # this slot's cache columns (host)
    # sampler rng at checkpoint time (host copy) — stamped by the
    # recovery path (``checkpoint_units``) so a temperature>0 stream
    # resumed into an otherwise-empty engine replays its lost tail
    # bit-identically; migration snapshots leave it None (the live rng
    # keeps advancing)
    rng: Optional[np.ndarray] = None

    @property
    def remaining_tokens(self) -> int:
        return max(self.request.total_tokens - self.fed, 1)

    def remaining_cost(self,
                       discount: float = DEFAULT_PREFILL_DISCOUNT) -> float:
        """Remaining load with the not-yet-fed prefill part discounted."""
        rem = self.remaining_tokens
        rem_prefill = min(max(len(self.request.prompt) - 1 - self.fed, 0),
                          rem)
        return rem_prefill * discount + (rem - rem_prefill)


class BlockAllocator:
    """Free-list allocator over the paged cache's physical block pool.

    Pure host-side bookkeeping (no jax): a slot's whole reservation is
    taken in one ``allocate`` at admission and returned in one
    ``release`` at retire/pack — there is no incremental growth, which
    is what keeps the fused decode window dispatch-free.  Invariants
    (property-tested): every block is either free or owned by exactly
    one slot; ``allocate`` on an owning slot and ``release`` on a
    non-owning slot raise (leak/double-free detection, not silence).
    """

    def __init__(self, num_blocks: int):
        self.num_blocks = int(num_blocks)
        self._free = list(range(self.num_blocks - 1, -1, -1))
        self._owned: Dict[int, Tuple[int, ...]] = {}
        self.peak_in_use = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def owned(self, slot: int) -> Tuple[int, ...]:
        return self._owned.get(slot, ())

    def allocate(self, slot: int, n: int) -> Tuple[int, ...]:
        if slot in self._owned:
            raise ValueError(f"slot {slot} already owns blocks (leak)")
        if n > len(self._free):
            raise ValueError(
                f"pool exhausted: want {n}, free {len(self._free)}")
        blocks = tuple(self._free.pop() for _ in range(max(n, 0)))
        self._owned[slot] = blocks
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return blocks

    def release(self, slot: int) -> Tuple[int, ...]:
        if slot not in self._owned:
            raise ValueError(f"slot {slot} owns no blocks (double free)")
        blocks = self._owned.pop(slot)
        self._free.extend(reversed(blocks))
        return blocks

    def check_invariants(self):
        """Raises unless free + owned exactly partition the pool."""
        free = set(self._free)
        owned = [b for bs in self._owned.values() for b in bs]
        assert len(free) == len(self._free), "duplicate free blocks"
        assert len(set(owned)) == len(owned), "block owned twice"
        assert not (free & set(owned)), "block both free and owned"
        assert len(free) + len(owned) == self.num_blocks, "blocks leaked"


# One jitted fn per (cfg, shape[, bucket/block]): replicas in a cluster
# share the compiled graphs instead of recompiling per engine.
_LOOP_CACHE: Dict[Tuple, Any] = {}
_PREFILL_CACHE: Dict[Tuple, Any] = {}


def _shared_loop(cfg: ModelConfig, shape: ShapeConfig, n_steps: int,
                 temperature: float, eos_token: Optional[int] = None):
    key = (cfg, shape, n_steps, float(temperature), eos_token)
    if key not in _LOOP_CACHE:
        _LOOP_CACHE[key] = jax.jit(
            zoo.make_decode_loop(cfg, shape, n_steps, temperature,
                                 eos_token=eos_token),
            donate_argnums=(1, 2))
    return _LOOP_CACHE[key]


def _shared_bulk_prefill(cfg: ModelConfig, shape: ShapeConfig, chunk: int):
    key = (cfg, shape, chunk)
    if key not in _PREFILL_CACHE:
        _PREFILL_CACHE[key] = jax.jit(
            zoo.make_bulk_prefill(cfg, shape, chunk), donate_argnums=(1,))
    return _PREFILL_CACHE[key]


def _shared_paged_loop(cfg: ModelConfig, shape: ShapeConfig, n_steps: int,
                       temperature: float, eos_token: Optional[int],
                       block_size: int, num_blocks: int):
    key = ("paged", cfg, shape, n_steps, float(temperature), eos_token,
           block_size, num_blocks)
    if key not in _LOOP_CACHE:
        _LOOP_CACHE[key] = jax.jit(
            zoo.make_paged_decode_loop(cfg, shape, n_steps, block_size,
                                       num_blocks, temperature,
                                       eos_token=eos_token),
            donate_argnums=(1, 2))
    return _LOOP_CACHE[key]


def _shared_paged_prefill(cfg: ModelConfig, shape: ShapeConfig, chunk: int,
                          block_size: int, num_blocks: int,
                          first: bool = False):
    key = ("paged", cfg, shape, chunk, block_size, num_blocks, first)
    if key not in _PREFILL_CACHE:
        _PREFILL_CACHE[key] = jax.jit(
            zoo.make_paged_bulk_prefill(cfg, shape, chunk, block_size,
                                        num_blocks, first_chunk=first),
            donate_argnums=(1,))
    return _PREFILL_CACHE[key]


def _slot_write(sample, prompt_buf, slot, next_tok, fed, plen, maxfed,
                active, prompt_row):
    """Fused slot (re)initialization: every per-slot sample field + the
    prompt row in ONE dispatch.  Admission used to issue seven eager
    device scatters per slot; under churn that dominated the decode loop
    itself, so the whole write is a single donated jit call."""
    sample = zoo.SampleState(
        next_tok=sample.next_tok.at[slot, 0].set(next_tok),
        active=sample.active.at[slot].set(active),
        fed=sample.fed.at[slot].set(fed),
        plen=sample.plen.at[slot].set(plen),
        maxfed=sample.maxfed.at[slot].set(maxfed),
        out_buf=sample.out_buf.at[slot].set(0),
        rng=sample.rng)
    return sample, prompt_buf.at[slot].set(prompt_row)


_SLOT_WRITE = jax.jit(_slot_write, donate_argnums=(0, 1))


def _table_write(bt, slot, row):
    return bt.at[slot].set(row)


_TABLE_WRITE = jax.jit(_table_write, donate_argnums=(0,))


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 4,
                 max_seq: int = 128, temperature: float = 0.0, seed: int = 0,
                 prefill_mode: str = "chunked",
                 prefill_buckets: Tuple[int, ...] = DEFAULT_PREFILL_BUCKETS,
                 prefill_discount: float = DEFAULT_PREFILL_DISCOUNT,
                 decode_block: int = 8, eos_token: Optional[int] = None,
                 cache_mode: str = "dense", block_size: int = 16,
                 kv_pool_blocks: Optional[int] = None):
        if prefill_mode not in ("chunked", "streamed"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        if cache_mode not in ("dense", "paged"):
            raise ValueError(f"unknown cache_mode {cache_mode!r}")
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.temperature = temperature
        self.prefill_mode = prefill_mode
        self.prefill_discount = prefill_discount
        self.decode_block = max(int(decode_block), 1)
        # device-side EOS early exit: a slot that samples this token
        # clears its own active flag inside the fused loop.  The host
        # projection can no longer predict completion, so eos engines
        # reconcile against device truth after every window (one fetch
        # per window instead of zero; the saved fused steps dominate).
        self.eos_token = eos_token
        self.cache_mode = cache_mode
        self.shape = ShapeConfig("serve", max_seq, batch_size, "decode")
        if cache_mode == "paged":
            if max_seq % block_size:
                raise ValueError(
                    f"max_seq={max_seq} not a multiple of "
                    f"block_size={block_size}")
            self.block_size = block_size
            self.max_blocks = max_seq // block_size
            # default pool = exactly the dense engine's kv memory; pass a
            # smaller pool to trade ceiling for memory (admission gates
            # on free blocks, so it degrades to queueing, never OOM)
            self.pool_blocks = (batch_size * self.max_blocks
                                if kv_pool_blocks is None
                                else int(kv_pool_blocks))
            self.state = zoo.init_paged_decode_state(
                cfg, self.shape, block_size, self.pool_blocks)
            self._alloc: Optional[BlockAllocator] = BlockAllocator(
                self.pool_blocks)
            # host mirror of the device block tables: pack() and the
            # allocator invariants read this; the device copy is kept in
            # lockstep by ONE fused row-write dispatch per admission
            # (releases update only the mirror — see _release_blocks)
            self._tables = np.full((batch_size, self.max_blocks),
                                   self.pool_blocks, np.int32)
        else:
            self.block_size = 0
            self.pool_blocks = 0
            self.state = zoo.init_decode_state(cfg, self.shape, fill_len=0)
            self._alloc = None
            self._tables = None
        self.sample = zoo.init_sample_state(cfg, self.shape, seed=seed)
        self._prompt_buf = jnp.zeros((batch_size, max_seq), jnp.int32)
        self._slots: List[Optional[Request]] = [None] * batch_size
        self._queue: List[Request] = []
        self._restore: List["WorkUnit"] = []
        # per-slot provenance of restored units: slot -> (uid, hops,
        # origin).  ``pack`` re-uses it so a unit keeps ONE identity and
        # one hop history across any number of pack->unpack round trips.
        self._unit_meta: Dict[int, Tuple[int, list, Optional[int]]] = {}
        self._completed: List[Request] = []
        # exact host mirrors of the device progress counters: advanced by
        # projection after every decode window, overwritten with device
        # truth at every poll
        self._fed = np.zeros(batch_size, np.int64)
        self._plen = np.ones(batch_size, np.int64)
        self._maxfed = np.zeros(batch_size, np.int64)
        self._next_tok_host = np.zeros(batch_size, np.int64)
        self._out_read = np.zeros(batch_size, np.int64)
        self.processed_tokens = 0   # prefill + decode work units (rate feed)
        self.host_syncs = 0         # device->host fetches (poll/drain only)
        self.chunk_prefills = 0     # bulk prefill dispatches issued
        self.preemptions = 0        # slots paused via preempt()
        self.resumes = 0            # paused units re-admitted via resume()
        self.resizes = 0            # in-place geometry changes via resize()
        self.resize_evictions = 0   # slots evicted (paused) by a shrink
        self._peak_slots = 0        # high-water concurrent occupied slots
        self._chunk_tokens_pending = 0
        if prefill_mode == "chunked" and cfg.family in zoo.BULK_PREFILL_FAMILIES:
            self._buckets = tuple(sorted(
                c for c in prefill_buckets if 0 < c <= max_seq))
        else:
            self._buckets = ()
        if not self._buckets:
            # no bulk path (streamed mode / family without a token-only
            # prefill): every prompt token costs a full decode step, so
            # backlog must not discount prefill work
            self.prefill_discount = 1.0
        # per-leaf batch axis of the cache pytree (slot slicing/placement)
        self._cache_axes = {
            k: ax.index("cache_batch")
            for k, ax in zoo.decode_state_logical_axes(cfg).cache.items()}

    # ------------------------------------------------------------- requests
    def submit(self, req: Request):
        if len(req.prompt) > self.max_seq - 1:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"cannot fit a max_seq={self.max_seq} cache")
        self._queue.append(req)

    def reclaim_queue(self) -> List[Request]:
        """Hand not-yet-admitted requests back (router re-dispatch)."""
        queued, self._queue = self._queue, []
        return queued

    def pop_completed(self) -> List[Request]:
        done, self._completed = self._completed, []
        return done

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self._slots)

    @property
    def n_queued(self) -> int:
        return len(self._queue) + len(self._restore)

    @property
    def free_slots(self) -> int:
        """Admittable-request capacity (what the router/EDF simulate).

        Dense: free lanes.  Paged: also bounded by free pool blocks —
        a lane without blocks to back it cannot admit — estimated at the
        per-request block need of the engine's own pending work (falling
        back to the mean reservation of running slots, then to a whole
        ``max_seq`` worth: the conservative dense-equivalent).
        """
        lanes = self.batch - self.n_active
        if self._alloc is None or lanes == 0:
            return lanes
        est = self._est_blocks_per_request()
        return min(lanes, self._alloc.free_count // max(est, 1))

    def _est_blocks_per_request(self) -> int:
        reqs = [u.snapshot.request for u in self._restore] + self._queue
        if reqs:
            need = [self._blocks_needed(self._req_maxfed(r)) for r in reqs]
            return max(1, round(sum(need) / len(need)))
        owned = [len(self._alloc.owned(s)) for s, r in
                 enumerate(self._slots) if r is not None]
        if owned:
            return max(1, round(sum(owned) / len(owned)))
        return self.max_blocks

    def occupancy(self) -> Dict[str, int]:
        """Slot/block occupancy counters (threaded into cluster metrics).

        ``max_concurrent_slots`` is the high-water mark of simultaneously
        occupied slots; ``peak_blocks_in_use`` the pool's high-water
        block usage (both 0-pool for dense engines).
        """
        return {
            "active_slots": self.n_active,
            "max_concurrent_slots": self._peak_slots,
            "blocks_in_use": self._alloc.in_use if self._alloc else 0,
            "peak_blocks_in_use":
                self._alloc.peak_in_use if self._alloc else 0,
            "pool_blocks": self.pool_blocks,
        }

    # ----------------------------------------------------- block lifecycle
    def _req_maxfed(self, req: Request) -> int:
        return min(len(req.prompt) + req.max_new_tokens - 1,
                   self.max_seq - 1)

    def _blocks_needed(self, maxfed: int) -> int:
        """Blocks covering every position a slot will ever write.

        Decode writes kv at positions ``0 .. maxfed-1`` (the token fed
        when ``fed == maxfed-1`` is the last one entering the cache), so
        ``ceil(maxfed / block_size)`` blocks reserved up front make the
        fused window allocation-free.
        """
        return max(1, -(-int(maxfed) // self.block_size))

    def _write_table_row(self, slot: int, blocks: Tuple[int, ...]):
        """Install ``slot``'s block mapping: host mirror + ONE fused
        device dispatch (sentinel-fill past the mapped prefix).  The
        mirror is what ``pack`` and the allocator invariants read; the
        device row is what every decode/prefill dispatch routes
        through."""
        self._tables[slot] = self.pool_blocks       # sentinel-fill
        self._tables[slot, :len(blocks)] = blocks
        self.state = self.state._replace(
            block_tables=_TABLE_WRITE(self.state.block_tables,
                                      np.int32(slot), self._tables[slot]))

    def _release_blocks(self, slot: int):
        """Return a retiring slot's blocks and sentinel its host table
        row.  The *device* row is left stale on purpose: a retired lane
        is ``active=0``, so its decode writes are routed to the drop
        sentinel by the active mask and its (clamped) gathers are
        discarded — and the row is rewritten by ``_write_table_row``
        before the slot is ever dispatched again.  Skipping the device
        sentinel write keeps retirement free of device dispatches."""
        self._alloc.release(slot)
        self._tables[slot] = self.pool_blocks

    def fed_tokens(self, slot: int) -> int:
        """Tokens already in ``slot``'s cache (exact, no device sync)."""
        return int(self._fed[slot])

    def queued_requests(self) -> Tuple[Request, ...]:
        """Accepted-but-unadmitted requests (control-plane visibility)."""
        return tuple(self._queue)

    def slot_requests(self) -> List[Tuple[int, Request]]:
        """Per occupied slot: (slot, request) — the preemptor's victim
        candidates, alongside ``slot_costs`` for their remaining load."""
        return [(i, r) for i, r in enumerate(self._slots) if r is not None]

    def backlog_tokens(self) -> float:
        """Remaining load across slots + queue (the router's signal).

        Prefill-remaining tokens are weighted by ``prefill_discount``:
        they are bulk-prefilled in one dispatch, so counting them 1:1
        with decode tokens would overstate the load of prompt-heavy
        engines and mis-steer the rate-aware router.
        """
        d = self.prefill_discount
        load = sum(cost for _, cost in self.slot_costs())
        load += sum(u.snapshot.remaining_cost(d) for u in self._restore)
        load += sum(request_cost(r, d) for r in self._queue)
        return load

    def restore_costs(self, discount: Optional[float] = None) -> List[float]:
        """Remaining discounted load per not-yet-admitted restore-queue
        unit (they claim free slots ahead of fresh work — the router's
        slot-availability simulation must count them)."""
        d = self.prefill_discount if discount is None else discount
        return [u.snapshot.remaining_cost(d) for u in self._restore]

    def slot_costs(self) -> List[Tuple[int, float]]:
        """Per occupied slot: (slot, remaining discounted load).

        The cluster's rebalancer uses this to pick migration victims —
        the slot with the most remaining work moves the most load per
        snapshot/restore round-trip.
        """
        d = self.prefill_discount
        out = []
        for slot, req in enumerate(self._slots):
            if req is None:
                continue
            rem = max(int(self._maxfed[slot] - self._fed[slot]), 1)
            rem_prefill = min(
                max(int(self._plen[slot] - 1 - self._fed[slot]), 0), rem)
            out.append((slot, rem_prefill * d + (rem - rem_prefill)))
        return out

    # ------------------------------------------------------------ admission
    def _pick_chunk(self, n_prefill: int,
                    room: Optional[int] = None) -> Tuple[int, int]:
        """Bulk-prefill bucket for ``n_prefill`` prompt tokens.

        Returns ``(bucket, n_real)`` — ``bucket`` = 0 means stream.
        Pad-safe (causal attention) families take the smallest bucket
        that covers the prompt and right-pad it; recurrent families take
        the largest fully-real bucket so no pad token ever enters the
        state recurrence.

        ``room`` caps the bucket at the cache positions left past the
        chunk's start offset (multi-chunk prefill mid-prompt): a padded
        bucket larger than the room would spill the write past the end
        of the slot's logical range.  When no covering bucket fits, a
        fully-real bucket is used instead (and the next round handles
        the remainder).
        """
        if not self._buckets or n_prefill <= 0:
            return 0, 0
        room = self.max_seq if room is None else room
        if self.cfg.family in zoo.PAD_SAFE_FAMILIES:
            for c in self._buckets:
                if n_prefill <= c <= room:
                    return c, n_prefill
            best = 0
            for c in self._buckets:
                if c <= min(n_prefill, room):
                    best = c
            return best, best
        best = 0
        chunk = max(self.cfg.ssm_chunk, 1)
        for c in self._buckets:
            if c <= min(n_prefill, room) and (c <= chunk or c % chunk == 0):
                best = c
        return best, best

    def _set_cache_len(self, slot: int, value: int):
        self.state = self.state._replace(
            cache_len=self.state.cache_len.at[slot].set(value))

    def _set_sample_row(self, slot: int, *, next_tok: int, fed: int,
                        plen: int, maxfed: int, prompt: np.ndarray,
                        active: int = 1):
        row = np.zeros(self.max_seq, np.int32)
        row[:len(prompt)] = prompt
        self.sample, self._prompt_buf = _SLOT_WRITE(
            self.sample, self._prompt_buf, np.int32(slot),
            np.int32(next_tok), np.int32(fed), np.int32(plen),
            np.int32(maxfed), np.int32(active), row)
        self._fed[slot] = fed
        self._plen[slot] = plen
        self._maxfed[slot] = maxfed
        self._next_tok_host[slot] = next_tok

    def _admit_fresh(self, req: Request, slot: int):
        P = len(req.prompt)
        maxfed = self._req_maxfed(req)
        if self._alloc is not None:
            blocks = self._alloc.allocate(slot, self._blocks_needed(maxfed))
            self._write_table_row(slot, blocks)
            n_fed = self._paged_chunk_prefills(req, slot, 0, P - 1)
        else:
            chunk, n_real = self._pick_chunk(P - 1)
            if chunk:
                bulk = _shared_bulk_prefill(self.cfg, self.shape, chunk)
                ctoks = np.zeros((1, chunk), np.int32)
                ctoks[0, :n_real] = req.prompt[:n_real]
                self.state = bulk(self.params, self.state,
                                  jnp.asarray(ctoks), np.int32(slot),
                                  np.int32(n_real))
                self.chunk_prefills += 1
                self._chunk_tokens_pending += n_real
            else:
                self._set_cache_len(slot, 0)
            n_fed = n_real
        self._slots[slot] = req
        self._out_read[slot] = 0
        self._set_sample_row(slot, next_tok=int(req.prompt[n_fed]),
                             fed=n_fed, plen=P, maxfed=maxfed,
                             prompt=req.prompt)

    def _paged_chunk_prefills(self, req: Request, slot: int, start: int,
                              n_prefill: int) -> int:
        """Feed ``req.prompt[start : start + n_prefill]`` into ``slot``
        by state-continued chunk prefills (block-table appends).

        Unlike the dense path (one chunk, remainder streamed through the
        decode loop), prompts beyond the largest bucket keep appending
        chunks — each attends causally over the history already in the
        slot's blocks, and recurrent leaves carry the SSD/conv state
        across the chunk boundary.  Returns the new fed count; if no
        bucket fits the (remaining, room) pair the leftover streams.
        """
        off, remaining = start, n_prefill
        while remaining > 0:
            chunk, n_real = self._pick_chunk(remaining,
                                             room=self.max_seq - off)
            if not chunk:
                break
            bulk = _shared_paged_prefill(self.cfg, self.shape, chunk,
                                         self.block_size, self.pool_blocks,
                                         first=(off == 0))
            ctoks = np.zeros((1, chunk), np.int32)
            ctoks[0, :n_real] = req.prompt[off:off + n_real]
            self.state = bulk(self.params, self.state,
                              jnp.asarray(ctoks), np.int32(slot),
                              np.int32(off), np.int32(n_real))
            self.chunk_prefills += 1
            self._chunk_tokens_pending += n_real
            off += n_real
            remaining -= n_real
        if off == start:
            self._set_cache_len(slot, start)
        return off

    def _install(self, snap: SlotSnapshot, slot: int):
        """Write a snapshot's cache columns into ``slot`` and resume it.

        Snapshots are *canonical contiguous* (full ``max_seq`` columns)
        regardless of the source engine's cache mode or block size —
        a paged engine re-blocks them into its own geometry here, which
        is what makes dense<->paged and cross-block-size migration
        round-trip bit-identically.
        """
        req = snap.request
        maxfed = self._req_maxfed(req)
        # recovery checkpoints carry the sampler rng: restoring into an
        # otherwise-empty sampled engine replays the exact draws of the
        # lost tail (the rng is shared across slots, so a busy engine —
        # or a greedy one, which never consumes it — keeps its own)
        if (snap.rng is not None and self.temperature > 0
                and self.n_active == 0):
            self.sample = self.sample._replace(rng=jnp.asarray(snap.rng))
        if self._alloc is not None:
            blocks = self._alloc.allocate(slot, self._blocks_needed(maxfed))
            self._write_table_row(slot, blocks)
            kv_keys = set(zoo.paged_kv_keys(self.cfg))
            new_cache = {}
            for k, arr in self.state.cache.items():
                ax = self._cache_axes[k]
                col = np.asarray(snap.cache[k])
                if k in kv_keys:
                    # contiguous column -> (max_blocks, block_size) at the
                    # seq axis -> scatter the reserved prefix through the
                    # fresh table (dense batch axis == paged block axis)
                    sh = col.shape
                    blocked = col.reshape(
                        sh[:ax] + (self.max_blocks, self.block_size)
                        + sh[ax + 1:])
                    sel = blocked[(slice(None),) * ax
                                  + (slice(0, len(blocks)),)]
                    idx = [slice(None)] * arr.ndim
                    idx[ax] = jnp.asarray(blocks, jnp.int32)
                    new_cache[k] = arr.at[tuple(idx)].set(
                        jnp.asarray(sel, arr.dtype))
                else:
                    idx = [slice(None)] * arr.ndim
                    idx[ax] = slot
                    new_cache[k] = arr.at[tuple(idx)].set(
                        jnp.asarray(col, arr.dtype))
            self.state = self.state._replace(cache=new_cache)
        else:
            new_cache = {}
            for k, arr in self.state.cache.items():
                ax = self._cache_axes[k]
                idx = [slice(None)] * arr.ndim
                idx[ax] = slot
                new_cache[k] = arr.at[tuple(idx)].set(
                    jnp.asarray(snap.cache[k], arr.dtype))
            self.state = self.state._replace(cache=new_cache)
        self._set_cache_len(slot, snap.cache_len)
        self._slots[slot] = req
        self._out_read[slot] = len(req.out_tokens)
        self._set_sample_row(slot, next_tok=snap.next_tok, fed=snap.fed,
                             plen=len(req.prompt), maxfed=maxfed,
                             prompt=req.prompt)

    def _can_admit(self, req: Request) -> bool:
        """Paged admission gate: the head-of-queue request must fit the
        free-block pool (FIFO — later requests don't jump a blocked
        head, so admission order stays deterministic and starvation-free).
        """
        if self._alloc is None:
            return True
        return self._alloc.can_allocate(
            self._blocks_needed(self._req_maxfed(req)))

    def _admit(self):
        """Fill free slots from the restore queue, then the request queue."""
        for slot in range(self.batch):
            if self._slots[slot] is not None:
                continue
            if self._restore:
                if not self._can_admit(self._restore[0].snapshot.request):
                    break
                u = self._restore.pop(0)
                self._install(u.snapshot, slot)
                # keep the unit's identity alive on the slot: a later
                # pack() re-emits the SAME uid and extends the same hop
                # history (the list object is shared, so provenance
                # recorded while the slot runs lands on the right unit)
                self._unit_meta[slot] = (u.uid, u.hops, u.origin)
            elif self._queue:
                if not self._can_admit(self._queue[0]):
                    break
                self._admit_fresh(self._queue.pop(0), slot)
        self._peak_slots = max(self._peak_slots, self.n_active)

    # ------------------------------------------------------------- stepping
    def step_many(self, n_steps: int) -> Dict[str, int]:
        """Admit, then run ``n_steps`` fused decode steps in ONE dispatch.

        Returns ``{"steps", "emitted", "processed", "chunk_tokens"}``.
        ``processed`` counts work units fed this call (bulk-prefilled
        chunk tokens + per-step feeds); ``emitted`` counts generated
        tokens.  Both come from the host-side exact projection — the
        device is polled only when the projection says a slot finished.
        """
        self._chunk_tokens_pending = 0
        self._admit()
        chunk_tokens = self._chunk_tokens_pending
        stats = {"steps": 0, "emitted": 0, "processed": chunk_tokens,
                 "chunk_tokens": chunk_tokens}
        occupied = [i for i, r in enumerate(self._slots) if r is not None]
        if not occupied:
            self.processed_tokens += stats["processed"]
            return stats
        before = {slot: int(self._fed[slot]) for slot in occupied}
        if self._alloc is not None:
            loop = _shared_paged_loop(self.cfg, self.shape, n_steps,
                                      self.temperature, self.eos_token,
                                      self.block_size, self.pool_blocks)
        else:
            loop = _shared_loop(self.cfg, self.shape, n_steps,
                                self.temperature, self.eos_token)
        self.state, self.sample = loop(self.params, self.state,
                                       self.sample, self._prompt_buf)
        stats["steps"] = n_steps
        if self.eos_token is not None:
            # EOS can end a slot at any inner step, invisibly to the host
            # projection: reconcile against device truth every window
            # (``_poll`` reads fed/active, harvests finished slots).
            self._poll()
            for slot in occupied:
                after = int(self._fed[slot])
                plen = int(self._plen[slot])
                stats["processed"] += after - before[slot]
                stats["emitted"] += (max(0, after - plen + 1)
                                     - max(0, before[slot] - plen + 1))
            self.processed_tokens += stats["processed"]
            return stats
        done_any = False
        for slot in occupied:
            after = min(before[slot] + n_steps, int(self._maxfed[slot]))
            self._fed[slot] = after
            plen = int(self._plen[slot])
            stats["processed"] += after - before[slot]
            stats["emitted"] += (max(0, after - plen + 1)
                                 - max(0, before[slot] - plen + 1))
            if after >= self._maxfed[slot]:
                done_any = True
        self.processed_tokens += stats["processed"]
        if done_any:
            self._poll()
        return stats

    def step(self) -> int:
        """One engine step (admit + ONE fused decode); returns tokens
        emitted (generated tokens only — prefill doesn't count)."""
        return self.step_many(1)["emitted"]

    def run_until_idle(self, max_steps: int = 10_000) -> Dict[str, float]:
        t0 = time.perf_counter()
        tokens = 0
        steps = 0
        while (any(r is not None for r in self._slots) or self._queue
               or self._restore) and steps < max_steps:
            block = min(self.decode_block, max_steps - steps)
            out = self.step_many(block)
            tokens += out["emitted"]
            steps += max(out["steps"], 1)
        dt = time.perf_counter() - t0
        return {"tokens": tokens, "steps": steps, "seconds": dt,
                "tok_per_s": tokens / max(dt, 1e-9)}

    # ----------------------------------------------------------- host sync
    def _fetch(self, tree):
        """The ONLY device->host path in the engine (counted)."""
        self.host_syncs += 1
        return jax.device_get(tree)

    def _poll(self):
        """Materialize device progress into the Request objects.

        Called when the projection says a slot completed, and at drains —
        never in the steady-state decode loop.
        """
        occupied = [i for i, r in enumerate(self._slots) if r is not None]
        if not occupied:
            return
        out_buf, fed, next_tok, active = self._fetch(
            (self.sample.out_buf, self.sample.fed, self.sample.next_tok,
             self.sample.active))
        for slot in occupied:
            req = self._slots[slot]
            self._fed[slot] = int(fed[slot])
            self._next_tok_host[slot] = int(next_tok[slot, 0])
            n = max(0, int(fed[slot]) - int(self._plen[slot]) + 1)
            new = out_buf[slot, int(self._out_read[slot]):n]
            req.out_tokens.extend(int(t) for t in new)
            self._out_read[slot] = n
            # a device-deactivated occupied slot is finished — either it
            # reached maxfed, or it sampled the EOS token and early-exited
            if fed[slot] >= self._maxfed[slot] or int(active[slot]) == 0:
                req.done = True
                self._completed.append(req)
                self._slots[slot] = None
                self._unit_meta.pop(slot, None)
                if self._alloc is not None:
                    # blocks return to the pool at the window boundary;
                    # the next _admit can hand them to a queued request
                    self._release_blocks(slot)

    # ----------------------------------------------- WorkUnit pack/unpack
    #
    # One verb set for every in-flight-request move (the paper's PUP
    # interface): ``pack``/``unpack`` for migration and drain,
    # ``preempt``/``resume`` for SLO-aware pausing, and the
    # non-destructive ``checkpoint_units`` for periodic recovery
    # checkpoints.

    def _slot_cols(self, slot: int, cache_host: Dict[str, np.ndarray],
                   kv_keys) -> Dict[str, np.ndarray]:
        """Gather one slot's cache columns in the canonical contiguous
        layout (paged engines merge the slot's blocks and pad to
        ``max_seq``; dense engines just take the batch row)."""
        cols = {}
        for k, v in cache_host.items():
            ax = self._cache_axes[k]
            if k in kv_keys:
                # gather the slot's blocks into the canonical
                # contiguous column (block-size-agnostic snapshot)
                blocks = list(self._alloc.owned(slot))
                rows = v.take(blocks, axis=ax)
                sh = rows.shape
                merged = rows.reshape(
                    sh[:ax] + (sh[ax] * sh[ax + 1],) + sh[ax + 2:])
                pad = self.max_seq - merged.shape[ax]
                if pad:
                    widths = [(0, 0)] * merged.ndim
                    widths[ax] = (0, pad)
                    merged = np.pad(merged, widths)
                cols[k] = merged
            else:
                cols[k] = v.take(slot, axis=ax)
        return cols

    def _snapshot_slots(self, slots: Optional[List[int]] = None
                        ) -> List[Tuple[int, SlotSnapshot]]:
        """Checkpoint and release occupied slots (the PUP 'pack' step).

        ``slots`` restricts the checkpoint to a subset (the rebalancer's
        mid-stream migration and the preemptor pick single victims);
        None takes every occupied slot.  Works at any point in a
        request's life — including right after a bulk prefill chunk,
        before the prompt is fully fed.  Returns ``(slot, snapshot)``
        pairs so ``pack`` can look up per-slot unit provenance.
        """
        self._poll()
        occupied = [i for i, r in enumerate(self._slots)
                    if r is not None and (slots is None or i in slots)]
        if not occupied:
            return []
        cache_host = {k: np.asarray(v)
                      for k, v in self._fetch(self.state.cache).items()}
        kv_keys = (set(zoo.paged_kv_keys(self.cfg))
                   if self._alloc is not None else set())
        snaps = []
        deactivate = self.sample.active
        for slot in occupied:
            snaps.append((slot, SlotSnapshot(
                request=self._slots[slot],
                fed=int(self._fed[slot]),
                next_tok=int(self._next_tok_host[slot]),
                cache_len=int(self._fed[slot]),
                cache=self._slot_cols(slot, cache_host, kv_keys),
            )))
            self._slots[slot] = None
            if self._alloc is not None:
                self._release_blocks(slot)
            deactivate = deactivate.at[slot].set(0)
        self.sample = self.sample._replace(active=deactivate)
        return snaps

    def pack(self, slots: Optional[List[int]] = None) -> List["WorkUnit"]:
        """Checkpoint + release occupied slots as migratable ``WorkUnit``s.

        A packed unit is self-contained: ``unpack`` admits it into any
        engine built from the same ``(cfg, max_seq)`` and the greedy
        stream continues bit-identically.  A slot that was itself
        restored from a unit re-emits that unit's ``uid``, hop history
        and origin — identity is per in-flight request, not per
        checkpoint, so multi-hop migration chains stay traceable.
        """
        from repro.serving.workunit import WorkUnit
        units = []
        for slot, snap in self._snapshot_slots(slots):
            meta = self._unit_meta.pop(slot, None)
            if meta is None:
                units.append(WorkUnit(snapshot=snap))
            else:
                uid, hops, origin = meta
                units.append(WorkUnit(snapshot=snap, uid=uid, hops=hops,
                                      origin=origin))
        return units

    def unpack(self, units: List["WorkUnit"]):
        """Queue packed units for admission (cache written on admit).

        Unpacked units are admitted into free slots ahead of fresh
        queued requests, so migrated/resumed work never starves behind
        new arrivals.
        """
        self._restore.extend(units)

    def slot_provenance(self) -> Dict[int, Tuple[int, Tuple["Hop", ...]]]:
        """Per restored slot: ``(unit uid, hop history so far)`` — the
        observability window onto in-flight migration chains."""
        return {slot: (uid, tuple(hops))
                for slot, (uid, hops, _origin) in self._unit_meta.items()}

    def preempt(self, slots: Optional[List[int]] = None) -> List["WorkUnit"]:
        """Pause slots mid-stream: slot freed, snapshot retained.

        Mechanically a ``pack``, but the units come back ``PAUSED`` —
        parked by a preemption policy to free capacity for more urgent
        work, not in transit to another host.  ``resume`` continues the
        decoded stream bit-identically (asserted in tests).
        """
        from repro.serving.workunit import PAUSED
        units = self.pack(slots)
        for u in units:
            u.state = PAUSED
        self.preemptions += len(units)
        return units

    def resume(self, units: List["WorkUnit"]):
        """Re-admit paused units (the other half of ``preempt``)."""
        from repro.serving.workunit import PACKED
        for u in units:
            u.state = PACKED
        self.resumes += len(units)
        self.unpack(units)

    def drain_units(self) -> Tuple[List["WorkUnit"], List[Request]]:
        """Empty the engine: packed in-flight work + the untouched queue.

        Not-yet-admitted units waiting in the restore queue ride along
        as-is — same objects, same uids — so a drained engine hands back
        everything it owned without laundering identities.
        """
        units = self.pack()
        units.extend(self._restore)
        self._restore = []
        queued, self._queue = self._queue, []
        return units, queued

    def pending_units(self) -> Tuple["WorkUnit", ...]:
        """Restore-queue units awaiting admission (control-plane and
        failure-recovery visibility)."""
        return tuple(self._restore)

    def checkpoint_units(self) -> List["WorkUnit"]:
        """NON-destructive checkpoint of every occupied slot.

        Unlike ``pack``, the slots keep decoding: the returned units
        hold a *frozen* deep copy of each request (``out_tokens``
        truncated to checkpoint progress) plus the sampler rng, so a
        hard-killed replica's work restores from its last checkpoint
        and re-decodes only the lost tail — bit-identically for greedy
        streams (and for sampled streams resumed into an empty engine,
        which replays the same rng draws).  Unit identity (uid / hop
        history / origin) is copied, not shared: provenance recorded on
        the live slot after the checkpoint stays on the live unit.
        """
        from repro.serving.workunit import WorkUnit
        self._poll()
        occupied = [i for i, r in enumerate(self._slots) if r is not None]
        if not occupied:
            return []
        cache_raw, rng_raw = self._fetch((self.state.cache,
                                          self.sample.rng))
        cache_host = {k: np.asarray(v) for k, v in cache_raw.items()}
        rng_host = np.asarray(rng_raw)
        kv_keys = (set(zoo.paged_kv_keys(self.cfg))
                   if self._alloc is not None else set())
        units = []
        for slot in occupied:
            req = self._slots[slot]
            frozen = dataclasses.replace(
                req, out_tokens=list(req.out_tokens))
            snap = SlotSnapshot(
                request=frozen,
                fed=int(self._fed[slot]),
                next_tok=int(self._next_tok_host[slot]),
                cache_len=int(self._fed[slot]),
                cache=self._slot_cols(slot, cache_host, kv_keys),
                rng=rng_host.copy(),
            )
            meta = self._unit_meta.get(slot)
            if meta is None:
                units.append(WorkUnit(snapshot=snap))
            else:
                uid, hops, origin = meta
                units.append(WorkUnit(snapshot=snap, uid=uid,
                                      hops=list(hops), origin=origin))
        return units

    # ------------------------------------------------- vertical elasticity
    @staticmethod
    def _default_evict_key(u: "WorkUnit") -> Tuple:
        """Keep-preference order under a shrink: most urgent SLO class
        first (lowest priority number), then most progress (evicting a
        nearly-done stream wastes the most sunk work), uid tiebreak."""
        prio = u.slo.priority if u.slo is not None else 1
        return (prio, -u.snapshot.fed, u.uid)

    def resize(self, *, batch_size: Optional[int] = None,
               decode_block: Optional[int] = None,
               kv_pool_blocks: Optional[int] = None,
               evict_key=None) -> List["WorkUnit"]:
        """In-place geometry change: repack every live slot through the
        canonical ``SlotSnapshot`` path and rebuild the decode state at
        the new ``(batch_size, kv_pool_blocks)`` — no drain, no restart.

        Surviving slots re-admit through ``unpack``/``_install`` (ahead
        of the queue, re-blocked into the new pool geometry) so their
        streams continue bit-identically; the sampler rng is carried
        across so temperature>0 streams keep their draw sequence.  Slots
        that no longer fit (fewer lanes, or a smaller block pool) come
        back as ``PAUSED`` WorkUnits — the same objects a ``preempt``
        would return, ready for a resume here or anywhere else.
        ``evict_key`` orders keep-preference (lowest kept first to fill
        capacity); the default keeps the most urgent SLO classes, the
        QoS layer passes BestEffort-evicts-first.

        Compiled decode/prefill functions are keyed by shape, so a
        resize costs at most one new compilation per fresh geometry and
        nothing when bouncing between already-seen sizes.
        """
        from repro.serving.workunit import PAUSED
        new_batch = self.batch if batch_size is None else int(batch_size)
        if new_batch < 1:
            raise ValueError(f"batch_size must be >= 1, got {new_batch}")
        if decode_block is not None:
            self.decode_block = max(int(decode_block), 1)
        new_pool = self.pool_blocks
        if kv_pool_blocks is not None:
            if self.cache_mode != "paged":
                raise ValueError(
                    "kv_pool_blocks only applies to cache_mode='paged'")
            new_pool = int(kv_pool_blocks)
            if new_pool < self.max_blocks:
                raise ValueError(
                    f"kv_pool_blocks={new_pool} cannot hold one full "
                    f"request ({self.max_blocks} blocks) — admission "
                    f"would wedge")
        elif self.cache_mode == "paged" and batch_size is not None:
            # pool follows the lane count by default (the dense-equivalent
            # memory budget at the new width)
            new_pool = new_batch * self.max_blocks
        if new_batch == self.batch and new_pool == self.pool_blocks:
            return []              # decode_block-only change: no repack
        units = self.pack()        # polls + harvests completions first
        units.sort(key=evict_key or self._default_evict_key)
        keep: List["WorkUnit"] = []
        evicted: List["WorkUnit"] = []
        lanes, blocks_free = new_batch, new_pool
        for u in units:
            need = (self._blocks_needed(self._req_maxfed(u.snapshot.request))
                    if self.cache_mode == "paged" else 0)
            if lanes > 0 and need <= blocks_free:
                keep.append(u)
                lanes -= 1
                blocks_free -= need
            else:
                evicted.append(u)
        rng = self.sample.rng      # carried across the rebuild
        self.batch = new_batch
        self.shape = ShapeConfig("serve", self.max_seq, new_batch, "decode")
        if self.cache_mode == "paged":
            self.pool_blocks = new_pool
            self.state = zoo.init_paged_decode_state(
                self.cfg, self.shape, self.block_size, new_pool)
            self._alloc = BlockAllocator(new_pool)
            self._tables = np.full((new_batch, self.max_blocks),
                                   new_pool, np.int32)
        else:
            self.state = zoo.init_decode_state(self.cfg, self.shape,
                                               fill_len=0)
        self.sample = zoo.init_sample_state(
            self.cfg, self.shape, seed=0)._replace(rng=rng)
        self._prompt_buf = jnp.zeros((new_batch, self.max_seq), jnp.int32)
        self._slots = [None] * new_batch
        self._unit_meta = {}
        self._fed = np.zeros(new_batch, np.int64)
        self._plen = np.ones(new_batch, np.int64)
        self._maxfed = np.zeros(new_batch, np.int64)
        self._next_tok_host = np.zeros(new_batch, np.int64)
        self._out_read = np.zeros(new_batch, np.int64)
        # survivors re-admit ahead of everything already waiting
        self._restore = keep + self._restore
        for u in evicted:
            u.state = PAUSED
        self.resizes += 1
        self.resize_evictions += len(evicted)
        self._admit()
        return evicted
