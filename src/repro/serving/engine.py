"""Serving engine: continuous-batching decode over the model zoo.

Small but real: request queue, slot-based batching (a fixed decode batch of
``batch_size`` slots; finished sequences release their slot to the next
request), streamed prefill, greedy or temperature sampling.  The decode
step is the same ``serve_step`` the dry run lowers at 32k/500k scale.

Two properties make the engine drivable by a cluster loop (repro.cluster):

* **Non-blocking ``step()``** — every call runs exactly ONE jitted decode
  over the whole batch.  Prefill is streamed through the same decode path,
  one prompt token per step per admitting slot, with an ``active`` mask so
  idle slots' caches never advance.  No call ever loops over a full prompt.
* **Checkpointable slots** — ``snapshot_slots()`` captures each occupied
  slot (request progress + that slot's KV/state cache columns) as host
  arrays; ``restore_slots()`` admits snapshots into any engine built from
  the same ``(cfg, max_seq)``.  This is the migration substrate for the
  cluster's spot-instance drain (paper §IV Mode C applied to serving).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model_zoo as zoo


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (len,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def total_tokens(self) -> int:
        """Token-units of work: prompt + planned new tokens (LB load)."""
        return len(self.prompt) + self.max_new_tokens


@dataclasses.dataclass
class SlotSnapshot:
    """A checkpointed in-flight request: enough to resume decode anywhere."""
    request: Request
    fed: int                    # prompt+generated tokens already in cache
    next_tok: int               # next token to feed
    cache_len: int
    cache: Dict[str, np.ndarray]  # this slot's cache columns (host)

    @property
    def remaining_tokens(self) -> int:
        return max(self.request.total_tokens - self.fed, 1)


# One jitted serve_step per (cfg, shape): replicas in a cluster share the
# compiled step instead of recompiling the identical graph per engine.
_STEP_CACHE: Dict[Tuple[ModelConfig, ShapeConfig], Any] = {}


def _shared_step(cfg: ModelConfig, shape: ShapeConfig):
    key = (cfg, shape)
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = jax.jit(zoo.make_serve_step(cfg, shape))
    return _STEP_CACHE[key]


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 4,
                 max_seq: int = 128, temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        self.shape = ShapeConfig("serve", max_seq, batch_size, "decode")
        self.state = zoo.init_decode_state(cfg, self.shape, fill_len=0)
        self._step = _shared_step(cfg, self.shape)
        self._slots: List[Optional[Request]] = [None] * batch_size
        self._queue: List[Request] = []
        self._restore: List[SlotSnapshot] = []
        self._next_tok = np.zeros((batch_size, 1), np.int32)
        self._fed = [0] * batch_size
        self._completed: List[Request] = []
        self.processed_tokens = 0   # prefill + decode work units (rate feed)
        # per-leaf batch axis of the cache pytree (slot slicing/placement)
        self._cache_axes = {
            k: ax.index("cache_batch")
            for k, ax in zoo.decode_state_logical_axes(cfg).cache.items()}

    # ------------------------------------------------------------- requests
    def submit(self, req: Request):
        if len(req.prompt) > self.max_seq - 1:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"cannot fit a max_seq={self.max_seq} cache")
        self._queue.append(req)

    def reclaim_queue(self) -> List[Request]:
        """Hand not-yet-admitted requests back (router re-dispatch)."""
        queued, self._queue = self._queue, []
        return queued

    def pop_completed(self) -> List[Request]:
        done, self._completed = self._completed, []
        return done

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self._slots)

    @property
    def n_queued(self) -> int:
        return len(self._queue) + len(self._restore)

    @property
    def free_slots(self) -> int:
        return self.batch - self.n_active

    def backlog_tokens(self) -> float:
        """Remaining token-units across slots + queue (the router's load)."""
        load = 0.0
        for slot, req in enumerate(self._slots):
            if req is not None:
                load += max(req.total_tokens - self._fed[slot], 1)
        load += sum(s.remaining_tokens for s in self._restore)
        load += sum(r.total_tokens for r in self._queue)
        return load

    def _set_cache_len(self, slot: int, value: int):
        cl = np.array(self.state.cache_len)
        cl[slot] = value
        self.state = zoo.DecodeState(self.state.cache, jnp.asarray(cl))

    def _admit(self):
        """Fill free slots from the restore queue, then the request queue."""
        for slot in range(self.batch):
            if self._slots[slot] is not None:
                continue
            if self._restore:
                self._install(self._restore.pop(0), slot)
            elif self._queue:
                req = self._queue.pop(0)
                self._slots[slot] = req
                self._fed[slot] = 0
                self._next_tok[slot, 0] = req.prompt[0]
                self._set_cache_len(slot, 0)

    def _decode_all(self, tokens, active):
        logits, self.state = self._step(self.params, self.state,
                                        {"tokens": tokens, "active": active})
        return logits

    # ------------------------------------------------------------- stepping
    def step(self) -> int:
        """One engine step: admit, then ONE decode over every occupied slot.

        Slots mid-prefill consume their next prompt token; slots past
        prefill sample and emit one new token.  Returns tokens emitted
        (generated tokens only — prefill consumption doesn't count).
        """
        self._admit()
        occupied = [i for i, r in enumerate(self._slots) if r is not None]
        if not occupied:
            return 0
        active = np.zeros((self.batch,), np.int32)
        active[occupied] = 1
        self.processed_tokens += len(occupied)
        logits = self._decode_all(jnp.asarray(self._next_tok),
                                  jnp.asarray(active))
        last = np.asarray(logits[:, -1, :])
        if self.temperature > 0:
            self.rng, sub = jax.random.split(self.rng)
            nxt = np.asarray(jax.random.categorical(
                sub, jnp.asarray(last) / self.temperature, axis=-1))
        else:
            nxt = last.argmax(-1)
        emitted = 0
        cache_len = np.asarray(self.state.cache_len)
        for slot in occupied:
            req = self._slots[slot]
            self._fed[slot] += 1
            if self._fed[slot] < len(req.prompt):
                # still prefilling: stream the next prompt token
                self._next_tok[slot, 0] = req.prompt[self._fed[slot]]
                continue
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            emitted += 1
            self._next_tok[slot, 0] = tok
            if (len(req.out_tokens) >= req.max_new_tokens
                    or int(cache_len[slot]) >= self.max_seq - 1):
                req.done = True
                self._completed.append(req)
                self._slots[slot] = None
        return emitted

    def run_until_idle(self, max_steps: int = 10_000) -> Dict[str, float]:
        t0 = time.perf_counter()
        tokens = 0
        steps = 0
        while (any(r is not None for r in self._slots) or self._queue
               or self._restore) and steps < max_steps:
            tokens += self.step()
            steps += 1
        dt = time.perf_counter() - t0
        return {"tokens": tokens, "steps": steps, "seconds": dt,
                "tok_per_s": tokens / max(dt, 1e-9)}

    # --------------------------------------------------------- checkpointing
    def snapshot_slots(self) -> List[SlotSnapshot]:
        """Checkpoint and release every occupied slot (drain semantics)."""
        occupied = [i for i, r in enumerate(self._slots) if r is not None]
        if not occupied:
            return []
        cache_host = {k: np.asarray(jax.device_get(v))
                      for k, v in self.state.cache.items()}
        cache_len = np.asarray(self.state.cache_len)
        snaps = []
        for slot in occupied:
            snaps.append(SlotSnapshot(
                request=self._slots[slot],
                fed=self._fed[slot],
                next_tok=int(self._next_tok[slot, 0]),
                cache_len=int(cache_len[slot]),
                cache={k: v.take(slot, axis=self._cache_axes[k])
                       for k, v in cache_host.items()},
            ))
            self._slots[slot] = None
        return snaps

    def restore_slots(self, snapshots: List[SlotSnapshot]):
        """Queue checkpointed slots for admission (cache written on admit)."""
        self._restore.extend(snapshots)

    def drain(self) -> Tuple[List[SlotSnapshot], List[Request]]:
        """Empty the engine: checkpoints of in-flight work + untouched queue."""
        snaps = self.snapshot_slots()
        snaps.extend(self._restore)
        self._restore = []
        queued, self._queue = self._queue, []
        return snaps, queued

    def _install(self, snap: SlotSnapshot, slot: int):
        """Write a snapshot's cache columns into ``slot`` and resume it."""
        new_cache = {}
        for k, arr in self.state.cache.items():
            ax = self._cache_axes[k]
            idx = [slice(None)] * arr.ndim
            idx[ax] = slot
            new_cache[k] = arr.at[tuple(idx)].set(
                jnp.asarray(snap.cache[k], arr.dtype))
        self.state = zoo.DecodeState(new_cache, self.state.cache_len)
        self._set_cache_len(slot, snap.cache_len)
        self._slots[slot] = snap.request
        self._fed[slot] = snap.fed
        self._next_tok[slot, 0] = snap.next_tok
