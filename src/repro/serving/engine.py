"""Serving engine: continuous-batching decode over the model zoo.

Small but real: request queue, slot-based batching (a fixed decode batch of
``batch_size`` slots; finished sequences release their slot to the next
request), prefill-then-decode, greedy or temperature sampling.  The decode
step is the same ``serve_step`` the dry run lowers at 32k/500k scale.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model_zoo as zoo


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (len,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 4,
                 max_seq: int = 128, temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        self.shape = ShapeConfig("serve", max_seq, batch_size, "decode")
        self.state = zoo.init_decode_state(cfg, self.shape, fill_len=0)
        self._step = jax.jit(zoo.make_serve_step(cfg, self.shape))
        self._slots: List[Optional[Request]] = [None] * batch_size
        self._queue: List[Request] = []
        self._next_tok = np.zeros((batch_size, 1), np.int32)

    # ------------------------------------------------------------- requests
    def submit(self, req: Request):
        self._queue.append(req)

    def _admit(self):
        """Fill free slots: token-by-token prefill through serve_step.

        (Chunked bulk prefill exists as ``make_prefill``; slot-level decode
        prefill keeps the engine simple and exercises the same cache path.)
        """
        for slot in range(self.batch):
            if self._slots[slot] is not None or not self._queue:
                continue
            req = self._queue.pop(0)
            self._slots[slot] = req
            # reset this slot's cache_len to 0
            cl = np.array(self.state.cache_len)
            cl[slot] = 0
            self.state = zoo.DecodeState(self.state.cache, jnp.asarray(cl))
            # feed prompt tokens one at a time (slot-isolated prefill)
            for t in req.prompt[:-1]:
                tok = np.array(self._next_tok)
                tok[slot, 0] = t
                self._decode_all(jnp.asarray(tok))
            self._next_tok[slot, 0] = req.prompt[-1]

    def _decode_all(self, tokens):
        logits, self.state = self._step(self.params, self.state,
                                        {"tokens": tokens})
        return logits

    # ------------------------------------------------------------- stepping
    def step(self) -> int:
        """One engine step: admit, decode one token for every active slot."""
        self._admit()
        active = [i for i, r in enumerate(self._slots) if r is not None]
        if not active:
            return 0
        logits = self._decode_all(jnp.asarray(self._next_tok))
        last = np.asarray(logits[:, -1, :])
        if self.temperature > 0:
            self.rng, sub = jax.random.split(self.rng)
            nxt = np.asarray(jax.random.categorical(
                sub, jnp.asarray(last) / self.temperature, axis=-1))
        else:
            nxt = last.argmax(-1)
        emitted = 0
        for slot in active:
            req = self._slots[slot]
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            emitted += 1
            self._next_tok[slot, 0] = tok
            seq_len = int(np.asarray(self.state.cache_len)[slot])
            if (len(req.out_tokens) >= req.max_new_tokens
                    or seq_len >= self.max_seq - 1):
                req.done = True
                self._slots[slot] = None
        return emitted

    def run_until_idle(self, max_steps: int = 10_000) -> Dict[str, float]:
        t0 = time.perf_counter()
        tokens = 0
        steps = 0
        while (any(self._slots) or self._queue) and steps < max_steps:
            tokens += self.step()
            steps += 1
        dt = time.perf_counter() - t0
        return {"tokens": tokens, "steps": steps, "seconds": dt,
                "tok_per_s": tokens / max(dt, 1e-9)}
