"""SimEngine: the ServingEngine's exact host-side twin, minus the device.

A 10^6-request scenario cannot run real jitted decode in CI minutes —
and doesn't need to: the cluster layers (router, preemptor, autoscaler,
chaos recovery, metrics) only ever observe the engine through its
host-side projection (fed counts, slot costs, completions, WorkUnits).
``SimEngine`` implements that projection directly: the same admission
order, the same ``step_many`` accounting arithmetic (steps / emitted /
processed / chunk_tokens), the same pack/unpack/preempt/resume verb set
over ``SlotSnapshot``s — with "decode" producing deterministic
pseudo-tokens that are a pure function of ``(request rid, position)``,
so pack/resume/replay round-trips are bit-identical by construction.

Drop-in: ``Replica(engine_cls=SimEngine)`` /
``ServingCluster(engine="sim")``.  ``cfg`` and ``params`` are accepted
and ignored, so cluster scenarios swap engines without touching their
setup.  What it does NOT simulate: real cache contents (snapshots carry
an empty ``cache`` dict), paged-pool block pressure, EOS early exit,
and temperature sampling (tokens are deterministic regardless).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.engine import (DEFAULT_PREFILL_DISCOUNT, Request,
                                  SlotSnapshot, request_cost)


def sim_token(rid: int, index: int, vocab: int = 50_000) -> int:
    """The deterministic pseudo-token stream: output ``index`` of request
    ``rid``.  A pure function, so any pack/resume/replay interleaving
    regenerates the identical stream."""
    return (rid * 1_000_003 + index * 7_919) % vocab


class SimEngine:
    """Token-accounting ServingEngine twin (no jax, no device)."""

    def __init__(self, cfg=None, params=None, *, batch_size: int = 4,
                 max_seq: int = 128, temperature: float = 0.0,
                 seed: int = 0, prefill_mode: str = "chunked",
                 prefill_discount: float = DEFAULT_PREFILL_DISCOUNT,
                 decode_block: int = 8, eos_token: Optional[int] = None,
                 **_ignored):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.temperature = temperature
        self.prefill_mode = prefill_mode
        self.prefill_discount = prefill_discount
        self.decode_block = max(int(decode_block), 1)
        self.eos_token = eos_token
        self.cache_mode = "sim"
        self.block_size = 0
        self.pool_blocks = 0
        self._alloc = None
        self._slots: List[Optional[Request]] = [None] * batch_size
        self._queue: List[Request] = []
        self._restore: List = []          # WorkUnits awaiting admission
        self._unit_meta: Dict[int, Tuple[int, list, Optional[int]]] = {}
        self._completed: List[Request] = []
        self._fed = np.zeros(batch_size, np.int64)
        self._plen = np.ones(batch_size, np.int64)
        self._maxfed = np.zeros(batch_size, np.int64)
        self._next_tok_host = np.zeros(batch_size, np.int64)
        self._out_read = np.zeros(batch_size, np.int64)
        self.processed_tokens = 0
        self.host_syncs = 0               # no device: stays 0 forever
        self.chunk_prefills = 0
        self.preemptions = 0
        self.resumes = 0
        self.resizes = 0
        self.resize_evictions = 0
        self._peak_slots = 0
        self._chunk_tokens_pending = 0

    # ------------------------------------------------------------- requests
    def submit(self, req: Request):
        if len(req.prompt) > self.max_seq - 1:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"cannot fit a max_seq={self.max_seq} cache")
        self._queue.append(req)

    def reclaim_queue(self) -> List[Request]:
        queued, self._queue = self._queue, []
        return queued

    def pop_completed(self) -> List[Request]:
        done, self._completed = self._completed, []
        return done

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self._slots)

    @property
    def n_queued(self) -> int:
        return len(self._queue) + len(self._restore)

    @property
    def free_slots(self) -> int:
        return self.batch - self.n_active

    def occupancy(self) -> Dict[str, int]:
        return {
            "active_slots": self.n_active,
            "max_concurrent_slots": self._peak_slots,
            "blocks_in_use": 0,
            "peak_blocks_in_use": 0,
            "pool_blocks": 0,
        }

    def fed_tokens(self, slot: int) -> int:
        return int(self._fed[slot])

    def queued_requests(self) -> Tuple[Request, ...]:
        return tuple(self._queue)

    def slot_requests(self) -> List[Tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self._slots) if r is not None]

    def backlog_tokens(self) -> float:
        d = self.prefill_discount
        load = sum(cost for _, cost in self.slot_costs())
        load += sum(u.snapshot.remaining_cost(d) for u in self._restore)
        load += sum(request_cost(r, d) for r in self._queue)
        return load

    def restore_costs(self, discount: Optional[float] = None) -> List[float]:
        d = self.prefill_discount if discount is None else discount
        return [u.snapshot.remaining_cost(d) for u in self._restore]

    def slot_costs(self) -> List[Tuple[int, float]]:
        d = self.prefill_discount
        out = []
        for slot, req in enumerate(self._slots):
            if req is None:
                continue
            rem = max(int(self._maxfed[slot] - self._fed[slot]), 1)
            rem_prefill = min(
                max(int(self._plen[slot] - 1 - self._fed[slot]), 0), rem)
            out.append((slot, rem_prefill * d + (rem - rem_prefill)))
        return out

    # ------------------------------------------------------------ admission
    def _req_maxfed(self, req: Request) -> int:
        return min(len(req.prompt) + req.max_new_tokens - 1,
                   self.max_seq - 1)

    def _next_tok(self, req: Request, fed: int, plen: int) -> int:
        """Token to feed at cache position ``fed``: prompt while it
        lasts, then the deterministic output stream."""
        if fed < plen:
            return int(req.prompt[fed])
        return sim_token(req.rid, fed - plen)

    def _admit_fresh(self, req: Request, slot: int):
        P = len(req.prompt)
        n_fed = max(P - 1, 0)
        if n_fed:
            # the whole prefill rides one bulk chunk (the dense chunked
            # engine's common case); accounted identically
            self.chunk_prefills += 1
            self._chunk_tokens_pending += n_fed
        self._slots[slot] = req
        self._out_read[slot] = 0
        self._fed[slot] = n_fed
        self._plen[slot] = P
        self._maxfed[slot] = self._req_maxfed(req)
        self._next_tok_host[slot] = self._next_tok(req, n_fed, P)

    def _install(self, snap: SlotSnapshot, slot: int):
        req = snap.request
        self._slots[slot] = req
        self._out_read[slot] = len(req.out_tokens)
        self._fed[slot] = snap.fed
        self._plen[slot] = len(req.prompt)
        self._maxfed[slot] = self._req_maxfed(req)
        self._next_tok_host[slot] = snap.next_tok

    def _admit(self):
        for slot in range(self.batch):
            if self._slots[slot] is not None:
                continue
            if self._restore:
                u = self._restore.pop(0)
                self._install(u.snapshot, slot)
                self._unit_meta[slot] = (u.uid, u.hops, u.origin)
            elif self._queue:
                self._admit_fresh(self._queue.pop(0), slot)
        self._peak_slots = max(self._peak_slots, self.n_active)

    # ------------------------------------------------------------- stepping
    def step_many(self, n_steps: int) -> Dict[str, int]:
        """Admit, then advance every occupied slot ``n_steps`` feeds
        (capped at its maxfed) — the exact accounting arithmetic of
        ``ServingEngine.step_many``, with no device dispatch behind it.
        """
        self._chunk_tokens_pending = 0
        self._admit()
        chunk_tokens = self._chunk_tokens_pending
        stats = {"steps": 0, "emitted": 0, "processed": chunk_tokens,
                 "chunk_tokens": chunk_tokens}
        occupied = [i for i, r in enumerate(self._slots) if r is not None]
        if not occupied:
            self.processed_tokens += stats["processed"]
            return stats
        stats["steps"] = n_steps
        done_any = False
        for slot in occupied:
            before = int(self._fed[slot])
            after = min(before + n_steps, int(self._maxfed[slot]))
            self._fed[slot] = after
            plen = int(self._plen[slot])
            self._next_tok_host[slot] = self._next_tok(
                self._slots[slot], after, plen)
            stats["processed"] += after - before
            stats["emitted"] += (max(0, after - plen + 1)
                                 - max(0, before - plen + 1))
            if after >= self._maxfed[slot]:
                done_any = True
        self.processed_tokens += stats["processed"]
        if done_any:
            self._poll()
        return stats

    def step(self) -> int:
        return self.step_many(1)["emitted"]

    def run_until_idle(self, max_steps: int = 10_000) -> Dict[str, float]:
        tokens = 0
        steps = 0
        while (any(r is not None for r in self._slots) or self._queue
               or self._restore) and steps < max_steps:
            block = min(self.decode_block, max_steps - steps)
            out = self.step_many(block)
            tokens += out["emitted"]
            steps += max(out["steps"], 1)
        return {"tokens": tokens, "steps": steps, "seconds": 0.0,
                "tok_per_s": 0.0}

    def _poll(self):
        """Materialize progress into the Request objects (same contract
        as the device poll: emitted tokens appended, finished slots
        harvested to ``_completed``)."""
        for slot, req in enumerate(self._slots):
            if req is None:
                continue
            fed = int(self._fed[slot])
            plen = int(self._plen[slot])
            n = max(0, fed - plen + 1)
            for i in range(int(self._out_read[slot]), n):
                req.out_tokens.append(sim_token(req.rid, i))
            self._out_read[slot] = n
            if fed >= self._maxfed[slot]:
                req.done = True
                self._completed.append(req)
                self._slots[slot] = None
                self._unit_meta.pop(slot, None)

    # ----------------------------------------------- WorkUnit pack/unpack
    def _snapshot_slots(self, slots: Optional[List[int]] = None
                        ) -> List[Tuple[int, SlotSnapshot]]:
        self._poll()
        occupied = [i for i, r in enumerate(self._slots)
                    if r is not None and (slots is None or i in slots)]
        snaps = []
        for slot in occupied:
            req = self._slots[slot]
            snaps.append((slot, SlotSnapshot(
                request=req,
                fed=int(self._fed[slot]),
                next_tok=int(self._next_tok_host[slot]),
                cache_len=int(self._fed[slot]),
                cache={},        # no device cache: the pseudo-token
            )))                  # stream regenerates from (rid, index)
            self._slots[slot] = None
        return snaps

    def pack(self, slots: Optional[List[int]] = None) -> List:
        from repro.serving.workunit import WorkUnit
        units = []
        for slot, snap in self._snapshot_slots(slots):
            meta = self._unit_meta.pop(slot, None)
            if meta is None:
                units.append(WorkUnit(snapshot=snap))
            else:
                uid, hops, origin = meta
                units.append(WorkUnit(snapshot=snap, uid=uid, hops=hops,
                                      origin=origin))
        return units

    def unpack(self, units: List):
        self._restore.extend(units)

    def slot_provenance(self) -> Dict[int, Tuple[int, tuple]]:
        return {slot: (uid, tuple(hops))
                for slot, (uid, hops, _origin) in self._unit_meta.items()}

    def preempt(self, slots: Optional[List[int]] = None) -> List:
        from repro.serving.workunit import PAUSED
        units = self.pack(slots)
        for u in units:
            u.state = PAUSED
        self.preemptions += len(units)
        return units

    def resume(self, units: List):
        from repro.serving.workunit import PACKED
        for u in units:
            u.state = PACKED
        self.resumes += len(units)
        self.unpack(units)

    def drain_units(self) -> Tuple[List, List[Request]]:
        units = self.pack()
        units.extend(self._restore)
        self._restore = []
        queued, self._queue = self._queue, []
        return units, queued

    def pending_units(self) -> tuple:
        return tuple(self._restore)

    def checkpoint_units(self) -> List:
        from repro.serving.workunit import WorkUnit
        self._poll()
        units = []
        for slot, req in enumerate(self._slots):
            if req is None:
                continue
            frozen = dataclasses.replace(
                req, out_tokens=list(req.out_tokens))
            snap = SlotSnapshot(
                request=frozen,
                fed=int(self._fed[slot]),
                next_tok=int(self._next_tok_host[slot]),
                cache_len=int(self._fed[slot]),
                cache={},
            )
            meta = self._unit_meta.get(slot)
            if meta is None:
                units.append(WorkUnit(snapshot=snap))
            else:
                uid, hops, origin = meta
                units.append(WorkUnit(snapshot=snap, uid=uid,
                                      hops=list(hops), origin=origin))
        return units

    # ------------------------------------------------- vertical elasticity
    def resize(self, *, batch_size: Optional[int] = None,
               decode_block: Optional[int] = None,
               kv_pool_blocks: Optional[int] = None,
               evict_key=None) -> List:
        """Exact mirror of ``ServingEngine.resize`` minus the device:
        repack live slots, rebuild the host mirrors at the new lane
        count, re-admit survivors ahead of the queue, return evictees as
        ``PAUSED`` units.  ``kv_pool_blocks`` is accepted and ignored
        (the sim has no block pool), matching the constructor contract.
        """
        from repro.serving.engine import ServingEngine
        from repro.serving.workunit import PAUSED
        del kv_pool_blocks
        new_batch = self.batch if batch_size is None else int(batch_size)
        if new_batch < 1:
            raise ValueError(f"batch_size must be >= 1, got {new_batch}")
        if decode_block is not None:
            self.decode_block = max(int(decode_block), 1)
        if new_batch == self.batch:
            return []
        units = self.pack()
        units.sort(key=evict_key or ServingEngine._default_evict_key)
        keep, evicted = units[:new_batch], units[new_batch:]
        self.batch = new_batch
        self._slots = [None] * new_batch
        self._unit_meta = {}
        self._fed = np.zeros(new_batch, np.int64)
        self._plen = np.ones(new_batch, np.int64)
        self._maxfed = np.zeros(new_batch, np.int64)
        self._next_tok_host = np.zeros(new_batch, np.int64)
        self._out_read = np.zeros(new_batch, np.int64)
        self._restore = keep + self._restore
        for u in evicted:
            u.state = PAUSED
        self.resizes += 1
        self.resize_evictions += len(evicted)
        self._admit()
        return evicted
