"""Behaviour-shaped arrival processes: the million-request load library.

Every generator is a seeded, time-ordered ``ArrivalProcess`` producing a
**nonhomogeneous Poisson** stream at a time-varying rate ``rate(t)`` via
Lewis-Shedler thinning: candidate gaps are drawn at the envelope rate
``rate_max`` and each candidate survives with probability
``rate(t) / rate_max``.  Requests are built lazily, one per *accepted*
arrival — a 10^6-request diurnal trace never materializes a request
list (``PoissonArrivals`` copies every ``Request`` up front; these
stream), and same seed → bit-identical ``(t, rid)`` streams.

The shape catalogue ports the Kube-DRM behaviour library
(``scripts_behaviour/``: pulse_spikes, sawtooth, staircase, epochs,
staged_plateau — "Kub: Enabling Elastic HPC Workloads on Containerized
Environments", arXiv:2410.10655) plus a smooth ``diurnal`` day/night
cycle, the load family the elastic-job-scheduler evaluation matrix runs
under ("An Elastic Job Scheduler for HPC Applications on the Cloud",
arXiv:2510.15147).

Each shape also exposes ``segments(until)`` — ``(start, end,
mean_rate)`` windows of its rate profile — so property tests can hold
the empirical per-segment rate against the nominal one, and
``make_shape(name, n, rate=...)`` parameterizes any catalogue shape
around a target long-run mean rate (what the matrix benchmark scales to
fleet capacity).
"""

from __future__ import annotations

import math
from typing import Iterator, List, Sequence, Tuple

import numpy as np

from repro.serving.engine import Request
from repro.serving.workload import (BATCH, INTERACTIVE, ArrivalProcess,
                                    SLOClass)


class ShapedArrivals(ArrivalProcess):
    """Base: seeded nonhomogeneous Poisson by thinning, lazy requests.

    Subclasses define the rate profile: ``rate(t)`` (instantaneous
    requests/virtual-second), ``rate_max`` (a tight upper envelope — the
    thinning proposal rate), and ``segments(until)``.  Request shapes
    mirror ``workload.classed_requests``: an interactive (chat-turn
    sized, tight deadline) / batch (summarize-sized, loose deadline) mix
    over optional multi-model pools.
    """

    def __init__(self, n: int, *, seed: int = 0, t0: float = 0.0,
                 vocab_size: int = 256, interactive_frac: float = 0.3,
                 start_rid: int = 0,
                 model_ids: Sequence[str] = ("default",),
                 interactive: SLOClass = INTERACTIVE,
                 batch: SLOClass = BATCH):
        self.n = int(n)
        self.seed = seed
        self.t0 = float(t0)
        self.vocab_size = vocab_size
        self.interactive_frac = interactive_frac
        self.start_rid = start_rid
        self.model_ids = tuple(model_ids)
        self.interactive = interactive
        self.batch = batch

    # ------------------------------------------------------- rate profile
    def rate(self, t: float) -> float:
        """Instantaneous arrival rate at virtual time ``t``."""
        raise NotImplementedError

    @property
    def rate_max(self) -> float:
        """Tight upper envelope of ``rate`` (thinning proposal rate)."""
        raise NotImplementedError

    def segments(self, until: float) -> List[Tuple[float, float, float]]:
        """``(start, end, mean_rate)`` windows covering [t0, until]."""
        raise NotImplementedError

    def _mean_rate(self, a: float, b: float, k: int = 256) -> float:
        """Numeric mean of ``rate`` over [a, b] (midpoint rule)."""
        ts = a + (np.arange(k) + 0.5) * (b - a) / k
        return float(np.mean([self.rate(t) for t in ts]))

    # ------------------------------------------------------ request build
    def _build_request(self, rid: int, rng: np.random.Generator) -> Request:
        if rng.random() < self.interactive_frac:
            (plo, phi), (nlo, nhi) = ((3, 8), (3, 7))
            slo = self.interactive
        else:
            (plo, phi), (nlo, nhi) = ((6, 14), (10, 18))
            slo = self.batch
        return Request(
            rid=rid,
            prompt=rng.integers(0, self.vocab_size,
                                int(rng.integers(plo, phi)),
                                dtype=np.int32),
            max_new_tokens=int(rng.integers(nlo, nhi)),
            slo=slo,
            model_id=self.model_ids[rid % len(self.model_ids)])

    # ----------------------------------------------------------- stream
    def __iter__(self) -> Iterator[Tuple[float, Request]]:
        rng = np.random.default_rng(self.seed)
        rmax = float(self.rate_max)
        if not rmax > 0:
            raise ValueError(f"{type(self).__name__}: rate_max must be "
                             f"positive, got {rmax}")
        t = self.t0
        for i in range(self.n):
            # Lewis-Shedler thinning: propose at the envelope rate,
            # accept with prob rate(t)/rate_max
            while True:
                t += rng.exponential(1.0 / rmax)
                if rng.random() * rmax <= self.rate(t):
                    break
            yield t, self._build_request(self.start_rid + i, rng)


class PulseSpikes(ShapedArrivals):
    """Quiet baseline traffic punctured by periodic sharp spikes: the
    first ``spike_frac`` of every ``period`` runs at ``spike_rate``,
    the rest at ``base_rate``."""

    def __init__(self, n: int, *, base_rate: float, spike_rate: float,
                 period: float = 60.0, spike_frac: float = 0.2, **kw):
        super().__init__(n, **kw)
        self.base_rate = float(base_rate)
        self.spike_rate = float(spike_rate)
        self.period = float(period)
        self.spike_frac = float(spike_frac)

    def rate(self, t: float) -> float:
        phase = (t - self.t0) % self.period
        return (self.spike_rate if phase < self.spike_frac * self.period
                else self.base_rate)

    @property
    def rate_max(self) -> float:
        return max(self.base_rate, self.spike_rate)

    def segments(self, until: float) -> List[Tuple[float, float, float]]:
        out, start = [], self.t0
        while start < until:
            split = min(start + self.spike_frac * self.period, until)
            end = min(start + self.period, until)
            out.append((start, split, self.spike_rate))
            if end > split:
                out.append((split, end, self.base_rate))
            start = end
        return out


class Sawtooth(ShapedArrivals):
    """Linear ramp ``low -> high`` over each ``period``, then snap back
    (the classic gradual-rampup / instant-release tooth)."""

    def __init__(self, n: int, *, low: float, high: float,
                 period: float = 120.0, **kw):
        super().__init__(n, **kw)
        self.low = float(low)
        self.high = float(high)
        self.period = float(period)

    def rate(self, t: float) -> float:
        phase = ((t - self.t0) % self.period) / self.period
        return self.low + (self.high - self.low) * phase

    @property
    def rate_max(self) -> float:
        return max(self.low, self.high)

    def segments(self, until: float) -> List[Tuple[float, float, float]]:
        out, start = [], self.t0
        while start < until:
            end = min(start + self.period, until)
            out.append((start, end, self._mean_rate(start, end)))
            start = end
        return out


class Staircase(ShapedArrivals):
    """Discrete rate steps climbing ``low -> high`` across ``steps``
    levels of ``step_dur`` each, then resetting (a load-testing ladder
    that repeats)."""

    def __init__(self, n: int, *, low: float, high: float,
                 steps: int = 4, step_dur: float = 45.0, **kw):
        super().__init__(n, **kw)
        if steps < 2:
            raise ValueError("staircase needs >= 2 steps")
        self.low = float(low)
        self.high = float(high)
        self.steps = int(steps)
        self.step_dur = float(step_dur)

    def _level_rate(self, level: int) -> float:
        return self.low + (self.high - self.low) * level / (self.steps - 1)

    def rate(self, t: float) -> float:
        cycle = self.steps * self.step_dur
        level = int(((t - self.t0) % cycle) // self.step_dur)
        return self._level_rate(level)

    @property
    def rate_max(self) -> float:
        return max(self.low, self.high)

    def segments(self, until: float) -> List[Tuple[float, float, float]]:
        out, start, level = [], self.t0, 0
        while start < until:
            end = min(start + self.step_dur, until)
            out.append((start, end, self._level_rate(level)))
            level = (level + 1) % self.steps
            start = end
        return out


class Epochs(ShapedArrivals):
    """Cycle through an explicit list of rates, ``epoch_dur`` apiece —
    the shape for workloads with distinct repeating phases (train /
    eval / checkpoint epochs driving inference side-traffic)."""

    def __init__(self, n: int, *, rates: Sequence[float],
                 epoch_dur: float = 60.0, **kw):
        super().__init__(n, **kw)
        if not rates:
            raise ValueError("epochs needs at least one rate")
        self.rates = tuple(float(r) for r in rates)
        self.epoch_dur = float(epoch_dur)

    def rate(self, t: float) -> float:
        cycle = len(self.rates) * self.epoch_dur
        idx = int(((t - self.t0) % cycle) // self.epoch_dur)
        return self.rates[idx]

    @property
    def rate_max(self) -> float:
        return max(self.rates)

    def segments(self, until: float) -> List[Tuple[float, float, float]]:
        out, start, idx = [], self.t0, 0
        while start < until:
            end = min(start + self.epoch_dur, until)
            out.append((start, end, self.rates[idx]))
            idx = (idx + 1) % len(self.rates)
            start = end
        return out


class StagedPlateau(ShapedArrivals):
    """An explicit sequence of ``(rate, duration)`` plateaus, holding
    the final stage's rate forever after (so the stream always drains
    its ``n`` requests)."""

    def __init__(self, n: int, *, stages: Sequence[Tuple[float, float]],
                 **kw):
        super().__init__(n, **kw)
        if not stages:
            raise ValueError("staged_plateau needs at least one stage")
        self.stages = tuple((float(r), float(d)) for r, d in stages)

    def rate(self, t: float) -> float:
        off = t - self.t0
        for r, d in self.stages:
            if off < d:
                return r
            off -= d
        return self.stages[-1][0]

    @property
    def rate_max(self) -> float:
        return max(r for r, _ in self.stages)

    def segments(self, until: float) -> List[Tuple[float, float, float]]:
        out, start = [], self.t0
        for r, d in self.stages:
            if start >= until:
                return out
            end = min(start + d, until)
            out.append((start, end, r))
            start = end
        if start < until:
            out.append((start, until, self.stages[-1][0]))
        return out


class Diurnal(ShapedArrivals):
    """The million-user day/night cycle: a smooth sinusoid from
    ``base_rate`` (midnight trough, at ``t0``) up to ``peak_rate``
    (midday) over each ``day`` — the canonical piecewise-rate
    nonhomogeneous Poisson trace for fleet-scale runs."""

    def __init__(self, n: int, *, base_rate: float, peak_rate: float,
                 day: float = 86_400.0, **kw):
        super().__init__(n, **kw)
        self.base_rate = float(base_rate)
        self.peak_rate = float(peak_rate)
        self.day = float(day)

    def rate(self, t: float) -> float:
        phase = 2.0 * math.pi * (t - self.t0) / self.day
        # 0 at t0 (trough), 1 at half-day (peak)
        lift = 0.5 * (1.0 - math.cos(phase))
        return self.base_rate + (self.peak_rate - self.base_rate) * lift

    @property
    def rate_max(self) -> float:
        return max(self.base_rate, self.peak_rate)

    def segments(self, until: float) -> List[Tuple[float, float, float]]:
        out, start = [], self.t0
        quarter = self.day / 4.0
        while start < until:
            end = min(start + quarter, until)
            out.append((start, end, self._mean_rate(start, end)))
            start = end
        return out


def make_shape(name: str, n: int, *, rate: float, period: float = 60.0,
               seed: int = 0, **kw) -> ShapedArrivals:
    """Build a catalogue shape parameterized around a target long-run
    mean ``rate`` (requests/virtual-second).

    Each shape's amplitude is fixed relative to that mean — e.g.
    ``pulse_spikes`` idles at 0.5x and spikes to 3x — so one knob scales
    any shape to a fleet's capacity.  ``period`` sets the pattern
    length (the diurnal shape's "day").
    """
    if name == "pulse_spikes":
        # mean = 0.2*3r + 0.8*0.5r = r
        return PulseSpikes(n, base_rate=0.5 * rate, spike_rate=3.0 * rate,
                           period=period, spike_frac=0.2, seed=seed, **kw)
    if name == "sawtooth":
        return Sawtooth(n, low=0.5 * rate, high=1.5 * rate,
                        period=period, seed=seed, **kw)
    if name == "staircase":
        return Staircase(n, low=0.4 * rate, high=1.6 * rate, steps=4,
                         step_dur=period / 4.0, seed=seed, **kw)
    if name == "epochs":
        return Epochs(n, rates=(0.5 * rate, 1.5 * rate, 0.8 * rate,
                                1.2 * rate),
                      epoch_dur=period / 4.0, seed=seed, **kw)
    if name == "staged_plateau":
        return StagedPlateau(n, stages=((1.5 * rate, period),
                                        (0.5 * rate, period),
                                        (1.0 * rate, period)),
                             seed=seed, **kw)
    if name == "diurnal":
        return Diurnal(n, base_rate=0.4 * rate, peak_rate=1.6 * rate,
                       day=period, seed=seed, **kw)
    raise ValueError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")


SHAPES = {
    "pulse_spikes": PulseSpikes,
    "sawtooth": Sawtooth,
    "staircase": Staircase,
    "epochs": Epochs,
    "staged_plateau": StagedPlateau,
    "diurnal": Diurnal,
}
