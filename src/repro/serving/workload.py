"""Synthetic serving workloads shared by benchmarks, tests, and CLIs."""

from __future__ import annotations

from typing import List, Tuple, Union

import numpy as np

from repro.serving.engine import Request


def synthetic_requests(n: int, vocab_size: int, *, seed: int = 0,
                       prompt_len: Tuple[int, int] = (3, 9),
                       max_new: Union[int, Tuple[int, int]] = (4, 10),
                       start_rid: int = 0) -> List[Request]:
    """``n`` random-token requests; lengths drawn from half-open ranges."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(start_rid, start_rid + n):
        plen = int(rng.integers(*prompt_len))
        new = max_new if isinstance(max_new, int) \
            else int(rng.integers(*max_new))
        reqs.append(Request(rid=rid,
                            prompt=rng.integers(0, vocab_size, plen,
                                                dtype=np.int32),
                            max_new_tokens=new))
    return reqs
