"""Synthetic serving workloads shared by benchmarks, tests, and CLIs.

Besides the request generator, this module defines the open-loop
``ArrivalProcess`` family: iterables of ``(arrival_t, Request)`` that a
``ServingCluster`` consumes one event at a time (each arrival schedules
the next), so load is offered at a rate independent of service progress —
in contrast to the closed-loop ``BatchArrivals`` baseline that dumps the
whole batch at t0.
"""

from __future__ import annotations

from typing import Iterator, List, Sequence, Tuple, Union

import numpy as np

from repro.serving.engine import Request


def synthetic_requests(n: int, vocab_size: int, *, seed: int = 0,
                       prompt_len: Tuple[int, int] = (3, 9),
                       max_new: Union[int, Tuple[int, int]] = (4, 10),
                       start_rid: int = 0) -> List[Request]:
    """``n`` random-token requests; lengths drawn from half-open ranges."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(start_rid, start_rid + n):
        plen = int(rng.integers(*prompt_len))
        new = max_new if isinstance(max_new, int) \
            else int(rng.integers(*max_new))
        reqs.append(Request(rid=rid,
                            prompt=rng.integers(0, vocab_size, plen,
                                                dtype=np.int32),
                            max_new_tokens=new))
    return reqs


def prefill_heavy_requests(n: int, vocab_size: int, *, prompt_len: int = 64,
                           max_new: int = 8, seed: int = 0,
                           start_rid: int = 0) -> List[Request]:
    """Fixed-length long-prompt requests: the prefill-dominated workload
    the chunked-bulk-prefill path is measured on (``engine_throughput``).
    All prompts share one length so streamed-vs-chunked timing isolates
    the prefill strategy, not workload variance."""
    rng = np.random.default_rng(seed)
    return [Request(rid=rid,
                    prompt=rng.integers(0, vocab_size, prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for rid in range(start_rid, start_rid + n)]


# ------------------------------------------------------------- arrivals
class ArrivalProcess:
    """Iterable of ``(arrival_t, Request)`` pairs, time-ordered."""

    def __iter__(self) -> Iterator[Tuple[float, Request]]:
        raise NotImplementedError


class BatchArrivals(ArrivalProcess):
    """Closed-loop baseline: the whole batch is submitted at ``t0``."""

    def __init__(self, requests: Sequence[Request], t0: float = 0.0):
        self.requests = list(requests)
        self.t0 = t0

    def __iter__(self):
        for req in self.requests:
            yield self.t0, req


class PoissonArrivals(ArrivalProcess):
    """Open-loop Poisson process: seeded exponential inter-arrival gaps
    at ``rate`` requests per virtual second."""

    def __init__(self, requests: Sequence[Request], rate: float, *,
                 seed: int = 0, t0: float = 0.0):
        if rate <= 0:
            raise ValueError(f"poisson arrival rate must be > 0, got {rate}")
        self.requests = list(requests)
        self.rate = rate
        self.seed = seed
        self.t0 = t0

    def __iter__(self):
        rng = np.random.default_rng(self.seed)
        t = self.t0
        for req in self.requests:
            t += float(rng.exponential(1.0 / self.rate))
            yield t, req


class TraceArrivals(ArrivalProcess):
    """Trace-driven arrivals: explicit timestamps, one per request.

    A trace shorter than the request list truncates it; extra timestamps
    are ignored.
    """

    def __init__(self, requests: Sequence[Request],
                 times: Sequence[float]):
        self.requests = list(requests)
        self.times = sorted(float(t) for t in times)

    @classmethod
    def from_file(cls, path: str,
                  requests: Sequence[Request]) -> "TraceArrivals":
        """Trace file: one arrival timestamp per line (# comments)."""
        times = []
        with open(path) as fh:
            for line in fh:
                line = line.split("#", 1)[0].strip()
                if line:
                    times.append(float(line))
        return cls(requests, times)

    def __iter__(self):
        for t, req in zip(self.times, self.requests):
            yield t, req


def make_arrivals(spec: str, requests: Sequence[Request], *,
                  seed: int = 0) -> ArrivalProcess:
    """Build an arrival process from a CLI spec.

    ``batch`` | ``poisson:<rate>`` | ``trace:<file>``
    """
    if spec == "batch":
        return BatchArrivals(requests)
    kind, _, arg = spec.partition(":")
    if kind == "poisson" and arg:
        return PoissonArrivals(requests, float(arg), seed=seed)
    if kind == "trace" and arg:
        return TraceArrivals.from_file(arg, requests)
    raise ValueError(
        f"unknown arrival spec {spec!r}; "
        f"expected batch | poisson:<rate> | trace:<file>")
