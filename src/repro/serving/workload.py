"""Synthetic serving workloads shared by benchmarks, tests, and CLIs.

Besides the request generator, this module defines:

* the per-request ``SLOClass`` vocabulary (deadline + priority) the
  cluster's admission/routing layer consumes;
* the open-loop ``ArrivalProcess`` family: iterables of
  ``(arrival_t, Request)`` that a ``ServingCluster`` consumes one event
  at a time (each arrival schedules the next), so load is offered at a
  rate independent of service progress;
* the closed-loop ``ClosedLoopThinkTime`` process: ``n_users``
  concurrent sessions, each re-arming its next arrival an exponential
  think time after its previous request completes — offered load tracks
  completions instead of an external clock.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.serving.engine import Request


# ----------------------------------------------------------------- SLOs
@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A service-level objective: completion deadline + admission rank.

    ``priority`` orders admission and routing (lower = more urgent —
    interactive requests queue-jump batch ones); ``deadline`` is the
    per-request completion budget in virtual seconds from arrival
    (``inf`` = best-effort).  ``admit_lazily`` marks classes that should
    only be admitted while the fleet has backlog headroom, so they never
    crowd out latency-sensitive work.
    """
    name: str
    priority: int
    deadline: float = math.inf
    admit_lazily: bool = False


INTERACTIVE = SLOClass("interactive", 0, deadline=15.0)
STANDARD = SLOClass("standard", 1)
BATCH = SLOClass("batch", 2, deadline=300.0, admit_lazily=True)
SLO_CLASSES = {c.name: c for c in (INTERACTIVE, STANDARD, BATCH)}


def synthetic_requests(n: int, vocab_size: int, *, seed: int = 0,
                       prompt_len: Tuple[int, int] = (3, 9),
                       max_new: Union[int, Tuple[int, int]] = (4, 10),
                       start_rid: int = 0) -> List[Request]:
    """``n`` random-token requests; lengths drawn from half-open ranges."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(start_rid, start_rid + n):
        plen = int(rng.integers(*prompt_len))
        new = max_new if isinstance(max_new, int) \
            else int(rng.integers(*max_new))
        reqs.append(Request(rid=rid,
                            prompt=rng.integers(0, vocab_size, plen,
                                                dtype=np.int32),
                            max_new_tokens=new))
    return reqs


def prefill_heavy_requests(n: int, vocab_size: int, *, prompt_len: int = 64,
                           max_new: int = 8, seed: int = 0,
                           start_rid: int = 0) -> List[Request]:
    """Fixed-length long-prompt requests: the prefill-dominated workload
    the chunked-bulk-prefill path is measured on (``engine_throughput``).
    All prompts share one length so streamed-vs-chunked timing isolates
    the prefill strategy, not workload variance."""
    rng = np.random.default_rng(seed)
    return [Request(rid=rid,
                    prompt=rng.integers(0, vocab_size, prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for rid in range(start_rid, start_rid + n)]


def classed_requests(n: int, vocab_size: int, *, interactive_frac: float = 0.5,
                     seed: int = 0, start_rid: int = 0,
                     interactive: SLOClass = INTERACTIVE,
                     batch: SLOClass = BATCH,
                     interactive_shape: Tuple[Tuple[int, int],
                                              Tuple[int, int]] = ((3, 8),
                                                                  (3, 7)),
                     batch_shape: Tuple[Tuple[int, int],
                                        Tuple[int, int]] = ((6, 14),
                                                            (10, 18)),
                     model_ids: Sequence[str] = ("default",)
                     ) -> List[Request]:
    """A seeded interactive/batch request mix for SLO scenarios.

    Interactive requests are short (chat-turn shaped) with a tight
    deadline; batch requests are longer (summarize/extract shaped) with a
    loose one.  ``model_ids`` round-robins requests over a multi-model
    fleet's pools; shapes are ``((plen_lo, plen_hi), (new_lo, new_hi))``
    half-open ranges.
    """
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(start_rid, start_rid + n):
        if rng.random() < interactive_frac:
            (plo, phi), (nlo, nhi) = interactive_shape
            slo = interactive
        else:
            (plo, phi), (nlo, nhi) = batch_shape
            slo = batch
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(0, vocab_size, int(rng.integers(plo, phi)),
                                dtype=np.int32),
            max_new_tokens=int(rng.integers(nlo, nhi)),
            slo=slo,
            model_id=model_ids[rid % len(model_ids)]))
    return reqs


# ------------------------------------------------------------- arrivals
class ArrivalProcess:
    """Iterable of ``(arrival_t, Request)`` pairs, time-ordered."""

    def __iter__(self) -> Iterator[Tuple[float, Request]]:
        raise NotImplementedError


class BatchArrivals(ArrivalProcess):
    """Closed-loop baseline: the whole batch is submitted at ``t0``."""

    def __init__(self, requests: Sequence[Request], t0: float = 0.0):
        self.requests = list(requests)
        self.t0 = t0

    def __iter__(self):
        for req in self.requests:
            yield self.t0, req


class PoissonArrivals(ArrivalProcess):
    """Open-loop Poisson process: seeded exponential inter-arrival gaps
    at ``rate`` requests per virtual second."""

    def __init__(self, requests: Sequence[Request], rate: float, *,
                 seed: int = 0, t0: float = 0.0):
        if rate <= 0:
            raise ValueError(f"poisson arrival rate must be > 0, got {rate}")
        self.requests = list(requests)
        self.rate = rate
        self.seed = seed
        self.t0 = t0

    def __iter__(self):
        rng = np.random.default_rng(self.seed)
        t = self.t0
        for req in self.requests:
            t += float(rng.exponential(1.0 / self.rate))
            yield t, req


class TraceArrivals(ArrivalProcess):
    """Trace-driven arrivals: explicit timestamps, one per request.

    A trace shorter than the request list truncates it; extra timestamps
    are ignored.
    """

    def __init__(self, requests: Sequence[Request],
                 times: Sequence[float]):
        self.requests = list(requests)
        self.times = sorted(float(t) for t in times)

    @classmethod
    def from_file(cls, path: str,
                  requests: Sequence[Request]) -> "TraceArrivals":
        """Trace file: one arrival timestamp per line (# comments)."""
        times = []
        with open(path) as fh:
            for line in fh:
                line = line.split("#", 1)[0].strip()
                if line:
                    times.append(float(line))
        return cls(requests, times)

    def __iter__(self):
        for t, req in zip(self.times, self.requests):
            yield t, req


# ----------------------------------------------------------- closed loop
class ClosedLoopThinkTime:
    """Closed-loop offered load: ``n_users`` concurrent sessions.

    Each session submits one request at a time; when its request
    completes at ``t`` the next one arrives at ``t + Exp(think_mean)``.
    Unlike the open-loop processes, offered load *tracks completions* —
    a saturated fleet sees at most ``n_users`` requests in flight, and a
    faster fleet is offered proportionally more load.

    Protocol (consumed by ``ServingCluster.attach_closed_loop``):

    * ``initial()``            — the first ``n_users`` arrivals at ``t0``;
    * ``on_complete(req, t)``  — called at every request completion;
                                 returns ``(t_next, next_request)`` or
                                 ``None`` when the session list is spent.

    ``issued`` / ``completed`` log ``(t, rid)`` pairs so tests can assert
    the in-flight population never exceeds ``n_users`` and every re-arm
    strictly follows the completion that triggered it.
    """

    def __init__(self, requests: Sequence[Request], *, n_users: int = 2,
                 think_mean: float = 1.0, seed: int = 0, t0: float = 0.0):
        if think_mean < 0:
            raise ValueError(f"think_mean must be >= 0, got {think_mean}")
        self.requests = list(requests)
        self.n_users = max(int(n_users), 1)
        self.think_mean = float(think_mean)
        self.t0 = t0
        self._rng = np.random.default_rng(seed)
        self._next = 0
        self._outstanding: set = set()   # rids this process issued, live
        self.issued: List[Tuple[float, int]] = []
        self.completed: List[Tuple[float, int]] = []

    def initial(self) -> List[Tuple[float, Request]]:
        first = []
        while self._next < min(self.n_users, len(self.requests)):
            req = self.requests[self._next]
            self._next += 1
            first.append((self.t0, req))
            self.issued.append((self.t0, req.rid))
            self._outstanding.add(req.rid)
        return first

    def on_complete(self, req: Request,
                    t: float) -> Optional[Tuple[float, Request]]:
        # the cluster fires completion hooks for EVERY finished request;
        # a session only frees when one of OUR requests completes —
        # foreign (open-loop / submitted) traffic must not re-arm us
        if req.rid not in self._outstanding:
            return None
        self._outstanding.discard(req.rid)
        self.completed.append((t, req.rid))
        if self._next >= len(self.requests):
            return None
        nxt = self.requests[self._next]
        self._next += 1
        t_next = t + float(self._rng.exponential(self.think_mean)) \
            if self.think_mean > 0 else t
        self.issued.append((t_next, nxt.rid))
        self._outstanding.add(nxt.rid)
        return t_next, nxt


def make_arrivals(spec: str, requests: Sequence[Request], *,
                  seed: int = 0) -> ArrivalProcess:
    """Build an arrival process from a CLI spec.

    ``batch`` | ``poisson:<rate>`` | ``trace:<file>``
    """
    if spec == "batch":
        return BatchArrivals(requests)
    kind, _, arg = spec.partition(":")
    if kind == "poisson" and arg:
        return PoissonArrivals(requests, float(arg), seed=seed)
    if kind == "trace" and arg:
        return TraceArrivals.from_file(arg, requests)
    raise ValueError(
        f"unknown arrival spec {spec!r}; "
        f"expected batch | poisson:<rate> | trace:<file>")
