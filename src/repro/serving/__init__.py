from repro.serving.engine import (DEFAULT_PREFILL_BUCKETS,
                                  DEFAULT_PREFILL_DISCOUNT, Request,
                                  ServingEngine, SlotSnapshot, request_cost)
