"""Declarative parameter schemas.

A schema is a nested dict mapping param name -> ``Spec(shape, axes, init)``:

* ``shape``  — global shape
* ``axes``   — logical axis name per dim (see launch/sharding.py for the
               logical->mesh mapping); ``None`` = never sharded
* ``init``   — 'normal' (1/sqrt(fan_in)), 'embed', 'zeros', 'ones',
               'ssm_a', 'ssm_dt'

From one schema we derive: real initialized params (smoke tests / training),
``jax.ShapeDtypeStruct`` stand-ins (dry-run), and PartitionSpecs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"
    dtype: Optional[str] = None  # override param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _fan_in(spec: Spec) -> int:
    # Last dim is fan-out by convention; everything else but stacking dims
    # ('layers', 'periods', 'stack') contributes to fan-in.
    fan = 1
    for dim, ax in zip(spec.shape[:-1], spec.axes[:-1]):
        if ax not in ("layers", "periods", "stack"):
            fan *= dim
    return max(fan, 1)


def init_one(spec: Spec, key: jax.Array, dtype) -> jax.Array:
    dt = jnp.dtype(spec.dtype or dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "embed":
        return (0.02 * jax.random.normal(key, spec.shape)).astype(dt)
    if spec.init == "ssm_a":  # A_log: log of A in [1, 16]
        u = jax.random.uniform(key, spec.shape, minval=1.0, maxval=16.0)
        return jnp.log(u).astype(dt)
    if spec.init == "ssm_dt":  # dt_bias: softplus^-1 of dt in [1e-3, 1e-1]
        u = jax.random.uniform(key, spec.shape, minval=math.log(1e-3),
                               maxval=math.log(1e-1))
        dtv = jnp.exp(u)
        return (dtv + jnp.log(-jnp.expm1(-dtv))).astype(dt)
    scale = 1.0 / math.sqrt(_fan_in(spec))
    return (scale * jax.random.normal(key, spec.shape)).astype(dt)


def init_params(schema, key: jax.Array, dtype="float32"):
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [init_one(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(schema, dtype="float32"):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or dtype)),
        schema, is_leaf=is_spec)


def param_logical_axes(schema):
    return jax.tree.map(lambda s: s.axes, schema, is_leaf=is_spec)


def count_params(schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))
