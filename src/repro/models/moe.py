"""GShard/Switch-style top-k MoE with capacity-bounded scatter dispatch.

Dispatch is sort-free: for each of the k routing choices we compute the
token's position-in-expert with a cumulative sum over the one-hot expert
assignment, drop tokens past ``capacity``, and scatter token activations into
a per-expert buffer of shape (E, C, d).  Expert FFNs then run as one batched
einsum with the expert dim sharded over the 'model' mesh axis (EP); GSPMD
materializes the token redistribution as all-to-all / collective traffic,
which the roofline analysis measures.

qwen2-moe's 60 experts do not divide the 16-way model axis; the sharding
rules fall back to sharding each expert's d_ff (see launch/sharding.py), so
the layer keeps a TP dimension without uneven input shardings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import constrain
from repro.models import layers as L
from repro.models.schema import Spec


def moe_schema(cfg: ModelConfig, stacked=None, prefix="layers"):
    st = (stacked,) if stacked is not None else ()
    sa = (prefix,) if stacked is not None else ()
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    sch = {
        "norm": Spec(st + (d,), sa + (None,), "ones"),
        "router": Spec(st + (d, E), sa + ("embed", None)),
        "we_gate": Spec(st + (E, d, f), sa + ("experts", "embed", "expert_ff")),
        "we_up": Spec(st + (E, d, f), sa + ("experts", "embed", "expert_ff")),
        "we_down": Spec(st + (E, f, d), sa + ("experts", "expert_ff", "embed")),
    }
    if cfg.num_shared_experts:
        fs = cfg.num_shared_experts * f
        sch.update({
            "ws_gate": Spec(st + (d, fs), sa + ("embed", "ff")),
            "ws_up": Spec(st + (d, fs), sa + ("embed", "ff")),
            "ws_down": Spec(st + (fs, d), sa + ("ff", "embed")),
        })
    return sch


def expert_capacity(cfg: ModelConfig, num_tokens: int) -> int:
    cap = int(cfg.capacity_factor * num_tokens * cfg.top_k / cfg.num_experts)
    return max(8, min(cap, num_tokens))


def route(router_logits, cfg: ModelConfig):
    """top-k routing. router_logits: (T, E) fp32.

    Returns (expert_idx (T,k), weights (T,k), aux_loss scalar).
    """
    probs = jax.nn.softmax(router_logits, axis=-1)
    weights, expert_idx = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss
    E = cfg.num_experts
    density = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * (E ** 2) / E
    return expert_idx, weights, aux * cfg.router_aux_weight


def moe_block(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d), aux_loss.  Dispatches to the best
    available implementation:

    1. ``_moe_explicit_ep`` — partial-manual shard_map over the 'model'
       axis: activations are already replicated over 'model', so each
       expert shard gathers its own experts' tokens LOCALLY and the only
       communication is one psum of the combined output per layer.
       Requires an active mesh with E % model_size == 0.
    2. ``_moe_grouped`` — pure-pjit sort-based grouped dispatch (GShard
       capacity sharding).  Fallback for CPU smoke tests and for archs
       whose expert count does not divide the model axis (qwen2's 60).

    The O(kT*E) one-hot/cumsum form is kept as ``moe_block_onehot`` (the
    paper-era baseline; see EXPERIMENTS.md §Perf for the measured ladder).
    """
    from repro.launch.sharding import active_rules
    if cfg.moe_impl == "onehot":
        return moe_block_onehot(p, x, cfg)
    rules = active_rules()
    E = cfg.num_experts
    if cfg.moe_impl != "grouped" and rules is not None \
            and "model" in rules.axes:
        msize = rules.mesh.shape["model"]
        if msize > 1 and E % msize == 0:
            return _moe_explicit_ep(p, x, cfg, rules, msize)
    return _moe_grouped(p, x, cfg)


def _routing_tables(p, ht, cfg: ModelConfig, G: int, Tg: int):
    """Shared routing math: slot->token / slot->weight tables per group.

    ht: (G, Tg, d).  Returns (tok_of_slot, w_of_slot) with shape
    (G, E*C) plus (aux, C)."""
    E, k = cfg.num_experts, cfg.top_k
    C = expert_capacity(cfg, Tg)
    kTg = k * Tg
    router_logits = jnp.einsum(
        "gtd,de->gte", ht.astype(jnp.float32),
        p["router"].astype(jnp.float32))
    expert_idx, weights, aux = route(router_logits.reshape(G * Tg, E), cfg)
    expert_idx = expert_idx.reshape(G, Tg, k)
    weights = weights.reshape(G, Tg, k)

    flat_e = jnp.swapaxes(expert_idx, 1, 2).reshape(G, kTg)
    flat_tok = jnp.tile(jnp.arange(Tg, dtype=jnp.int32), (G, k))
    flat_w = jnp.swapaxes(weights, 1, 2).reshape(G, kTg)

    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    counts = jax.vmap(lambda fe: jnp.bincount(fe, length=E))(flat_e)
    starts = jnp.cumsum(counts, axis=1) - counts
    rank = (jnp.arange(kTg, dtype=jnp.int32)[None]
            - jnp.take_along_axis(starts, sorted_e, axis=1))
    keep = rank < C
    slot = sorted_e * C + jnp.clip(rank, 0, C - 1)
    slot_or_oob = jnp.where(keep, slot, E * C)

    gidx = jnp.arange(G)[:, None]
    tok_of_slot = jnp.full((G, E * C + 1), Tg, jnp.int32).at[
        gidx, slot_or_oob].set(jnp.take_along_axis(flat_tok, order, axis=1),
                               mode="drop")[:, :E * C]
    w_of_slot = jnp.zeros((G, E * C + 1), jnp.float32).at[
        gidx, slot_or_oob].set(jnp.take_along_axis(flat_w, order, axis=1),
                               mode="drop")[:, :E * C]
    return tok_of_slot, w_of_slot, aux, C


def _moe_explicit_ep(p, x, cfg: ModelConfig, rules, msize: int):
    """Explicit expert parallelism: FULLY manual shard_map.

    Batch is sharded over the non-'model' axes and replicated over 'model';
    expert weights are sharded over 'model'.  Each device routes its local
    tokens, gathers its own experts' tokens locally (zero-communication
    dispatch), runs the expert FFNs, and the ONLY collective is one f32
    psum of the combined output over 'model' per layer.  (Fully-manual
    shard_map avoids two XLA-CPU partial-manual/all-reduce-promotion
    compiler bugs hit along the way — see EXPERIMENTS.md §Perf.)
    """
    from jax.sharding import PartitionSpec as P
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    E_loc = E // msize
    mesh = rules.mesh
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    if b % dp != 0:
        return _moe_grouped(p, x, cfg)

    h = L.rms_norm(x, p["norm"], cfg.norm_eps).astype(dt)

    def body(ht, router_w, we_gate, we_up, we_down):
        # ht: LOCAL (b/dp, s, d) f32 (f32 boundary: AD's psum of a bf16
        # cotangent crashes XLA-CPU's AllReducePromotion pass)
        ht = ht.astype(dt)
        b_loc = ht.shape[0]
        T_loc = b_loc * s
        m = jax.lax.axis_index("model")
        htg = ht.reshape(1, T_loc, d)
        tok_of_slot, w_of_slot, aux, C = _routing_tables(
            {"router": router_w}, htg, cfg, 1, T_loc)
        # slice this shard's experts' slots: dispatch is fully local
        tok_local = jax.lax.dynamic_slice_in_dim(
            tok_of_slot.reshape(E, C), m * E_loc, E_loc, axis=0)
        w_local = jax.lax.dynamic_slice_in_dim(
            w_of_slot.reshape(E, C), m * E_loc, E_loc, axis=0)
        tok_local = tok_local.reshape(E_loc * C)
        # local dispatch gather (pad row = dropped/empty slots)
        ht_pad = jnp.concatenate(
            [ht.reshape(T_loc, d), jnp.zeros((1, d), dt)], axis=0)
        buf = ht_pad[tok_local].reshape(E_loc, C, d)
        # local expert FFNs
        g = jnp.einsum("ecd,edf->ecf", buf, we_gate.astype(dt))
        u = jnp.einsum("ecd,edf->ecf", buf, we_up.astype(dt))
        out_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                             we_down.astype(dt))
        # local combine (f32 partial sums) + THE one collective
        out_flat = out_buf.reshape(E_loc * C, d).astype(jnp.float32) * \
            w_local.reshape(E_loc * C, 1)
        partial = jnp.zeros((T_loc, d), jnp.float32).at[tok_local].add(
            out_flat, mode="drop")
        out = jax.lax.psum(partial, "model").astype(dt)
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return out.reshape(b_loc, s, d), aux

    wspec = P("model", None, None)
    from repro.core.compat import shard_map
    sm = shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(), wspec, wspec, wspec),
        out_specs=(P(batch_axes, None, None), P()),
        check_vma=False)
    out, aux = sm(h.astype(jnp.float32), p["router"], p["we_gate"],
                  p["we_up"], p["we_down"])
    out = out.astype(dt)

    if cfg.num_shared_experts:
        gs = jnp.einsum("bsd,df->bsf", h, p["ws_gate"].astype(dt))
        us = jnp.einsum("bsd,df->bsf", h, p["ws_up"].astype(dt))
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gs) * us,
                               p["ws_down"].astype(dt))
    return x + constrain(out, "batch", None, "embed"), aux


def _moe_grouped(p, x, cfg: ModelConfig):
    """Pure-pjit sort-based grouped dispatch (GShard capacity sharding)."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    T = b * s
    E, k = cfg.num_experts, cfg.top_k
    G = cfg.moe_groups if T % max(cfg.moe_groups, 1) == 0 else 1
    Tg = T // G
    C = expert_capacity(cfg, Tg)    # per-group capacity (GShard sharding)
    kTg = k * Tg

    h = L.rms_norm(x, p["norm"], cfg.norm_eps).astype(dt)
    ht = h.reshape(G, Tg, d)
    ht = constrain(ht, "batch", None, "embed")
    router_logits = jnp.einsum(
        "gtd,de->gte", ht.astype(jnp.float32),
        p["router"].astype(jnp.float32))
    expert_idx, weights, aux = route(router_logits.reshape(G * Tg, E), cfg)
    expert_idx = expert_idx.reshape(G, Tg, k)
    weights = weights.reshape(G, Tg, k)

    # choice-major flattening per group: first choices precede second
    # choices, so the stable sort preserves Switch-style drop priority.
    flat_e = jnp.swapaxes(expert_idx, 1, 2).reshape(G, kTg)    # (G, kTg)
    flat_tok = jnp.tile(jnp.arange(Tg, dtype=jnp.int32), (G, k))
    flat_w = jnp.swapaxes(weights, 1, 2).reshape(G, kTg)

    order = jnp.argsort(flat_e, axis=1, stable=True)           # (G, kTg)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    counts = jax.vmap(lambda fe: jnp.bincount(fe, length=E))(flat_e)
    starts = jnp.cumsum(counts, axis=1) - counts               # (G, E)
    rank = (jnp.arange(kTg, dtype=jnp.int32)[None]
            - jnp.take_along_axis(starts, sorted_e, axis=1))
    keep = rank < C                                            # capacity drop
    slot = sorted_e * C + jnp.clip(rank, 0, C - 1)             # (G, kTg)
    slot_or_oob = jnp.where(keep, slot, E * C)                 # OOB -> drop

    # slot -> (token, weight) per group; empty slots hit a zero pad row
    gidx = jnp.arange(G)[:, None]
    tok_of_slot = jnp.full((G, E * C + 1), Tg, jnp.int32).at[
        gidx, slot_or_oob].set(jnp.take_along_axis(flat_tok, order, axis=1),
                               mode="drop")[:, :E * C]
    w_of_slot = jnp.zeros((G, E * C + 1), jnp.float32).at[
        gidx, slot_or_oob].set(jnp.take_along_axis(flat_w, order, axis=1),
                               mode="drop")[:, :E * C]
    tok_of_slot = constrain(tok_of_slot, "batch", None)
    w_of_slot = constrain(w_of_slot, "batch", None)

    # dispatch: a group-local batched gather, then reshard (G,data)x(E,model)
    # -> the MoE all-to-all
    ht_pad = jnp.concatenate([ht, jnp.zeros((G, 1, d), dt)], axis=1)
    buf = jnp.take_along_axis(
        ht_pad, tok_of_slot[:, :, None], axis=1)               # (G, E*C, d)
    buf = buf.reshape(G, E, C, d)
    buf = constrain(buf, "batch", "experts", None, "embed")

    # expert FFNs as batched einsums (EP over 'model')
    g = jnp.einsum("gecd,edf->gecf", buf, p["we_gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", buf, p["we_up"].astype(dt))
    act = constrain(jax.nn.silu(g) * u, "batch", "experts", None,
                    "expert_ff")
    out_buf = jnp.einsum("gecf,efd->gecd", act, p["we_down"].astype(dt))
    out_buf = constrain(out_buf, "batch", "experts", None, "embed")

    # combine: all-to-all back, then a group-local weighted scatter-add.
    # f32 scatter: partial-sum all-reduces of bf16 crash XLA-CPU's
    # AllReducePromotion pass (and f32 is better combine numerics anyway)
    out_flat = out_buf.reshape(G, E * C, d).astype(jnp.float32) * \
        w_of_slot[:, :, None]
    out_flat = constrain(out_flat, "batch", None, "embed")
    # batched scatter-add with a d-wide window; empty slots carry tok=Tg
    # (out of bounds) and are dropped
    combined = jnp.zeros((G, Tg, d), jnp.float32).at[gidx, tok_of_slot].add(
        out_flat, mode="drop").astype(dt)
    out = combined.reshape(b, s, d)

    if cfg.num_shared_experts:
        gs = jnp.einsum("bsd,df->bsf", h, p["ws_gate"].astype(dt))
        us = jnp.einsum("bsd,df->bsf", h, p["ws_up"].astype(dt))
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gs) * us,
                               p["ws_down"].astype(dt))
    return x + constrain(out, "batch", None, "embed"), aux


def moe_block_onehot(p, x, cfg: ModelConfig):
    """Paper-era one-hot/cumsum dispatch (GShard formulation).

    Kept as the §Perf baseline and as a second oracle for the sort-based
    path; O(kT*E) dispatch temporaries."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    T = b * s
    E, k = cfg.num_experts, cfg.top_k
    C = expert_capacity(cfg, T)

    h = L.rms_norm(x, p["norm"], cfg.norm_eps).astype(dt)
    ht = h.reshape(T, d)
    router_logits = jnp.einsum(
        "td,de->te", ht.astype(jnp.float32), p["router"].astype(jnp.float32))
    expert_idx, weights, aux = route(router_logits, cfg)

    flat_e = expert_idx.T.reshape(-1)              # (k*T,) choice-major
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (kT, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                       # (kT, E)
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < C

    tok_idx = jnp.tile(jnp.arange(T), k)
    buf = jnp.zeros((E, C, d), dt)
    src = ht[tok_idx] * keep[:, None].astype(dt)
    buf = buf.at[flat_e, jnp.clip(pos_in_e, 0, C - 1)].add(
        src, mode="drop")
    buf = constrain(buf, "experts", None, "embed")

    g = jnp.einsum("ecd,edf->ecf", buf, p["we_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, p["we_up"].astype(dt))
    act = constrain(jax.nn.silu(g) * u, "experts", None, "expert_ff")
    out_buf = jnp.einsum("ecf,efd->ecd", act, p["we_down"].astype(dt))
    out_buf = constrain(out_buf, "experts", None, "embed")

    flat_w = weights.T.reshape(-1).astype(dt) * keep.astype(dt)
    gathered = out_buf[flat_e, jnp.clip(pos_in_e, 0, C - 1)]   # (kT, d)
    combined = jnp.zeros((T, d), dt).at[tok_idx].add(
        gathered * flat_w[:, None])
    out = combined.reshape(b, s, d)

    if cfg.num_shared_experts:
        gs = jnp.einsum("bsd,df->bsf", h, p["ws_gate"].astype(dt))
        us = jnp.einsum("bsd,df->bsf", h, p["ws_up"].astype(dt))
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gs) * us,
                               p["ws_down"].astype(dt))
    return x + constrain(out, "batch", None, "embed"), aux
