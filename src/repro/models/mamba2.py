"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Training/prefill uses the chunked SSD form: within a chunk the output is a
(masked) quadratic attention-like product; across chunks a small recurrent
state (H heads x P head_dim x N ssm_state) is passed.  Decode is the O(1)
per-token recurrence on that state.  The chunk kernel has a Pallas TPU
implementation in kernels/ssd/ validated against the pure-jnp path here.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import constrain
from repro.models import layers as L
from repro.models.schema import Spec


def mamba2_dims(cfg: ModelConfig):
    d_inner = cfg.d_inner
    nheads = cfg.ssm_heads
    conv_dim = d_inner + 2 * cfg.ssm_state  # x + B + C (single group)
    d_in_proj = 2 * d_inner + 2 * cfg.ssm_state + nheads  # z,x,B,C,dt
    return d_inner, nheads, conv_dim, d_in_proj


def mamba2_schema(cfg: ModelConfig, stacked: Optional[tuple] = None,
                  prefix: Tuple[str, ...] = ()):
    st = tuple(stacked) if stacked is not None else ()
    sa = tuple(prefix) if stacked is not None else ()
    d = cfg.d_model
    d_inner, nheads, conv_dim, d_in_proj = mamba2_dims(cfg)
    return {
        "norm": Spec(st + (d,), sa + (None,), "ones"),
        "in_proj": Spec(st + (d, d_in_proj), sa + ("embed", "d_inner")),
        "conv_w": Spec(st + (cfg.conv_width, conv_dim),
                       sa + (None, "conv_dim")),
        "conv_b": Spec(st + (conv_dim,), sa + (None,), "zeros"),
        "A_log": Spec(st + (nheads,), sa + (None,), "ssm_a"),
        "D": Spec(st + (nheads,), sa + (None,), "ones"),
        "dt_bias": Spec(st + (nheads,), sa + (None,), "ssm_dt"),
        "ssm_norm": Spec(st + (d_inner,), sa + (None,), "ones"),
        "out_proj": Spec(st + (d_inner, d), sa + ("d_inner", "embed")),
    }


# ----------------------------------------------------------------- SSD core
def ssd_chunked(x, dt, A, B, C, chunk: int, impl: str = "jnp",
                init_state=None):
    """Chunked SSD scan.

    x:  (b, s, h, p)   — per-head inputs
    dt: (b, s, h)      — positive step sizes
    A:  (h,)           — negative decay rates (A = -exp(A_log))
    B:  (b, s, n)      — input projection (single group, shared over heads)
    C:  (b, s, n)      — output projection
    ``init_state`` (b, h, p, n) seeds the inter-chunk recurrence (zeros
    when None) — block-boundary continuation for multi-chunk prefill:
    prefilling ``s`` tokens from a carried state is exactly equivalent
    to one longer prefill over history + chunk.
    Returns y: (b, s, h, p), final_state: (b, h, p, n).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32

    xr = x.reshape(b, nc, chunk, h, p).astype(f32)
    dtr = dt.reshape(b, nc, chunk, h).astype(f32)
    Br = B.reshape(b, nc, chunk, n).astype(f32)
    Cr = C.reshape(b, nc, chunk, n).astype(f32)
    dA = dtr * A.astype(f32)                      # (b,nc,l,h) negative
    dA_cs = jnp.cumsum(dA, axis=2)                # within-chunk cumsum

    if impl == "pallas":
        from repro.kernels.ssd import ops as ssd_ops
        y_diag, chunk_states = ssd_ops.ssd_intra_chunk(xr, dtr, dA_cs, Br, Cr)
    else:
        y_diag, chunk_states = ssd_intra_chunk_ref(xr, dtr, dA_cs, Br, Cr)

    # inter-chunk recurrence on states: (b, nc, h, p, n)
    chunk_decay = jnp.exp(dA_cs[:, :, -1])        # (b,nc,h) total chunk decay

    def scan_fn(state, inp):
        st_c, decay = inp                          # (b,h,p,n), (b,h)
        new = state * decay[..., None, None] + st_c
        return new, state                          # emit state *entering* chunk

    init = jnp.zeros((b, h, p, n), f32) if init_state is None \
        else init_state.astype(f32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,nc,h,p,n)

    # contribution of the entering state to each position in the chunk
    state_decay = jnp.exp(dA_cs)                   # (b,nc,l,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cr, prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_intra_chunk_ref(xr, dtr, dA_cs, Br, Cr):
    """Pure-jnp intra-chunk SSD (the Pallas kernel oracle).

    xr: (b,nc,l,h,p) f32; dtr: (b,nc,l,h); dA_cs: (b,nc,l,h) cumsum of dt*A;
    Br, Cr: (b,nc,l,n).
    Returns y_diag (b,nc,l,h,p) and per-chunk state contributions
    (b,nc,h,p,n).
    """
    # decay from position j to i (i >= j): exp(dA_cs[i] - dA_cs[j])
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]  # (b,nc,i,j,h)
    l = xr.shape[2]
    mask = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cr, Br)                 # (b,nc,i,j)
    att = cb[..., None] * decay                                # (b,nc,i,j,h)
    xdt = xr * dtr[..., None]                                  # (b,nc,l,h,p)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", att, xdt)
    # state contribution of this chunk: sum_j exp(dA_cs[-1]-dA_cs[j]) B_j x_j
    last = dA_cs[:, :, -1:, :]                                 # (b,nc,1,h)
    w = jnp.exp(last - dA_cs)                                  # (b,nc,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Br, w, xdt)
    return y_diag, states


# ----------------------------------------------------------------- block
def _causal_conv(xBC, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv1d, width W. xBC: (b, s, c); conv_w: (W, c).

    With ``conv_state`` (b, W-1, c) performs streaming decode conv and
    returns the updated state.
    """
    w = conv_w.shape[0]
    if conv_state is not None:
        window = jnp.concatenate([conv_state, xBC], axis=1)   # (b, W-1+s, c)
        new_state = window[:, -(w - 1):]
    else:
        pad = jnp.zeros(xBC.shape[:1] + (w - 1,) + xBC.shape[2:], xBC.dtype)
        window = jnp.concatenate([pad, xBC], axis=1)
        new_state = window[:, -(w - 1):]
    out = sum(window[:, i:i + xBC.shape[1]] * conv_w[i][None, None]
              for i in range(w))
    return jax.nn.silu(out + conv_b[None, None]), new_state


def mamba2_block(p, x, cfg: ModelConfig, *, ssm_state=None, conv_state=None,
                 impl: str = "jnp", active=None,
                 init_ssm=None, init_conv=None):
    """Full Mamba2 block. x: (b, s, d).

    Training/prefill: ssm_state/conv_state None -> chunked SSD.
    Decode: states provided (s==1) -> recurrent update; returns
    (out, (ssm_state, conv_state)).

    ``init_ssm`` (b,h,p,n) / ``init_conv`` (b,W-1,conv_dim) seed the
    prefill branch for state-continued (multi-chunk) prefill: chunk i+1
    starts from chunk i's final states, exactly equivalent to one long
    prefill over the concatenated token stream.
    """
    dt_c = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    d_inner, nheads, conv_dim, _ = mamba2_dims(cfg)
    n = cfg.ssm_state
    hp = cfg.ssm_head_dim

    h = L.rms_norm(x, p["norm"], cfg.norm_eps).astype(dt_c)
    proj = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(dt_c))
    proj = constrain(proj, "batch", None, "d_inner")
    z, xBC, dt_raw = jnp.split(
        proj, [d_inner, d_inner + conv_dim], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (b,s,h)

    decoding = ssm_state is not None
    xBC, new_conv = _causal_conv(xBC, p["conv_w"].astype(dt_c),
                                 p["conv_b"].astype(dt_c),
                                 conv_state if decoding else init_conv)
    xs, B, C = jnp.split(xBC, [d_inner, d_inner + n], axis=-1)
    xh = xs.reshape(b, s, nheads, hp)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (h,)

    if not decoding:
        y, final_state = ssd_chunked(xh, dt, A, B, C,
                                     min(cfg.ssm_chunk, s), impl=impl,
                                     init_state=init_ssm)
        new_ssm = final_state
        # new_conv (the last W-1 pre-conv activations) enables exact
        # streaming decode right after a chunked prefill
    else:
        # single-token recurrence: state (b,h,p,n)
        dA = jnp.exp(dt[:, 0] * A[None])                       # (b,h)
        xdt = xh[:, 0].astype(jnp.float32) * dt[:, 0][..., None]
        upd = jnp.einsum("bhp,bn->bhpn", xdt, B[:, 0].astype(jnp.float32))
        new_ssm = ssm_state * dA[..., None, None] + upd
        if active is not None:
            new_ssm = jnp.where(active[:, None, None, None], new_ssm,
                                ssm_state)
            new_conv = jnp.where(active[:, None, None], new_conv,
                                 conv_state)
        y = jnp.einsum("bhpn,bn->bhp", new_ssm,
                       C[:, 0].astype(jnp.float32))[:, None]
        y = y.reshape(b, 1, nheads, hp).astype(dt_c)

    y = y + xh * p["D"].astype(dt_c)[None, None, :, None]
    y = y.reshape(b, s, d_inner)
    y = y * jax.nn.silu(z)                                     # gated
    y = L.rms_norm(y, p["ssm_norm"], cfg.norm_eps).astype(dt_c)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_c))
    out = x + constrain(out, "batch", None, "embed")
    return out, (new_ssm, new_conv)
